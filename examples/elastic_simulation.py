"""Elastic re-shard of the distributed scan core — the thesis's headline
contribution, end to end:

    python examples/elastic_simulation.py        (4 emulated members)

The IntelligentAdaptiveScaler watches simulation load and grows the mesh
1→2→4 members (then shrinks back) MID-RUN.  Each scale event rebalances the
271-virtual-partition ``PartitionTable`` (re-homing only the moved
partitions), retires exactly the outgoing mesh's compiled core, and re-homes
the DataGrid; because VM ownership is a *runtime* operand of the distributed
scan core, the next simulation's finish vector is BIT-identical to a
fixed-mesh run — elasticity with zero accuracy cost (PAPER §4.1.3, §4.3).
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.cloudsim import (ElasticSimulationCluster, SimulationConfig,
                                 run_simulation)
from repro.core.health import HealthConfig

import dataclasses


def main():
    devs = jax.devices()
    cfg = SimulationConfig(n_vms=200, n_cloudlets=400, broker="matchmaking",
                           core="scan_dist")
    fixed = run_simulation(dataclasses.replace(cfg, core="scan"),
                           Mesh(np.array(devs[:1]), ("data",)))

    hc = HealthConfig(target_step_time=1.0, max_threshold=0.8,
                      min_threshold=0.2, time_between_scaling=1, window=1,
                      max_instances=4)
    cluster = ElasticSimulationCluster(devices=devs, health_cfg=hc,
                                       start_members=1)
    loads = [2.0, 2.0, 0.05]                 # hot, hot, idle -> out, out, in
    r = cluster.simulate(cfg)
    print(f"members={cluster.n_members}  makespan={r.makespan:9.1f}  "
          f"bit-identical={np.array_equal(fixed.finish_times, r.finish_times)}")
    for load in loads:
        decision = cluster.observe_load(load)
        ev = cluster.scale_events[-1]
        r = cluster.simulate(cfg)
        ok = np.array_equal(fixed.finish_times, r.finish_times)
        print(f"load={load:4.2f} decision={int(decision):+d} -> "
              f"members={cluster.n_members}  moved_partitions="
              f"{ev['moved_partitions']}/271  retired_cores="
              f"{ev['retired_cores']}  bit-identical={ok}")
        assert ok
    assert [e["n_members"] for e in cluster.scale_events] == [2, 4, 2]
    print("IAS scale-out 1->2->4 and scale-in 4->2: finish vectors "
          "bit-identical throughout OK")


if __name__ == "__main__":
    main()
