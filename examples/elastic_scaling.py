"""Adaptive scaling + fault tolerance: the IntelligentAdaptiveScaler grows the
member set under load, and a simulated member crash recovers from the last
checkpoint (synchronous-backup semantics)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config, reduced
from repro.core.health import HealthConfig
from repro.data.pipeline import DataConfig
from repro.models.model import build_model
from repro.train.elastic_runner import run_elastic_training


def main():
    cfg = reduced(get_config("smollm-360m"), n_layers=2, d_model=64,
                  n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                  vocab_size=256)
    model = build_model(cfg, remat=False, xent_chunk=16)
    with tempfile.TemporaryDirectory() as ckpt:
        rep = run_elastic_training(
            model, steps=30, data_cfg=DataConfig(256, 32, 8),
            start_instances=1, ckpt_dir=ckpt, inject_failure_at=20,
            health_cfg=HealthConfig(target_step_time=1e-4,   # always "hot"
                                    min_threshold=-1.0,
                                    time_between_scaling=5, window=2))
    print(f"scale events: {rep.scale_events}")
    print(f"final members: {rep.final_n_instances}; "
          f"restarts after injected crash: {rep.restarts}")
    assert rep.scale_events and rep.restarts == 1
    print("elastic scaling + crash recovery OK")


if __name__ == "__main__":
    main()
