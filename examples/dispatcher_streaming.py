"""The unified elastic dispatch middleware, end to end:

    python examples/dispatcher_streaming.py      (4 emulated members)

ONE dispatcher under all three execution paths — a scenario grid and a
MapReduce word count stream through it chunk by chunk while the
IntelligentAdaptiveScaler grows the mesh 1→2→4 and shrinks it back MID-
STREAM, and the elastic DES cluster runs as a thin client of the same
instance.  Every chunk of a geometry reuses one compiled executable (the
CompileCache counters prove it) and results are BIT-identical to a
single-member run — the thesis's "general purpose auto scaler middleware"
claim, demonstrated.

The stream is an ASYNC, DOUBLE-BUFFERED pipeline: chunk k+1 is staged (on
device, for device-resident corpora) while chunk k computes, and a scale
event is a pipeline BARRIER — the dispatcher drains the in-flight chunks
(watch ``drained_in_flight`` in the scale-event log), rebalances, rebuilds
the mesh, and resumes, with chunk boundaries and reduce order unchanged.
Float MapReduce jobs (``word_weight_job``) ride the deterministic tree
reduction, so even non-associative f32 sums come out bit-identical across
every member count and scale path.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.cloudsim import ElasticSimulationCluster, SimulationConfig
from repro.core.des_scan import make_scenario_grid, run_scenario_grid
from repro.core.dispatch import ElasticDispatcher
from repro.core.health import HealthConfig
from repro.core.mapreduce import MapReduceEngine, make_corpus, word_count_job


def loads_feeder(seq):
    it = iter(seq)

    def on_chunk(disp, ci, n):
        load = next(it, None)
        if load is not None:
            disp.observe_load(load)

    return on_chunk


def main():
    hc = HealthConfig(target_step_time=1.0, max_threshold=0.8,
                      min_threshold=0.2, time_between_scaling=1, window=1,
                      max_instances=4)
    dispatcher = ElasticDispatcher(health_cfg=hc, start_members=1,
                                   dispatch_ahead=2)   # async double-buffer

    # ---- 1. a scenario GRID streamed in chunks across scale events -------
    cfg = SimulationConfig(n_vms=32, n_cloudlets=256, broker="matchmaking")
    grid = make_scenario_grid(seeds=range(8), mi_scales=[0.75, 1.5],
                              vm_counts=[16, 32], dc_counts=[0, 3])
    B = len(grid["seeds"])
    ref = run_scenario_grid(cfg, grid)                 # single-member oracle
    r = run_scenario_grid(cfg, grid, dispatcher=dispatcher, chunk=16,
                          on_chunk=loads_feeder([0.5, 2.0, 0.5, 2.0]))
    rep = r.dispatch
    print(f"grid: {B} variants in {rep['n_chunks']} chunks, members per "
          f"chunk {rep['members_per_chunk']}")
    print(f"      compiles={rep['compiles']} cache_hits={rep['cache_hits']} "
          f"scale_events={rep['scale_events']} "
          f"max_in_flight={rep['max_in_flight']}")
    for ev in dispatcher.scale_events:
        print(f"      remesh barrier -> {ev['n_members']} members: drained "
              f"{ev['drained_in_flight']} in-flight chunk(s), retired "
              f"{ev['retired_jobs']} executable(s)")
    assert np.array_equal(ref.finish_times, r.finish_times)
    print("      finish vectors BIT-identical to the single-member run")

    # ---- 2. MapReduce word count on the SAME middleware ------------------
    corpus = make_corpus(12, 4096, vocab=1024)
    expected = np.bincount(corpus.reshape(-1), minlength=1024)
    eng = MapReduceEngine(backend="hazelcast", dispatcher=dispatcher)
    out = eng.run(word_count_job(1024), corpus, chunk=4,
                  on_chunk=loads_feeder([2.0, 0.05]))
    print(f"mapreduce: 12 files in {eng.last_report.n_chunks} chunks, "
          f"members per chunk {eng.last_report.members_per_chunk}")
    assert np.array_equal(np.asarray(out), expected)
    print("      word count exact vs numpy across the scale path")

    # ---- 2b. FLOAT MapReduce: deterministic tree reduction ---------------
    from repro.core.mapreduce import word_weight_job
    w1 = np.asarray(eng.run(word_weight_job(1024), corpus, chunk=4,
                            on_chunk=loads_feeder([2.0, 0.05])))
    w2 = np.asarray(MapReduceEngine(
        backend="infinispan",
        dispatcher=ElasticDispatcher(start_members=1)).run(
            word_weight_job(1024), corpus))
    assert np.array_equal(w1, w2)
    print("mapreduce: f32 word-weight job bit-identical across backends, "
          "member counts and the scale path (deterministic tree reduction)")

    # ---- 3. the elastic DES cluster as a thin client ---------------------
    cluster = ElasticSimulationCluster(dispatcher=dispatcher)
    res = cluster.simulate(SimulationConfig(n_vms=40, n_cloudlets=80,
                                            core="scan_dist"))
    print(f"cluster: simulate() on the shared dispatcher at "
          f"{cluster.n_members} members, makespan {res.makespan:.1f}")
    print(f"scale events so far: {len(dispatcher.scale_events)}; "
          f"cache stats {dispatcher.cache.stats()}")


if __name__ == "__main__":
    main()
