"""The paper's default MapReduce job — word count — on both backends
(the Hazelcast/Infinispan pair), optionally through the Pallas histogram
kernel (interpret mode on CPU)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.mapreduce import MapReduceEngine, make_corpus, word_count_job


def main():
    mesh = Mesh(np.array(jax.devices()), ("data",))
    vocab, n_files = 2048, 8
    corpus = jnp.asarray(make_corpus(n_files, 16384, vocab))
    expected = np.bincount(np.asarray(corpus).reshape(-1), minlength=vocab)
    print(f"corpus: {n_files} files x 16384 tokens; vocab {vocab}; "
          f"map() invocations = {n_files}, reduce keys = {vocab}")
    for backend in ("hazelcast", "infinispan"):
        eng = MapReduceEngine(mesh, backend=backend)
        out, secs = eng.benchmark(word_count_job(vocab), corpus)
        assert np.array_equal(np.asarray(out), expected)
        print(f"  {backend:11s} {secs * 1e3:8.2f} ms/job  "
              f"top-5 tokens: {np.argsort(np.asarray(out))[-5:][::-1].tolist()}")
    out_k = MapReduceEngine(mesh, backend="hazelcast").run(
        word_count_job(vocab, use_kernel=True), corpus)
    assert np.array_equal(np.asarray(out_k), expected)
    print("  pallas histogram kernel backend agrees OK")


if __name__ == "__main__":
    main()
