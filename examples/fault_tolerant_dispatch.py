"""Fault-tolerant elastic dispatch, end to end:

    python examples/fault_tolerant_dispatch.py   (4 emulated members + spare)

A scenario grid streams through the `ElasticDispatcher` as an async
pipeline while a seeded `FaultInjector` KILLS member 1 halfway through the
stream.  The dispatcher detects the crash at launch, drains the pipeline,
promotes the DataGrid's synchronous backups, pulls a spare device into the
mesh (forced failure remesh — watch ``reason: member_failure`` in the
recovery log), and REPLAYS the lost chunks.  The finish vector of the
faulted run is compared elementwise against a fault-free single-member
sync run: byte-for-byte identical, the bit-identical-replay guarantee of
docs/robustness.md.

A second pass injects a NaN-poisoned chunk and a compile failure under a
`RetryPolicy`, showing chunk-level retry with structured failure records
(`report.failures[*].recovered_after_s`) instead of a mesh change.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=5")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.cloudsim import SimulationConfig
from repro.core.des_scan import make_scenario_grid, run_scenario_grid
from repro.core.dispatch import ElasticDispatcher
from repro.core.faults import FaultInjector, FaultSpec, RetryPolicy

import jax


def main():
    cfg = SimulationConfig(n_vms=32, n_cloudlets=256)
    grid = make_scenario_grid(seeds=range(8), mi_scales=[0.75, 1.5],
                              vm_counts=[16, 32])
    B = len(grid["seeds"])
    chunk = 4
    n_chunks = -(-B // chunk)

    # ---- reference: fault-free, single member, synchronous ---------------
    ref = run_scenario_grid(
        cfg, grid, dispatcher=ElasticDispatcher(devices=jax.devices()[:1],
                                                start_members=1,
                                                dispatch_ahead=0),
        chunk=chunk)

    # ---- 1. kill member 1 mid-stream, spare device absorbs the loss ------
    kill_at = n_chunks // 2
    inj = FaultInjector([FaultSpec("member_crash", chunk=kill_at, member=1)])
    d = ElasticDispatcher(devices=jax.devices(),   # 5 devices: 1 spare
                          start_members=4, dispatch_ahead=2,
                          fault_injector=inj)
    r = run_scenario_grid(cfg, grid, dispatcher=d, chunk=chunk)
    rep = r.dispatch
    ev = rep["recovery_events"][0]
    print(f"member crash @ chunk {kill_at}:")
    print(f"  cause            : {ev['cause']}")
    print(f"  dead device      : {ev['dead_device']}")
    print(f"  replayed chunks  : {ev['replayed_chunks']}")
    print(f"  recovery latency : {ev['recovery_s']:.3f}s")
    print(f"  members now      : {d.n_members} "
          f"(pool {len(d.devices)} devices, "
          f"{len(d.dead_devices)} dead)")
    identical = np.array_equal(np.asarray(ref.finish_times),
                               np.asarray(r.finish_times))
    print(f"  finish vector bit-identical to fault-free 1-member sync run: "
          f"{identical}")
    assert identical

    # ---- 2. chunk-level faults: NaN poison + compile failure -------------
    inj2 = FaultInjector([FaultSpec("nan_poison", chunk=1, member=0),
                          FaultSpec("compile_fail", chunk=3)])
    d2 = ElasticDispatcher(devices=jax.devices()[:2], start_members=2,
                           dispatch_ahead=2, fault_injector=inj2,
                           retry_policy=RetryPolicy(max_attempts=3,
                                                    check_finite=True))
    r2 = run_scenario_grid(cfg, grid, dispatcher=d2, chunk=chunk)
    print("\nchunk-level faults (no mesh change, retried in place):")
    for f in r2.dispatch["failures"]:
        print(f"  chunk {f['chunk']}: {f['kind']} (attempt {f['attempt']}, "
              f"member {f['member']}) -> recovered after "
              f"{f['recovered_after_s']:.3f}s")
    print(f"  retries: {r2.dispatch['retries']}, "
          f"result identical: "
          f"{np.array_equal(np.asarray(ref.finish_times), np.asarray(r2.finish_times))}")
    print("\nOK")


if __name__ == "__main__":
    main()
