"""Quickstart: train a small LM end-to-end with the elastic runtime.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced smollm-family model, streams deterministic synthetic data,
runs the jitted train step under the health monitor, checkpoints, and shows
a resume."""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config, reduced
from repro.core.health import HealthConfig
from repro.data.pipeline import DataConfig
from repro.models.model import build_model
from repro.train.elastic_runner import run_elastic_training
from repro.train.optimizer import AdamWConfig


def main():
    cfg = reduced(get_config("smollm-360m"), n_layers=4, d_model=128,
                  n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
                  vocab_size=512)
    model = build_model(cfg, remat=False, xent_chunk=32)
    print(f"arch family: {cfg.family}; params "
          f"{cfg.param_count() / 1e6:.2f}M; devices {len(jax.devices())}")
    with tempfile.TemporaryDirectory() as ckpt:
        report = run_elastic_training(
            model, steps=40,
            data_cfg=DataConfig(cfg.vocab_size, seq_len=64, global_batch=8),
            opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40),
            health_cfg=HealthConfig(target_step_time=10.0),
            ckpt_dir=ckpt)
        for i in range(0, 40, 8):
            print(f"  step {i:3d}  loss {report.losses[i]:.4f}")
        print(f"final loss {report.losses[-1]:.4f} "
              f"(started {report.losses[0]:.4f})")
    assert report.losses[-1] < report.losses[0]
    print("quickstart OK")


if __name__ == "__main__":
    main()
