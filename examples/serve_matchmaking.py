"""Continuous-batching LM serving with the thesis's two brokers: requests are
cloudlets, KV-cache slots are VMs; matchmaking binds each request to the
smallest adequate slot bucket with round-robin fairness."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serve.scheduler import Request, ServeEngine


def main():
    cfg = reduced(get_config("smollm-360m"), n_layers=2, d_model=64,
                  n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                  vocab_size=256)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    for policy in ("round_robin", "matchmaking"):
        engine = ServeEngine(model, params, n_slots=4, max_len=48,
                             policy=policy)
        for i in range(8):
            prompt = rng.integers(0, 256, size=int(rng.integers(2, 10)))
            engine.sched.submit(Request(i, prompt.astype(np.int32),
                                        max_new_tokens=int(rng.integers(2, 6))))
        out = engine.run(max_steps=128)
        print(f"{policy:13s} completed {len(out['completed'])}/8 in "
              f"{out['steps']} decode steps (dropped={out['dropped']})")
        for r in out["completed"][:2]:
            print(f"   req {r.req_id}: prompt[{len(r.prompt)}] -> {r.output}")


if __name__ == "__main__":
    main()
