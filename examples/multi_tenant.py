"""Multi-tenancy (§3.1.2): the Coordinator runs two tenants — a CloudSim
simulation and a MapReduce job — over one device pool and reports the
combined health/scaling view (Fig 3.4)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.coordinator import Coordinator
from repro.core.cloudsim import SimulationConfig, run_simulation
from repro.core.mapreduce import MapReduceEngine, make_corpus, word_count_job


def tenant_cloudsim(mesh, ctx):
    r = run_simulation(SimulationConfig(n_vms=64, n_cloudlets=128,
                                        broker="matchmaking"), mesh)
    return {"makespan": r.makespan}


def tenant_mapreduce(mesh, ctx):
    corpus = jnp.asarray(make_corpus(4, 4096, 512))
    out = MapReduceEngine(mesh, backend="infinispan").run(
        word_count_job(512), corpus)
    return {"total_tokens": int(np.asarray(out).sum())}


def main():
    coord = Coordinator()
    coord.register("cluster1-cloudsim", tenant_cloudsim, n_devices=2)
    coord.register("cluster2-mapreduce", tenant_mapreduce, n_devices=2)
    results = coord.run_all()
    print("tenant results:", results)
    print("coordinator view:", coord.report())
    assert all(t == "done" for t in coord.report()["tenants"].values())
    print("multi-tenant coordination OK")


if __name__ == "__main__":
    main()
