"""Multi-tenancy (§3.1.2, thesis conclusion): concurrent tenants submit a
scenario grid AND a MapReduce job through the ``TenantFrontEnd`` — one
shared elastic dispatcher and compile cache, per-tenant quotas, weighted-
fair scheduling, and a fault aimed at one tenant contained to that tenant
(see docs/serving.md)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.cloudsim import SimulationConfig
from repro.core.des_scan import make_scenario_grid
from repro.core.dispatch import ElasticDispatcher
from repro.core.faults import FaultInjector, FaultSpec, RetryPolicy
from repro.core.health import HealthConfig
from repro.core.mapreduce import make_corpus, word_count_job
from repro.serve.frontend import (TenantFrontEnd, grid_request,
                                  mapreduce_request)


def main():
    # one cluster serves every tenant; the mmn policy may scale it under load
    hc = HealthConfig(policy="mmn", max_instances=4, time_between_scaling=2)
    fe = TenantFrontEnd(ElasticDispatcher(start_members=2, health_cfg=hc),
                        backlog_max=32,
                        fault_injector=FaultInjector([
                            # chaos aimed at ONE tenant: nobody else sees it
                            FaultSpec(kind="nan_poison", chunk=0,
                                      tenant="cluster3-chaos")]))
    fe.register_tenant("cluster1-cloudsim", weight=2.0, priority=1)
    fe.register_tenant("cluster2-mapreduce", weight=1.0, priority=1)
    fe.register_tenant("cluster3-chaos", priority=0,
                       retry_policy=RetryPolicy(max_attempts=2,
                                                check_finite=True))

    cfg = SimulationConfig(n_vms=32, n_cloudlets=128, broker="matchmaking")
    grid = make_scenario_grid(seeds=range(2), mi_scales=[0.75, 1.5],
                              vm_counts=[16, 32],
                              mips_dists=["uniform", "fixed"])
    corpus = make_corpus(8, 2048, 512)

    decisions = [
        fe.submit(grid_request("cluster1-cloudsim", cfg, grid, chunk=8)),
        fe.submit(mapreduce_request("cluster2-mapreduce",
                                    word_count_job(512), corpus,
                                    backend="infinispan", chunk=4)),
        fe.submit(grid_request("cluster3-chaos", cfg, grid, chunk=8)),
    ]
    assert all(d.admitted for d in decisions), decisions
    outcomes = fe.run()

    for o in outcomes:
        status = "ok" if o["ok"] else f"FAILED ({o['error']})"
        print(f"  {o['tenant']} req#{o['req_id']}: {status}")
    view = fe.summary()
    print("front-end view:", {k: view[k] for k in
                              ("backlog", "n_members", "scale_events",
                               "cache")})
    grids = fe.tenants["cluster1-cloudsim"].results
    mapred = fe.tenants["cluster2-mapreduce"].results
    assert grids and mapred
    total_tokens = int(np.asarray(list(mapred.values())[0]).sum())
    print(f"tenant results: {len(grids)} grid request(s) served; "
          f"total_tokens={total_tokens}")
    # the chaos tenant's poisoned chunk was caught by its own retry budget;
    # an UNrecoverable failure would likewise stay contained to it
    assert fe.tenants["cluster3-chaos"].completed == 1
    assert fe.tenants["cluster1-cloudsim"].completed == 1
    print("multi-tenant serving OK")


if __name__ == "__main__":
    main()
