"""Durable dispatch, end to end:

    python examples/checkpoint_resume.py   (4 emulated members)

A scenario grid streams through the `ElasticDispatcher` with a
`CheckpointPolicy`, so every validated chunk is journaled and the partial
reduce state is checkpointed at pow2-aligned boundaries.  Mid-stream the
process receives SIGTERM — the preemption notice cluster schedulers send
before SIGKILL.  The installed drain handler stops launching, retires and
validates everything in flight, checkpoints the exact validated watermark,
and raises `DrainInterrupted` with the journal path.

A FRESH cluster (the restarted coordinator) then calls `resume_grid`: the
journal's environment signature is verified, already-checkpointed chunks
are skipped, in-flight casualties are replayed against their journaled
digests, and the finished makespan vector is byte-for-byte identical to an
uninterrupted run — the coordinator failure model of docs/robustness.md.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import shutil
import signal
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.cloudsim import ElasticSimulationCluster, SimulationConfig
from repro.core.des_scan import make_scenario_grid
from repro.core.journal import CheckpointPolicy, DrainInterrupted


def main():
    cfg = SimulationConfig(n_vms=16, n_cloudlets=128, core="scan")
    grid = make_scenario_grid(seeds=range(16), mi_scales=[0.75, 1.5])
    B = len(grid["seeds"])
    chunk = 4
    n_chunks = -(-B // chunk)
    workdir = tempfile.mkdtemp(prefix="ckpt_demo_")
    ck = os.path.join(workdir, "journal")

    # ---- reference: the uninterrupted run --------------------------------
    ref = ElasticSimulationCluster(start_members=2).simulate_grid(
        cfg, grid, chunk=chunk)

    # ---- journaled run, SIGTERM'd halfway --------------------------------
    cluster = ElasticSimulationCluster(start_members=2)
    cluster.dispatcher.install_drain_signal(signal.SIGTERM)

    def preempt(_d, ci, _n):
        if ci == n_chunks // 2:           # a scheduler would send this from
            os.kill(os.getpid(), signal.SIGTERM)   # outside, asynchronously

    try:
        cluster.simulate_grid(
            cfg, grid, chunk=chunk, on_chunk=preempt,
            checkpoint=CheckpointPolicy(path=ck, every_n_chunks=2))
        raise RuntimeError("drain did not interrupt the stream")
    except DrainInterrupted as e:
        rep = e.report
        print("SIGTERM -> graceful drain:")
        print(f"  journal          : {e.journal_path}")
        print(f"  checkpoints      : {rep.checkpoints} "
              f"(last write {rep.checkpoint_write_s[-1] * 1e3:.1f} ms)")

    # ---- the restarted coordinator resumes -------------------------------
    out, rep = ElasticSimulationCluster(start_members=2).resume_grid(
        ck, cfg, grid, chunk=chunk)
    _, _, makespans, _ = out
    identical = np.asarray(makespans).tobytes() == ref.makespans.tobytes()
    print("resume:")
    print(f"  chunks skipped   : {rep.chunks_skipped}/{rep.n_chunks}")
    print(f"  chunks replayed  : {rep.chunks_replayed}")
    print(f"  makespans bit-identical to uninterrupted run: {identical}")
    assert identical
    assert rep.chunks_skipped + rep.chunks_replayed == rep.n_chunks
    shutil.rmtree(workdir, ignore_errors=True)
    print("\nOK")


if __name__ == "__main__":
    main()
