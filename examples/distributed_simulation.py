"""The paper's core experiment: a distributed CloudSim simulation.

    python examples/distributed_simulation.py        (8 emulated members)

Round-robin and fair-matchmaking brokers schedule 400 cloudlets onto 200 VMs;
entity storage lives in the DataGrid, scheduling+workloads execute
member-locally (executeOnKeyOwner), and results are identical for any member
count — the thesis's accuracy claim.  The closing section shows phase 4
itself compute-partitioned: the owner-keyed exchange core re-homes each
cloudlet to its VM-owner member and sorts only ~C/M per member, with finish
vectors BIT-identical to the single-member scan."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.cloudsim import SimulationConfig, run_simulation


def main():
    devs = jax.devices()
    print(f"members available: {len(devs)}")
    for broker in ("round_robin", "matchmaking"):
        cfg = SimulationConfig(n_vms=200, n_cloudlets=400, broker=broker,
                               is_loaded=True, workload_iters_per_gmi=0.5)
        base = None
        for n in (1, 2, 8):
            mesh = Mesh(np.array(devs[:n]), ("data",))
            r = run_simulation(cfg, mesh)
            if base is None:
                base = r
            else:
                assert np.array_equal(base.vm_assign, r.vm_assign)
            t = sum(r.timings.values())
            print(f"  {broker:13s} members={n}  makespan={r.makespan:9.1f}  "
                  f"wall={t:6.2f}s  phases={ {k: round(v, 2) for k, v in r.timings.items()} }")
        print(f"  {broker}: identical scheduling on 1/2/8 members OK")

    # phase 4 compute-partitioned: owner-keyed exchange vs the single scan
    import jax.numpy as jnp

    from repro.core.des_scan import (simulate_completion_distributed,
                                     simulate_completion_scan)
    from repro.core.executor import DistributedExecutor

    rng = np.random.default_rng(0)
    C, V = 200_000, 1024
    assign = jnp.asarray(rng.integers(0, V, C).astype(np.int32))
    mi = jnp.asarray(rng.uniform(1e3, 5e4, C).astype(np.float32))
    mips = jnp.asarray(rng.uniform(500, 2000, V).astype(np.float32))
    valid = jnp.ones(C, bool)
    f_ref, _ = jax.jit(simulate_completion_scan)(assign, mi, mips, valid)
    for n in (1, 2, 8):
        ex = DistributedExecutor(Mesh(np.array(devs[:n]), ("data",)))
        f, _ = simulate_completion_distributed(assign, mi, mips, valid, ex)
        ok = np.array_equal(np.asarray(f), np.asarray(f_ref))
        print(f"  exchange core members={n}: each sorts ~{C // n} of {C} "
              f"cloudlets, bit-identical={ok}")
        assert ok


if __name__ == "__main__":
    main()
