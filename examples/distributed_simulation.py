"""The paper's core experiment: a distributed CloudSim simulation.

    python examples/distributed_simulation.py        (8 emulated members)

Round-robin and fair-matchmaking brokers schedule 400 cloudlets onto 200 VMs;
entity storage lives in the DataGrid, scheduling+workloads execute
member-locally (executeOnKeyOwner), and results are identical for any member
count — the thesis's accuracy claim."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.cloudsim import SimulationConfig, run_simulation


def main():
    devs = jax.devices()
    print(f"members available: {len(devs)}")
    for broker in ("round_robin", "matchmaking"):
        cfg = SimulationConfig(n_vms=200, n_cloudlets=400, broker=broker,
                               is_loaded=True, workload_iters_per_gmi=0.5)
        base = None
        for n in (1, 2, 8):
            mesh = Mesh(np.array(devs[:n]), ("data",))
            r = run_simulation(cfg, mesh)
            if base is None:
                base = r
            else:
                assert np.array_equal(base.vm_assign, r.vm_assign)
            t = sum(r.timings.values())
            print(f"  {broker:13s} members={n}  makespan={r.makespan:9.1f}  "
                  f"wall={t:6.2f}s  phases={ {k: round(v, 2) for k, v in r.timings.items()} }")
        print(f"  {broker}: identical scheduling on 1/2/8 members OK")


if __name__ == "__main__":
    main()
