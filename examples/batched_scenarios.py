"""Batched scenario sweeps on the closed-form DES core.

    python examples/batched_scenarios.py         (8 emulated members)

The segmented-scan core has no data-dependent event loop, so a whole stack
of scenario variants executes as ONE jitted vmap — and not just seeds ×
workload scales: the scenario GRID spans broker, VM-count, and
MIPS-distribution axes, with heterogeneous shapes padded (0-MIPS VMs,
valid=False cloudlets) so mixed variants stack.  The same grid also shards
across mesh members (the scenario vmap inside the partitioned member_fn)
with bit-identical results.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.cloudsim import SimulationConfig, run_simulation
from repro.core.des_scan import (make_scenario_grid, run_scenario_grid,
                                 run_simulation_batch)
from repro.core.executor import DistributedExecutor


def main():
    cfg = SimulationConfig(n_vms=256, n_cloudlets=5_000, broker="matchmaking")

    # --- 64 scenario variants in one jit: seeds x workload-length scales
    seeds = np.arange(64)
    scales = np.repeat(np.linspace(0.5, 2.0, 8), 8)
    r = run_simulation_batch(cfg, seeds, mi_scale=scales)
    s = r.summary()
    print(f"{r.n_scenarios} scenarios in {s['t_batch_total'] * 1e3:.1f} ms "
          f"({1 / s['t_per_scenario']:.0f} scenarios/s after jit)")
    print(f"makespan: min {s['min_makespan']:.0f}  "
          f"mean {s['mean_makespan']:.0f}  max {s['max_makespan']:.0f}")
    # heavier workloads -> longer makespans, scenario-for-scenario
    by_scale = r.makespans.reshape(8, 8).mean(axis=1)
    assert (np.diff(by_scale) > 0).all(), by_scale
    print("makespan grows monotonically with workload scale:",
          np.round(by_scale, 0))

    # --- the MULTI-AXIS grid: 2 brokers x 2 VM-counts x 3 MIPS-dists x
    #     2 scales x 4 seeds = 96 mixed-shape variants, one jit
    grid = make_scenario_grid(seeds=range(4), mi_scales=[0.75, 1.5],
                              brokers=["round_robin", "matchmaking"],
                              vm_counts=[128, 256],
                              mips_dists=["uniform", "fixed", "bimodal"])
    g = run_scenario_grid(cfg, grid)
    print(f"\n{g.n_scenarios}-variant multi-axis grid in "
          f"{g.timings['batch_total'] * 1e3:.1f} ms "
          f"({g.n_scenarios / g.timings['batch_total']:.0f} scenarios/s)")
    # padded rows are exactly 0; per-axis means show the axes matter
    for b in range(g.n_scenarios):
        assert (g.finish_times[b, int(g.n_cloudlets[b]):] == 0).all()
    for name, ids in (("broker", g.broker), ("mips_dist", g.mips_dist),
                      ("n_vms", g.n_vms)):
        means = {int(v): float(g.makespans[ids == v].mean())
                 for v in np.unique(ids)}
        print(f"  mean makespan by {name}: "
              + "  ".join(f"{k}:{v:.0f}" for k, v in means.items()))

    # --- the same grid sharded across 8 members: bit-identical, one
    #     member_fn dispatch with the scenario vmap inside
    ex = DistributedExecutor(Mesh(np.array(jax.devices()), ("data",)))
    gd = run_scenario_grid(cfg, grid, executor=ex)
    assert np.array_equal(g.finish_times, gd.finish_times)
    print(f"grid sharded over {ex.n_members} members: bit-identical, "
          f"{gd.n_scenarios / gd.timings['batch_total']:.0f} scenarios/s")

    # --- the same core, phase 4 distributed over members (identical output)
    devs = jax.devices()
    base = None
    for n in (1, 8):
        cfg_d = SimulationConfig(n_vms=256, n_cloudlets=5_000,
                                 broker="matchmaking",
                                 core="scan" if n == 1 else "scan_dist")
        rr = run_simulation(cfg_d, Mesh(np.array(devs[:n]), ("data",)))
        if base is None:
            base = rr
        else:
            assert np.array_equal(base.finish_times, rr.finish_times)
        print(f"members={n}  makespan={rr.makespan:9.1f}  "
              f"core_sim={rr.timings['core_sim'] * 1e3:.1f} ms "
              f"(first call, includes jit compile)")
    print("distributed phase 4 bit-identical on 1 vs 8 members OK")


if __name__ == "__main__":
    main()
