"""Batched scenario sweeps on the closed-form DES core.

    python examples/batched_scenarios.py         (8 emulated members)

The segmented-scan core has no data-dependent event loop, so a whole stack
of scenario variants — different seeds AND different workload scales —
executes as ONE jitted vmap.  64 scenarios of 5k cloudlets on 256 VMs run
in a single XLA dispatch; the same core also runs distributed (phase 4
partitioned over members by VM ownership) with identical results.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.cloudsim import SimulationConfig, run_simulation
from repro.core.des_scan import run_simulation_batch


def main():
    cfg = SimulationConfig(n_vms=256, n_cloudlets=5_000, broker="matchmaking")

    # --- 64 scenario variants in one jit: seeds x workload-length scales
    seeds = np.arange(64)
    scales = np.repeat(np.linspace(0.5, 2.0, 8), 8)
    r = run_simulation_batch(cfg, seeds, mi_scale=scales)
    s = r.summary()
    print(f"{r.n_scenarios} scenarios in {s['t_batch_total'] * 1e3:.1f} ms "
          f"({1 / s['t_per_scenario']:.0f} scenarios/s after jit)")
    print(f"makespan: min {s['min_makespan']:.0f}  "
          f"mean {s['mean_makespan']:.0f}  max {s['max_makespan']:.0f}")
    # heavier workloads -> longer makespans, scenario-for-scenario
    by_scale = r.makespans.reshape(8, 8).mean(axis=1)
    assert (np.diff(by_scale) > 0).all(), by_scale
    print("makespan grows monotonically with workload scale:",
          np.round(by_scale, 0))

    # --- the same core, phase 4 distributed over members (identical output)
    devs = jax.devices()
    base = None
    for n in (1, 8):
        cfg_d = SimulationConfig(n_vms=256, n_cloudlets=5_000,
                                 broker="matchmaking",
                                 core="scan" if n == 1 else "scan_dist")
        rr = run_simulation(cfg_d, Mesh(np.array(devs[:n]), ("data",)))
        if base is None:
            base = rr
        else:
            np.testing.assert_allclose(base.finish_times, rr.finish_times,
                                       atol=1e-3, rtol=1e-5)
        print(f"members={n}  makespan={rr.makespan:9.1f}  "
              f"core_sim={rr.timings['core_sim'] * 1e3:.1f} ms "
              f"(first call, includes jit compile)")
    print("distributed phase 4 identical on 1 vs 8 members OK")


if __name__ == "__main__":
    main()
