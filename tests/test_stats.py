"""Queueing-theoretic observability — the tier-1 validation suite.

Acceptance contract of the stats layer (ISSUE 7):

  * synthetic job streams of KNOWN service-time distribution, fed through
    ``DispatchStats``, reproduce the analytic M/M/n utilization and mean
    queue length (Erlang C / operational laws) within tolerance;
  * the queue-aware scaler (``HealthConfig.policy="mmn"``) makes the same
    call as the analytic bottleneck analysis for n ∈ {1, 2, 4, 8};
  * instrumentation NEVER changes results: streamed outputs are
    bit-identical with stats enabled.
"""
import math
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.health import HealthConfig, HealthMonitor
from repro.core.stats import (DispatchStats, Histogram, HistogramSet,
                              QueueSnapshot, StatsWindow, erlang_c, mmn_load,
                              mmn_metrics, mmn_required_members)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ------------------------------------------------------------- StatsWindow

def test_stats_window_int_trimming():
    w = StatsWindow(warmup=2, cooldown=1)
    w.extend([100.0, 50.0, 1.0, 2.0, 3.0, 999.0])
    np.testing.assert_array_equal(w.trimmed(), [1.0, 2.0, 3.0])
    assert w.mean() == 2.0
    assert w.percentile(50) == 2.0
    s = w.summary()
    assert s["n"] == 3.0 and s["mean"] == 2.0
    assert len(w) == 6 and w.raw().size == 6


def test_stats_window_fraction_trimming():
    w = StatsWindow(warmup=0.25, cooldown=0.25)     # quarter off each end
    w.extend(range(8))
    np.testing.assert_array_equal(w.trimmed(), [2.0, 3.0, 4.0, 5.0])


def test_stats_window_overtrimmed_is_nan():
    w = StatsWindow(warmup=3, cooldown=3)
    w.extend([1.0, 2.0])
    assert w.trimmed().size == 0
    assert math.isnan(w.mean()) and math.isnan(w.percentile(99))
    assert w.summary()["n"] == 0.0
    with pytest.raises(ValueError):
        StatsWindow(warmup=-1)


# --------------------------------------------------------------- Histogram

def test_histogram_quantile_bounded_relative_error():
    h = Histogram(lo=1e-3, hi=1e3, growth=1.5)
    rng = np.random.default_rng(0)
    samples = rng.uniform(0.01, 100.0, size=500)
    for v in samples:
        h.add(v)
    for q in (50, 95, 99):
        true = np.quantile(samples, q / 100.0, method="inverted_cdf")
        est = h.quantile(q)
        assert true <= est <= true * h.growth + 1e-12, (q, true, est)


def test_histogram_under_overflow_and_extrema_clamp():
    h = Histogram(lo=1.0, hi=10.0, growth=2.0)
    h.add(0.5)                      # underflow
    h.add(100.0)                    # overflow
    assert h.counts[0] == 1 and h.counts[-1] == 1
    assert h.quantile(1) == 1.0     # underflow reports lo
    assert h.quantile(99) == 100.0  # overflow clamps to observed max
    assert h.mean() == pytest.approx(50.25)


def test_histogram_rejects_bad_samples_and_merges():
    h = Histogram()
    with pytest.raises(ValueError):
        h.add(float("nan"))
    with pytest.raises(ValueError):
        h.add(-1.0)
    with pytest.raises(ValueError):
        Histogram(lo=0.0)
    a, b = Histogram(lo=1e-3, hi=1e3), Histogram(lo=1e-3, hi=1e3)
    for v in (0.1, 1.0):
        a.add(v)
    b.add(10.0)
    a.merge(b)
    assert a.count == 3 and a.max == 10.0 and a.min == 0.1
    with pytest.raises(ValueError):
        a.merge(Histogram(lo=1e-2, hi=1e3))


def test_histogram_set_shared_layout():
    hs = HistogramSet(lo=1e-3, hi=1e3)
    hs.record("service", 0.5)
    hs.record("service", 1.5)
    hs.record("queue_wait", 0.25)
    assert "service" in hs and "missing" not in hs
    qs = hs.quantiles((50,))
    assert set(qs) == {"service", "queue_wait"}
    assert hs["service"].count == 2
    assert hs.summary()["queue_wait"]["n"] == 1.0


# ---------------------------------------------------------- M/M/n analytics

def test_erlang_c_matches_mm1_closed_form():
    # M/M/1: P(wait) = rho exactly
    for rho in (0.1, 0.5, 0.9):
        assert erlang_c(1, rho) == pytest.approx(rho, rel=1e-12)
    # unstable and empty edges
    assert erlang_c(4, 4.0) == 1.0 and erlang_c(4, 0.0) == 0.0
    with pytest.raises(ValueError):
        erlang_c(0, 1.0)
    # adding servers at fixed offered load strictly reduces waiting
    waits = [erlang_c(n, 1.8) for n in (2, 3, 4, 8)]
    assert all(a > b for a, b in zip(waits, waits[1:]))


def test_mmn_metrics_closed_forms():
    # M/M/1: Lq = rho^2/(1-rho), W = 1/(mu-lam)
    m = mmn_metrics(lam=0.5, mu=1.0, n=1)
    assert m["rho"] == pytest.approx(0.5)
    assert m["lq"] == pytest.approx(0.25 / 0.5)
    assert m["w"] == pytest.approx(1.0 / (1.0 - 0.5))
    # Little's law internal consistency: L = lam * W
    for lam, mu, n in [(0.9, 0.5, 4), (3.0, 1.0, 8), (1.5, 1.0, 2)]:
        m = mmn_metrics(lam, mu, n)
        assert m["l"] == pytest.approx(lam * m["w"], rel=1e-12)
        assert m["lq"] == pytest.approx(lam * m["wq"], rel=1e-12)
    # instability
    m = mmn_metrics(lam=2.0, mu=1.0, n=2)
    assert m["rho"] == 1.0 and math.isinf(m["lq"])
    with pytest.raises(ValueError):
        mmn_metrics(1.0, 0.0, 1)


def test_mmn_required_members_is_analytic_bottleneck():
    assert mmn_required_members(lam=3.0, mu=1.0, rho_target=0.8) == 4
    assert mmn_required_members(lam=0.1, mu=1.0, rho_target=0.8) == 1
    assert mmn_required_members(lam=100.0, mu=1.0, rho_target=0.8,
                                max_members=8) == 8
    with pytest.raises(ValueError):
        mmn_required_members(1.0, 1.0, 0.0)


# ------------------------------- synthetic M/M/n stream vs operational laws

def _simulate_mmn(lam: float, mu: float, n: int, n_jobs: int, seed: int):
    """Event-driven FIFO M/M/n: Poisson arrivals (rate ``lam``), exp(mu)
    services, ``n`` parallel servers.  Returns (t_arrive, t_start, t_end)
    per job — the ground-truth event log the stats layer must reproduce."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / lam, size=n_jobs))
    services = rng.exponential(1.0 / mu, size=n_jobs)
    free_at = np.zeros(n)                     # next-free time per server
    out = []
    for t_arr, s in zip(arrivals, services):
        k = int(np.argmin(free_at))           # FIFO: earliest-free server
        t_start = max(t_arr, free_at[k])
        free_at[k] = t_start + s
        out.append((t_arr, t_start, t_start + s))
    return out


@pytest.mark.parametrize("n", [1, 2, 4, 8])
def test_measured_stats_match_mmn_analytics(n):
    """THE headline validation: a synthetic stream of known distribution,
    stamped through ``DispatchStats``, reproduces the Erlang-C utilization
    and mean queue length within sampling tolerance — and Little's law
    holds EXACTLY on the recorded event log."""
    mu, rho = 1.0, 0.7
    lam = rho * n * mu
    events = _simulate_mmn(lam, mu, n, n_jobs=4000, seed=n)
    st = DispatchStats(warmup=0, serialized=False)
    for i, (t_arr, t_start, t_end) in enumerate(events):
        st.record(i, t_enqueue=t_arr, t_dispatch=t_start, t_retire=t_end)

    q = st.queue_summary(n_servers=n)
    ana = mmn_metrics(lam, mu, n)
    assert q["n_completed"] == 4000
    # utilization law U = X·S/n vs analytic rho (finite-sample tolerance)
    assert q["utilization"] == pytest.approx(ana["rho"], rel=0.06)
    assert q["arrival_rate"] == pytest.approx(lam, rel=0.06)
    # time-averaged queue length vs Erlang-C Lq (Lq has high variance at
    # rho=0.7 — accept a generous but still discriminating band)
    assert q["mean_queue_length"] == pytest.approx(ana["lq"], rel=0.35), \
        (n, q["mean_queue_length"], ana["lq"])
    # Little's law L = λW is an IDENTITY on the event log: the horizon
    # integral equals the sojourn sum by construction
    t0, t1 = st.horizon()
    mean_sojourn = float(np.mean([e - a for a, _, e in events]))
    assert q["mean_in_system"] == pytest.approx(
        q["arrival_rate"] * mean_sojourn, rel=1e-9)
    # the per-interval windows decompose the sojourn: wait + service
    mean_wait = st.windows["queue_wait"].mean()
    mean_service = st.windows["service"].mean()
    assert mean_wait + mean_service == pytest.approx(mean_sojourn, rel=1e-9)
    assert mean_service == pytest.approx(1.0 / mu, rel=0.06)
    assert mean_wait == pytest.approx(ana["wq"], rel=0.35)


# ------------------------------------------------- queue-aware scaler calls

def _controller(n, max_instances=16):
    from repro.core.elastic import ElasticController
    hc = HealthConfig(window=1, time_between_scaling=1,
                      max_instances=max_instances)
    return ElasticController(hc, n)


@pytest.mark.parametrize("n", [1, 2, 4, 8])
def test_tick_queue_matches_analytic_bottleneck(n):
    """The scaler's decision agrees with the Erlang bottleneck analysis:
    scale OUT exactly when the analytic requirement exceeds n, scale IN
    when demand would be satisfied at min load by far fewer members."""
    from repro.core.elastic import Decision
    mu1 = 1.0
    # demand needing ~2n members at rho_target=0.8 -> analytic says grow
    lam_hot = 0.8 * (2 * n) * mu1
    assert mmn_required_members(lam_hot, mu1, 0.8) > n
    c = _controller(n)
    snap = QueueSnapshot(arrival_rate=lam_hot, service_rate=mu1, n_members=n)
    assert snap.rho >= 0.8
    assert c.tick_queue(snap) == Decision.SCALE_OUT
    assert c.n_instances == min(2 * n, 16)

    # demand satisfiable by far fewer members -> analytic says shrink
    lam_cold = 0.1 * n * mu1
    assert mmn_required_members(lam_cold, mu1, 0.8) <= max(n // 2, 1)
    c2 = _controller(n)
    snap2 = QueueSnapshot(arrival_rate=lam_cold, service_rate=mu1,
                          n_members=n)
    assert snap2.rho <= 0.2
    expect = Decision.SCALE_IN if n > 1 else Decision.NONE
    assert c2.tick_queue(snap2) == expect

    # balanced demand -> hold
    lam_ok = 0.5 * n * mu1
    c3 = _controller(n)
    assert c3.tick_queue(QueueSnapshot(
        arrival_rate=lam_ok, service_rate=mu1,
        n_members=n)) == Decision.NONE


def test_tick_queue_converges_to_analytic_member_count():
    """Iterating measure→decide from 1 member under fixed demand converges
    to a stable count that COVERS the analytic bottleneck requirement."""
    from repro.core.elastic import Decision
    mu1, lam = 1.0, 5.0                    # needs ceil(5/0.8) = 7 members
    need = mmn_required_members(lam, mu1, 0.8)
    c = _controller(1)
    for _ in range(10):
        d = c.tick_queue(QueueSnapshot(arrival_rate=lam, service_rate=mu1,
                                       n_members=c.n_instances))
        if d == Decision.NONE:
            break
    n_final = c.n_instances
    assert n_final >= need                       # demand is covered
    # and it is STABLE: neither threshold fires at the converged count
    assert c.tick_queue(QueueSnapshot(
        arrival_rate=lam, service_rate=mu1,
        n_members=n_final)) == Decision.NONE


def test_mmn_load_queue_pressure_override():
    """A saturated measured backlog forces the load signal to the scale-out
    threshold even when per-chunk service alone looks fine."""
    calm = QueueSnapshot(arrival_rate=1.0, service_rate=1.0, n_members=2,
                         queue_length=0.0)
    assert mmn_load(calm) == pytest.approx(0.5)
    backed_up = QueueSnapshot(arrival_rate=1.0, service_rate=1.0,
                              n_members=2, queue_length=20.0)
    assert mmn_load(backed_up, max_threshold=0.8, queue_cap=4.0) >= 0.8
    # pressure is capped: never more than 2x the threshold
    flood = QueueSnapshot(arrival_rate=1.0, service_rate=1.0, n_members=2,
                          queue_length=1e9)
    assert mmn_load(flood, max_threshold=0.8) == pytest.approx(1.6)


# ------------------------------------- HealthMonitor taint regression (sat 1)

def test_straggler_skew_excludes_tainted_samples():
    """Regression: a compile/remesh-spanning chunk's member walls must not
    trip straggler-skew detection — its skew is trace noise, and before the
    taint tag it polluted both the load window and the skew signal."""
    hm = HealthMonitor(HealthConfig(target_step_time=1.0, window=4))
    for i in range(4):
        hm.observe_chunk(step=i, wall_s=1.0, member_times=[1.0, 1.0, 1.0])
    assert hm.load() == pytest.approx(1.0)
    assert hm.straggler_skew() == pytest.approx(1.0)
    # a tainted sample with a 50x straggler and a 100x wall
    hm.observe_chunk(step=4, wall_s=100.0, member_times=[1.0, 1.0, 50.0],
                     tainted=True)
    assert hm.straggler_skew() == pytest.approx(1.0)   # newest CLEAN sample
    assert hm.load() == pytest.approx(1.0)             # window stays clean
    # a clean straggler IS still detected afterwards
    hm.observe_chunk(step=5, wall_s=1.0, member_times=[1.0, 1.0, 3.0])
    assert hm.straggler_skew() == pytest.approx(3.0)
    # tainted non-finite still flips health (crash detection never filtered)
    hm.observe_chunk(step=6, wall_s=1.0, finite=False, tainted=True)
    assert not hm.is_healthy()


# --------------------------------------------- dispatcher stats integration

def _double_job():
    from repro.core.dispatch import DispatchJob
    return DispatchJob(name="dbl", signature="dbl",
                      member_fn=lambda x, v, *_: x * 2.0, reduce="concat")


def test_dispatch_report_stats_structure():
    """collect_stats=True: every chunk is stamped at all four stages, the
    compile chunk is tainted, and the summary carries the queueing view."""
    from repro.core.dispatch import ElasticDispatcher
    d = ElasticDispatcher(start_members=1, collect_stats=True)
    x = np.arange(64, dtype=np.float32)
    out, rep = d.submit(_double_job(), x, chunk=8)
    np.testing.assert_allclose(np.asarray(out), x * 2.0)
    s = rep.stats
    assert s is not None
    assert s["n_records"] == rep.n_chunks == 8
    assert s["n_tainted"] >= 1                  # the compile chunk
    q = s["queue"]
    assert q["n_completed"] == 8 and q["horizon_s"] > 0
    assert 0 < q["utilization"] <= 1.0
    assert q["throughput"] > 0
    for name in ("queue_wait", "service", "validate", "sojourn"):
        assert {"n", "mean", "p50", "p95", "p99"} <= set(s[name])
    # windows exclude tainted + warmup records
    assert s["service"]["n"] <= s["n_records"] - s["n_tainted"]
    # a fresh summary survives JSON round-tripping (report consumers)
    import json
    json.dumps(s)


def test_dispatch_stats_off_by_default_and_per_submit_override():
    from repro.core.dispatch import ElasticDispatcher
    d = ElasticDispatcher(start_members=1)
    x = np.arange(16, dtype=np.float32)
    _, rep = d.submit(_double_job(), x, chunk=8)
    assert rep.stats is None
    _, rep_on = d.submit(_double_job(), x, chunk=8, collect_stats=True)
    assert rep_on.stats is not None
    # dispatcher-level default with per-submit opt-out
    d2 = ElasticDispatcher(start_members=1, collect_stats=True)
    _, r1 = d2.submit(_double_job(), x, chunk=8)
    assert r1.stats is not None
    _, r2 = d2.submit(_double_job(), x, chunk=8, collect_stats=False)
    assert r2.stats is None


def test_stats_instrumentation_bit_identical():
    """Instrumentation is pure host-side timestamping: streamed outputs are
    byte-identical with stats enabled, for concat AND deterministic sum."""
    import jax.numpy as jnp
    from repro.core.dispatch import DispatchJob, ElasticDispatcher
    x = np.linspace(0.1, 7.3, 64).astype(np.float32)
    det = DispatchJob(name="dsum", signature="dsum", reduce="sum",
                      deterministic=True,
                      member_fn=lambda v_, valid, *_: v_ * 1.7)
    for job in (_double_job(), det):
        d_off = ElasticDispatcher(start_members=1)
        d_on = ElasticDispatcher(start_members=1, collect_stats=True)
        out_off, _ = d_off.submit(job, x, chunk=8, deliver="host")
        out_on, rep_on = d_on.submit(job, x, chunk=8, deliver="host")
        assert np.asarray(out_off).tobytes() == np.asarray(out_on).tobytes()
        assert rep_on.stats is not None


def test_bad_policy_rejected():
    from repro.core.dispatch import ElasticDispatcher
    with pytest.raises(ValueError, match="policy"):
        ElasticDispatcher(health_cfg=HealthConfig(policy="bogus"))


def test_mmn_policy_scales_like_analytic_bottleneck():
    """End-to-end (8 fake devices): policy="mmn" under impossible demand
    scales 1→8, under trivial demand scales 4→1, and both runs' outputs
    stay bit-identical to the policy="ema" dispatcher's."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import numpy as np
from repro.core.dispatch import ElasticDispatcher, DispatchJob
from repro.core.health import HealthConfig

job = DispatchJob(name="dbl", signature="dbl",
                  member_fn=lambda x, v, *_: x * 2.0, reduce="concat")
x = np.arange(512, dtype=np.float32)
ref_d = ElasticDispatcher(start_members=1)
ref, _ = ref_d.submit(job, x, chunk=8, deliver="host")

# demand anchored at an impossible target -> rho >> 1 -> grow to the cap
hc = HealthConfig(policy="mmn", time_between_scaling=2, max_instances=8)
d = ElasticDispatcher(start_members=1, health_cfg=hc, auto_scale=True)
d.calibrate_target(job, 1e-7)
out, rep = d.submit(job, x, chunk=8, deliver="host")
assert d.n_members == 8, d.n_members
assert rep.scale_events == 3, rep.scale_events          # 1->2->4->8
assert rep.stats is not None                             # mmn forces stats
assert np.asarray(out).tobytes() == np.asarray(ref).tobytes()

# trivially satisfiable demand -> rho ~ 0 -> shrink to min_instances
hc2 = HealthConfig(policy="mmn", time_between_scaling=2, max_instances=8)
d2 = ElasticDispatcher(start_members=4, health_cfg=hc2, auto_scale=True)
d2.calibrate_target(job, 1e3)
out2, rep2 = d2.submit(job, x, chunk=8, deliver="host")
assert d2.n_members == 1, d2.n_members
assert np.asarray(out2).tobytes() == np.asarray(ref).tobytes()
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr
