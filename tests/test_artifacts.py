"""Dry-run artifact completeness: every assigned (arch × shape × mesh) cell
has a recorded dry-run result proving lower+compile succeeded."""
import glob
import json
import os

import pytest

from repro.configs import get_config, list_archs

DRY = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


@pytest.mark.skipif(not os.path.isdir(DRY), reason="dry-run artifacts absent")
@pytest.mark.parametrize("mesh", ["pod1", "pod2"])
def test_all_cells_have_artifacts(mesh):
    missing = []
    for arch in list_archs():
        for shape in get_config(arch).shapes():
            p = os.path.join(DRY, f"{arch}_{shape.name}_{mesh}.json")
            if not os.path.exists(p):
                missing.append((arch, shape.name))
    assert not missing, missing


@pytest.mark.skipif(not os.path.isdir(DRY), reason="dry-run artifacts absent")
def test_artifacts_record_required_fields():
    for p in glob.glob(os.path.join(DRY, "*_pod1.json")):
        m = json.load(open(p))
        for key in ("arg_bytes", "temp_bytes", "peak_gb", "compile_s",
                    "collective_op_counts"):
            assert key in m, (p, key)
        assert m["compile_s"] > 0


@pytest.mark.skipif(not os.path.isdir(DRY), reason="dry-run artifacts absent")
def test_hillclimbed_cells_fit_hbm():
    """The §Perf 'kept' variants restored HBM feasibility."""
    for name in ("olmoe-1b-7b_train_4k_pod1_ep",
                 "deepseek-7b_decode_32k_pod1_f8",
                 "jamba-v0.1-52b_train_4k_pod1_ep",
                 "olmoe-1b-7b_prefill_32k_pod1_ep"):
        m = json.load(open(os.path.join(DRY, name + ".json")))
        assert m["peak_gb"] <= 16.0, (name, m["peak_gb"])
