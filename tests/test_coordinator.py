"""Multi-tenant Coordinator (§3.1.2): tenants run over sub-meshes; the
coordinator aggregates health and scaling maps."""
import jax.numpy as jnp
import numpy as np

from repro.core.coordinator import Coordinator
from repro.core.cloudsim import SimulationConfig, run_simulation
from repro.core.mapreduce import MapReduceEngine, make_corpus, word_count_job


def test_two_tenants_share_pool():
    coord = Coordinator()

    def t1(mesh, ctx):
        r = run_simulation(SimulationConfig(n_vms=8, n_cloudlets=16), mesh)
        return {"makespan": r.makespan}

    def t2(mesh, ctx):
        corpus = jnp.asarray(make_corpus(2, 128, 32))
        out = MapReduceEngine(mesh, backend="infinispan").run(
            word_count_job(32), corpus)
        return {"total": int(np.asarray(out).sum())}

    coord.register("cluster1", t1, n_devices=1)
    coord.register("cluster2", t2, n_devices=1)
    results = coord.run_all()
    assert results["cluster1"]["makespan"] > 0
    assert results["cluster2"]["total"] == 2 * 128
    rep = coord.report()
    assert rep["tenants"] == {"cluster1": "done", "cluster2": "done"}
    assert set(rep["health"]) == {"cluster1", "cluster2"}


def test_health_map_keyed_by_tenant():
    coord = Coordinator()
    coord.register("a", lambda mesh, ctx: {}, n_devices=1)
    coord.run_all()
    assert "wall_s" in coord.health_map["a"]
