"""Elastic re-shard of the distributed scan core (PAPER §4.1.3 / §4.3).

The IntelligentAdaptiveScaler grows and shrinks the member set MID-RUN; the
``PartitionTable``-backed VM→member map re-homes only the moved virtual
partitions; and because ownership is a runtime operand of the compiled
distributed core, finish vectors stay BIT-identical (atol 0) across every
scale event — the thesis's accuracy claim under elasticity.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.partition import (DEFAULT_PARTITION_COUNT, PartitionTable,
                                  key_partition)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_elastic_scale_out_in_equivalence():
    """Scale-out 1→2→4 and scale-in 4→2 mid-run: every simulation's finish
    vector is identical (atol 0) to a single fixed-mesh run."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import dataclasses
import jax, numpy as np
from jax.sharding import Mesh
from repro.core.cloudsim import (ElasticSimulationCluster, SimulationConfig,
                                 run_simulation)
from repro.core.health import HealthConfig

devs = jax.devices()
cfg = SimulationConfig(n_vms=40, n_cloudlets=80, broker="matchmaking",
                       core="scan_dist")
# the oracle: one fixed-mesh single-member scan run
fixed = run_simulation(dataclasses.replace(cfg, core="scan"),
                       Mesh(np.array(devs[:1]), ("data",)))

hc = HealthConfig(target_step_time=1.0, max_threshold=0.8, min_threshold=0.2,
                  time_between_scaling=1, window=1, max_instances=4)
cl = ElasticSimulationCluster(devices=devs, health_cfg=hc, start_members=1)
results = [cl.simulate(cfg)]
member_path = [cl.n_members]
for load, expect in [(2.0, 2), (2.0, 4), (0.05, 2)]:    # out, out, in
    cl.observe_load(load)
    assert cl.n_members == expect, (cl.n_members, expect)
    member_path.append(cl.n_members)
    results.append(cl.simulate(cfg))
assert member_path == [1, 2, 4, 2], member_path

for i, r in enumerate(results):
    assert np.array_equal(fixed.finish_times, r.finish_times), i
    assert fixed.makespan == r.makespan, i

# each scale event re-homed only the minimal number of virtual partitions
# (a member-count doubling/halving moves ~half the table) and retired
# exactly the outgoing mesh's compiled core
for ev in cl.scale_events:
    assert ev["moved_partitions"] <= 271 // 2 + 2, ev
    assert ev["retired_cores"] == 1, ev
# ownership always covers every VM over the live members
owner = np.asarray(cl.vm_owner(40))
assert owner.shape == (40,) and (owner >= 0).all() and (owner < 2).all()
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_invalidate_dist_core_is_targeted():
    """A scale event retires only the outgoing mesh's compiled cores."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.des_scan import (_DIST_CORE_CACHE, invalidate_dist_core,
                                     simulate_completion_distributed)
    from repro.core.executor import DistributedExecutor

    invalidate_dist_core()
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    ex = DistributedExecutor(mesh)
    args = (jnp.zeros(8, jnp.int32), jnp.ones(8), jnp.ones(4),
            jnp.ones(8, bool))
    simulate_completion_distributed(*args, ex)                   # V=4
    simulate_completion_distributed(args[0], args[1], jnp.ones(8),
                                    args[3], ex)                 # V=8
    assert len(_DIST_CORE_CACHE) == 2
    other = Mesh(np.array(jax.devices()[:1]), ("other",))
    assert invalidate_dist_core(other) == 0                      # no match
    assert len(_DIST_CORE_CACHE) == 2
    assert invalidate_dist_core(mesh) == 2                       # targeted
    assert len(_DIST_CORE_CACHE) == 0


def test_grid_remesh_rebuilds_backups():
    """Regression: backups are neighbor-rolled by the OLD shard size; a
    remesh must rebuild them or fail-over restores a stale-offset shard."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.grid import DataGrid
devs = jax.devices()
grid = DataGrid(Mesh(np.array(devs[:4]), ("data",)), backup_count=1)
grid.put("x", jnp.arange(8.0))
grid.remesh(Mesh(np.array(devs[:2]), ("data",)))
restored = grid.restore_from_backup("x", lost_member=0)
assert np.array_equal(np.asarray(restored), np.arange(8.0)), restored
print("OK")
"""], env=env, capture_output=True, text=True, timeout=600)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_rebalance_movement_minimal_randomized():
    """Across random join/leave sequences: every partition owned by a live
    member, load spread ≤ 1, and movement ≤ forced (departed members'
    partitions) + leveling excess (above the balanced floor)."""
    rng = np.random.default_rng(0)
    for _ in range(50):
        pt = PartitionTable(n_instances=int(rng.integers(1, 17)))
        for n_new in rng.integers(1, 17, size=6):
            n_new = int(n_new)
            before = pt.owner.copy()
            counts = np.bincount(before[before < n_new], minlength=n_new)
            forced = int((before >= n_new).sum())
            floor = pt.partition_count // n_new
            excess = int(np.maximum(counts - floor, 0).sum())
            moved = pt.rebalance(n_new)
            load = pt.load()
            assert load.sum() == pt.partition_count
            assert (pt.owner >= 0).all() and (pt.owner < n_new).all()
            assert load.max() - load.min() <= 1
            assert int((pt.owner != before).sum()) <= moved
            assert moved <= forced + excess, (forced, excess, moved)


def test_rebalance_noop_when_stable():
    pt = PartitionTable(n_instances=4)
    assert pt.rebalance(4) == 0
    pt2 = PartitionTable(n_instances=1)
    moved_out = pt2.rebalance(2)
    assert moved_out in (DEFAULT_PARTITION_COUNT // 2,
                         DEFAULT_PARTITION_COUNT // 2 + 1)
    # scaling back: only the second member's partitions re-home
    assert pt2.rebalance(1) == moved_out


def test_key_partition_deterministic_across_processes():
    """Regression: str keys hash via zlib.crc32, so partition tables
    reproduce across processes regardless of PYTHONHASHSEED (Python's salted
    str hash used to re-home every string key between runs)."""
    keys = ["vm-0", "vm-17", "cloudlet-123", "datacenter/3", ""]
    expected = [key_partition(k) for k in keys]
    prog = ("import sys; sys.path.insert(0, %r); "
            "from repro.core.partition import key_partition; "
            "print([key_partition(k) for k in %r])" % (SRC, keys))
    outs = []
    for seed in ("0", "1", "31337"):
        env = dict(os.environ, PYTHONHASHSEED=seed)
        r = subprocess.run([sys.executable, "-c", prog], env=env,
                           capture_output=True, text=True, timeout=120)
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout.strip())
    assert outs[0] == outs[1] == outs[2] == str(expected)
    # int keys stay plain modulo (PartitionUtil semantics)
    assert key_partition(271) == 0 and key_partition(272) == 1
    # bytes and str agree
    assert key_partition(b"vm-17") == key_partition("vm-17")
