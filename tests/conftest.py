import os
import sys

# Tests run on the single real CPU device (the dry-run's 512 fake devices are
# configured ONLY inside launch/dryrun.py / benchmark subprocesses).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
