"""Benchmark-suite smoke: ``benchmarks/run.py --smoke`` runs EVERY benchmark
module at toy sizes (2 emulated devices, no BENCH files written), so the
benchmark scripts can't silently bit-rot while only the library under them
is tested.  A module failure exits non-zero and prints ``<mod>,FAILED``."""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_benchmark_smoke_runs_every_module(tmp_path):
    before = {f: os.path.getmtime(os.path.join(ROOT, f))
              for f in os.listdir(ROOT) if f.startswith("BENCH_")}
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "run.py"),
         "--smoke"],
        capture_output=True, text=True, timeout=1500, cwd=str(tmp_path))
    out = r.stdout
    assert r.returncode == 0, out + r.stderr
    assert "smoke OK" in out, out + r.stderr
    assert ",FAILED," not in out, out
    # every module emitted at least one line (one representative name each)
    for tag in ("t5.1/", "core/", "grid/", "dist/", "f5.1/", "f5.4/",
                "f5.9/", "t5.2/", "model/", "serve/", "queue/", "ckpt/",
                "kernel/"):
        assert tag in out, (tag, out)
    # --smoke must never touch the committed BENCH artifacts
    after = {f: os.path.getmtime(os.path.join(ROOT, f))
             for f in os.listdir(ROOT) if f.startswith("BENCH_")}
    assert before == after
