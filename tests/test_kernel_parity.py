"""v2 kernel parity: ``use_kernel=True`` is BIT-identical to the lax path.

The v2 position-gated kernel replicates ``_segmented_cumsum``'s
Hillis–Steele combine tree exactly (same step set {2^j : 2^j < C}, same
gate ``pos >= d``), so — unlike the tolerance-equivalent v1 matmul kernel
gated in ``test_kernels.py`` — its contract is bitwise equality, asserted
here in three layers:

  1. the kernel primitive vs the lax scan (emulation AND the real Pallas
     kernel under ``force_pallas``), across chunks / dtypes / sizes;
  2. the fused end-to-end scan core (sort + scan + scatter) vs the
     default path, jit-vs-jit (eager-vs-jit differs by pre-existing XLA
     fusion on BOTH paths equally, so like is compared with like);
  3. the distributed/elastic cores with ``use_kernel=True`` across member
     counts and a mid-stream scale event.

Plus the roofline autotuner's guarantees (never slower than the hand-
picked default on the measured harness; trace-time purity) and the
``kernel_path`` provenance satellite.
"""
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compat
from repro.core.des_scan import _segmented_cumsum, simulate_completion_scan_jit
from repro.kernels.seg_scan.v2 import scatter_finish_v2, seg_cumsum_v2
from repro.roofline import autotune

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
CHUNKS = (64, 128, 256)


def _case(rng, C, dtype):
    if np.issubdtype(dtype, np.integer):
        term = jnp.asarray(rng.integers(-50, 50, C).astype(dtype))
    else:
        term = jnp.asarray(rng.uniform(0.0, 5.0, C).astype(dtype))
    start = jnp.asarray(rng.uniform(size=C) < 0.1)
    return term, start


# ------------------------------------------------------- kernel primitive

@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_v2_emulation_bitwise_equals_lax(dtype):
    rng = np.random.default_rng(0)
    lax = jax.jit(_segmented_cumsum)
    for C in (1, 7, 64, 100, 257, 1000, 4096):
        term, start = _case(rng, C, dtype)
        want = np.asarray(lax(term, start))
        for chunk in CHUNKS:
            got = np.asarray(jax.jit(
                lambda t, s, c=chunk: seg_cumsum_v2(t, s, chunk=c,
                                                    interpret=True))(
                term, start))
            assert np.array_equal(want, got), (C, chunk, dtype)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_v2_real_kernel_bitwise_equals_lax(dtype):
    """``force_pallas=True`` runs the ACTUAL kernel body under the Pallas
    interpreter (grid loop, VMEM carry scratch, @pl.when reset) — the same
    program a TPU compiles — and it must match bitwise too."""
    rng = np.random.default_rng(1)
    lax = jax.jit(_segmented_cumsum)
    for C in (64, 100, 257):
        term, start = _case(rng, C, dtype)
        want = np.asarray(lax(term, start))
        for chunk in (64, 128):
            got = np.asarray(seg_cumsum_v2(term, start, chunk=chunk,
                                           force_pallas=True))
            assert np.array_equal(want, got), (C, chunk, dtype)


def test_scatter_finish_v2_bitwise_both_paths():
    rng = np.random.default_rng(2)
    for C in (5, 64, 257, 1000):
        f = jnp.asarray(rng.uniform(0.0, 9.0, C).astype(np.float32))
        order = jnp.asarray(rng.permutation(C).astype(np.int32))
        sent = jnp.asarray(rng.uniform(size=C) < 0.2)
        want = np.zeros(C, np.float32)
        want[np.asarray(order)] = np.where(np.asarray(sent), 0.0,
                                           np.asarray(f))
        for kw in (dict(interpret=True), dict(force_pallas=True)):
            got = np.asarray(scatter_finish_v2(f, order, sent, chunk=64,
                                               **kw))
            assert np.array_equal(want, got), (C, kw)


# ------------------------------------------------- fused end-to-end core

def test_scan_use_kernel_bitwise_equals_default():
    """The full fused path (lax.sort gather + v2 scan + fused scatter) is
    bitwise identical to ``use_kernel=False`` under jit — per chunk AND at
    the autotuned default (kernel_chunk=None)."""
    rng = np.random.default_rng(3)
    for C, V in ((80, 12), (333, 7), (2048, 64)):
        assign = jnp.asarray(rng.integers(0, V, C).astype(np.int32))
        mi = jnp.asarray(rng.uniform(1.0, 200.0, C).astype(np.float32))
        mips = jnp.asarray(rng.uniform(5.0, 20.0, V).astype(np.float32))
        mips = mips.at[0].set(0.0)                 # zero-MIPS padded VM
        valid = jnp.asarray(rng.uniform(size=C) < 0.8)
        f0, m0 = simulate_completion_scan_jit(assign, mi, mips, valid)
        for chunk in (None,) + CHUNKS:
            f1, m1 = simulate_completion_scan_jit(
                assign, mi, mips, valid, use_kernel=True, kernel_chunk=chunk)
            assert np.array_equal(np.asarray(f0), np.asarray(f1)), (C, chunk)
            assert float(m0) == float(m1), (C, chunk)


def test_use_kernel_distributed_bitwise_across_member_counts():
    """scan_dist with use_kernel=True on 1/2/4 members == the kernel-free
    single-device scan, BITWISE — the kernel keeps the elasticity accuracy
    claim intact (the whole point of the position-gated redesign)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import dataclasses
import jax, numpy as np
from jax.sharding import Mesh
from repro.core.cloudsim import SimulationConfig, run_simulation
devs = jax.devices()
cfg = SimulationConfig(n_vms=40, n_cloudlets=80, broker="matchmaking",
                       core="scan_dist", use_kernel=True, kernel_chunk=64)
base = run_simulation(dataclasses.replace(cfg, core="scan",
                                          use_kernel=False),
                      Mesh(np.array(devs[:1]), ("data",)))
for n in (1, 2, 4, 8):
    r = run_simulation(cfg, Mesh(np.array(devs[:n]), ("data",)))
    assert np.array_equal(base.finish_times, r.finish_times), n
    assert base.makespan == r.makespan, n
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_use_kernel_elastic_scale_event_bitwise():
    """A mid-run scale-out (1→2) with use_kernel=True: finish vectors stay
    bit-identical to the fixed-mesh kernel-free run across the event."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import dataclasses
import jax, numpy as np
from jax.sharding import Mesh
from repro.core.cloudsim import (ElasticSimulationCluster, SimulationConfig,
                                 run_simulation)
from repro.core.health import HealthConfig
devs = jax.devices()
cfg = SimulationConfig(n_vms=40, n_cloudlets=80, broker="matchmaking",
                       core="scan_dist", use_kernel=True)
fixed = run_simulation(dataclasses.replace(cfg, core="scan",
                                           use_kernel=False),
                       Mesh(np.array(devs[:1]), ("data",)))
hc = HealthConfig(target_step_time=1.0, max_threshold=0.8, min_threshold=0.2,
                  time_between_scaling=1, window=1, max_instances=2)
cl = ElasticSimulationCluster(devices=devs, health_cfg=hc, start_members=1)
results = [cl.simulate(cfg)]
cl.observe_load(2.0)                                   # scale out 1 -> 2
assert cl.n_members == 2, cl.n_members
results.append(cl.simulate(cfg))
for i, r in enumerate(results):
    assert np.array_equal(fixed.finish_times, r.finish_times), i
    assert fixed.makespan == r.makespan, i
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


# ------------------------------- deterministic-sum FMA fence (regression)

def test_deterministic_bare_product_bitwise_across_member_counts():
    """Regression for the M=1 FMA-fusion caveat: a deterministic sum job
    whose member_fn is a BARE product used to differ at M=1 because XLA
    fused ``xs * ws`` into the row reduction as an FMA (single executable)
    while M>1's exchange boundary kept them separate.  The row/tree split
    now compiles the tree in its own executable, so the bare product is
    bit-identical across member counts."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import numpy as np
from repro.core.dispatch import DispatchJob, ElasticDispatcher
rng = np.random.RandomState(0)
x = (rng.randn(24, 5) * 10 ** rng.uniform(-3, 3, (24, 5))).astype(np.float32)
w = (rng.randn(5) * 10 ** rng.uniform(-2, 2, 5)).astype(np.float32)
job = DispatchJob(name="prod", signature="prod", reduce="sum",
                  deterministic=True, member_fn=lambda xs, v, ws: xs * ws)
outs = []
for n in (1, 2, 4):
    d = ElasticDispatcher(start_members=n)
    out, _ = d.submit(job, x, replicated=(w,), chunk=4)
    outs.append(np.asarray(out))
assert np.array_equal(outs[0], outs[1]), "M=1 vs M=2"
assert np.array_equal(outs[0], outs[2]), "M=1 vs M=4"
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


# ----------------------------------------------------- roofline autotuner

def test_candidate_chunks_clamped_and_default_present():
    assert autotune.candidate_chunks(32) == (32,)       # clamped default
    cands = autotune.candidate_chunks(1 << 20)
    assert autotune.DEFAULT_CHUNK in cands
    assert all(c & (c - 1) == 0 for c in cands)
    assert min(cands) >= 64 and max(cands) <= 1024


def test_analytic_ranking_models_both_kernels():
    # v2 is memory-bound at 1M: bigger L -> fewer tail passes -> wins
    v2 = autotune.rank_chunks(1 << 20, kind="v2", backend="cpu")
    assert v2[0].chunk == max(s.chunk for s in v2)
    assert v2[0].bottleneck == "memory"
    # v1's masked matmul makes FLOPs grow with L: smallest chunk wins
    v1 = autotune.rank_chunks(1 << 20, kind="v1", backend="cpu")
    assert v1[0].chunk == min(s.chunk for s in v1)
    # the measured HLO anchor parses real compiled traffic (the add-only
    # scan has no dot ops, so only HBM bytes are nonzero — memory-bound)
    costs = autotune.lax_scan_costs(1 << 20)
    assert costs.hbm_bytes > 0
    small = autotune.lax_scan_costs(1 << 12)
    assert costs.hbm_bytes > small.hbm_bytes    # element·step extrapolation


def test_tuned_chunk_never_slower_than_default():
    """With measure=True the hand-picked default is ALWAYS in the measured
    set, so the returned chunk's measured time <= the default's."""
    fake = {64: 3e-3, 128: 2e-3, 256: 1e-3, 512: 4e-3, 1024: 5e-3}
    got = autotune.tuned_chunk(1 << 20, backend="fake-a", measure=True,
                               bench=lambda c: fake[c], top_k=2)
    choice = autotune.tuning_report(1 << 20, backend="fake-a")
    assert choice.source == "measured"
    assert autotune.DEFAULT_CHUNK in choice.measured_s
    assert choice.measured_s[got] <= choice.measured_s[autotune.DEFAULT_CHUNK]
    # when the default measures fastest, it IS the answer (ties included)
    got2 = autotune.tuned_chunk(1 << 19, backend="fake-b", measure=True,
                                bench=lambda c: 1e-3 if c == 128 else 9e-3)
    assert got2 == autotune.DEFAULT_CHUNK


def test_tuned_chunk_trace_time_purity_and_cache():
    """measure=False never benches (a poisoned bench proves it) and the
    measured choice persists per (backend, kind, pow2 bucket)."""
    def boom(c):
        raise AssertionError("measure=False must not bench")

    got = autotune.tuned_chunk(1 << 18, backend="fake-c", bench=boom)
    assert got == autotune.rank_chunks(1 << 18, backend="fake-c")[0].chunk
    autotune.tuned_chunk(1 << 18, backend="fake-c", measure=True,
                         bench=lambda c: {64: 9, 128: 9}.get(c, 1e-4))
    # cache hit: measured choice now wins even with a poisoned bench
    again = autotune.tuned_chunk(1 << 18, backend="fake-c", bench=boom,
                                 measure=True)
    assert again == autotune.tuning_report(1 << 18, backend="fake-c").chunk
    # same bucket, different size -> same cached entry
    assert autotune.tuned_chunk((1 << 18) - 3, backend="fake-c",
                                bench=boom) == again


def test_tuned_exchange_block_bounds():
    for C, M in ((100_000, 8), (4096, 4), (64, 16), (1, 1), (7, 32)):
        b = autotune.tuned_exchange_block(C, M)
        assert 1 <= b <= max(C // max(M, 1), 1), (C, M, b)
        assert b & (b - 1) == 0, (C, M, b)
    # the roofline view of an exchange returns finite positive seconds
    t, bottleneck = autotune.exchange_roofline(100_000, 8, 2048)
    assert t > 0 and bottleneck in ("compute", "memory", "collective")


# ------------------------------------------------- kernel_path provenance

def test_kernel_path_resolution():
    assert compat.kernel_path(False) is None
    assert compat.kernel_path(True, interpret=True) == "interpret"
    assert compat.kernel_path(True, interpret=False) == "compiled"
    on_cpu = "interpret" if jax.default_backend() != "tpu" else "compiled"
    assert compat.kernel_path(True) == on_cpu


def test_interpret_fallback_warns_exactly_once(monkeypatch):
    monkeypatch.setattr(compat, "_warned_interpret_fallback", False)
    if jax.default_backend() == "tpu":
        pytest.skip("fallback warning only fires off-TPU")
    with pytest.warns(compat.KernelInterpretFallbackWarning):
        assert compat.resolve_kernel_interpret(None) is True
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # second call must be silent
        assert compat.resolve_kernel_interpret(None) is True
        # explicit interpret is a deliberate choice: never warns
        monkeypatch.setattr(compat, "_warned_interpret_fallback", False)
        assert compat.resolve_kernel_interpret(True) is True
        assert compat.resolve_kernel_interpret(False) is False


def test_dispatch_report_records_kernel_path():
    from repro.core.cloudsim import SimulationConfig
    from repro.core.des_scan import run_simulation_batch, scenario_grid_job
    from repro.core.dispatch import ElasticDispatcher

    cfg = SimulationConfig(n_vms=8, n_cloudlets=16, use_kernel=True)
    expect = "interpret" if jax.default_backend() != "tpu" else "compiled"
    assert scenario_grid_job(cfg).kernel_path == expect
    r = run_simulation_batch(cfg, np.arange(4),
                             dispatcher=ElasticDispatcher(start_members=1),
                             chunk=2)
    assert r.dispatch["kernel_path"] == expect
    # the lax path records None — no kernel involved
    lax_cfg = SimulationConfig(n_vms=8, n_cloudlets=16)
    assert scenario_grid_job(lax_cfg).kernel_path is None
    r2 = run_simulation_batch(lax_cfg, np.arange(4),
                              dispatcher=ElasticDispatcher(start_members=1),
                              chunk=2)
    assert r2.dispatch["kernel_path"] is None
