"""Arch registry: all 10 assigned architectures with verified parameter counts."""
import pytest

from repro.configs import get_config, list_archs, SHAPES

EXPECTED = {
    "smollm-360m": (0.30e9, 0.45e9),
    "gemma3-4b": (3.5e9, 4.4e9),
    "llama3-8b": (7.5e9, 8.5e9),
    "deepseek-7b": (6.5e9, 7.3e9),
    "olmoe-1b-7b": (6.5e9, 7.3e9),
    "grok-1-314b": (300e9, 330e9),
    "llava-next-mistral-7b": (6.9e9, 7.6e9),
    "seamless-m4t-medium": (0.55e9, 0.9e9),
    "jamba-v0.1-52b": (49e9, 54e9),
    "mamba2-370m": (0.33e9, 0.42e9),
}

ACTIVE = {"olmoe-1b-7b": (1.0e9, 1.6e9), "grok-1-314b": (70e9, 90e9),
          "jamba-v0.1-52b": (10e9, 14e9)}


def test_all_archs_registered():
    assert sorted(list_archs()) == sorted(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_param_counts(arch):
    lo, hi = EXPECTED[arch]
    n = get_config(arch).param_count()
    assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B outside [{lo},{hi}]"


@pytest.mark.parametrize("arch", sorted(ACTIVE))
def test_active_params(arch):
    lo, hi = ACTIVE[arch]
    n = get_config(arch).active_param_count()
    assert lo <= n <= hi


def test_long_context_applicability():
    long_ok = {a for a in list_archs()
               if any(s.name == "long_500k" for s in get_config(a).shapes())}
    assert long_ok == {"gemma3-4b", "jamba-v0.1-52b", "mamba2-370m"}


def test_padded_vocab_shards():
    for a in list_archs():
        assert get_config(a).padded_vocab % 256 == 0


def test_cell_count():
    """The assignment's 40 (arch x shape) cells = 33 lowered + 7 documented skips."""
    cells = sum(len(get_config(a).shapes()) for a in list_archs())
    skips = sum(len(get_config(a).skipped_shapes()) for a in list_archs())
    assert cells == 33 and skips == 7 and cells + skips == 40
