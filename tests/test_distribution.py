"""Distribution-layer tests that need multiple devices / the 512-device
dry-run path — run in subprocesses so the test session keeps 1 device."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code, devices=4, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_des_identical_across_member_counts():
    r = run_py("""
import jax, numpy as np
from jax.sharding import Mesh
from repro.core.cloudsim import SimulationConfig, run_simulation
devs = jax.devices()
for broker in ("round_robin", "matchmaking"):
    cfg = SimulationConfig(n_vms=40, n_cloudlets=80, broker=broker, is_loaded=True,
                           workload_iters_per_gmi=0.05)
    r1 = run_simulation(cfg, Mesh(np.array(devs[:1]), ("data",)))
    r4 = run_simulation(cfg, Mesh(np.array(devs), ("data",)))
    assert np.array_equal(r1.vm_assign, r4.vm_assign), broker
    np.testing.assert_allclose(r1.finish_times, r4.finish_times, rtol=1e-5)
    np.testing.assert_allclose(r1.workload_checksum, r4.workload_checksum, rtol=1e-4)
print("OK")
""")
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_mapreduce_backends_agree_distributed():
    r = run_py("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.mapreduce import MapReduceEngine, make_corpus, word_count_job
mesh = Mesh(np.array(jax.devices()), ("data",))
corpus = make_corpus(8, 512, vocab=64)
expected = np.bincount(corpus.reshape(-1), minlength=64)
for backend in ("hazelcast", "infinispan"):
    out = MapReduceEngine(mesh, backend=backend).run(word_count_job(64),
                                                     jnp.asarray(corpus))
    assert np.array_equal(np.asarray(out), expected), backend
print("OK")
""")
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_moe_ep_matches_oracle_on_mesh():
    r = run_py("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_config, reduced
from repro.models import moe as moe_mod
from repro.models.shard_ctx import sharding_rules
from repro.models.param import init_params
from repro.core.compat import AXIS_TYPE_AUTO, make_mesh
mesh = make_mesh((2,2), ("data","model"),
                 axis_types=(AXIS_TYPE_AUTO,)*2)
cfg = dataclasses.replace(reduced(get_config("olmoe-1b-7b"), n_experts=4,
                                  d_ff_expert=64, d_model=64),
                          capacity_factor=8.0)
params = init_params(moe_mod.moe_defs(cfg), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64), jnp.float32)
ref = moe_mod.moe_block(params, x, cfg, compute_dtype=jnp.float32, moe_impl="dense")
with sharding_rules(cfg.policy, mesh, **{"exp": "model", "moe_ff": None}):
    ep = jax.jit(lambda p, xx: moe_mod.moe_block(
        p, xx, cfg, compute_dtype=jnp.float32, moe_impl="ep"))(params, x)
np.testing.assert_allclose(np.asarray(ep), np.asarray(ref), atol=2e-4, rtol=2e-3)
print("OK")
""")
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_ring_reduce_scatter_distributed():
    r = run_py("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.train.compression import ring_reduce_scatter
mesh = Mesh(np.array(jax.devices()), ("data",))
n, k = 4, 8
x = jnp.arange(n * n * k, dtype=jnp.float32).reshape(n, n * k)
out = ring_reduce_scatter(x, mesh)
np.testing.assert_allclose(np.asarray(out), np.asarray(x.sum(0).reshape(n, k)))
print("OK")
""")
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_dryrun_cell_compiles_on_512_devices():
    """End-to-end dry-run contract for one cheap cell (the full 66-cell sweep
    artifacts live in experiments/dryrun; see EXPERIMENTS.md §Dry-run)."""
    r = run_py("""
from repro.launch.dryrun import run_cell
from repro.launch import mesh as mesh_lib
mesh = mesh_lib.make_production_mesh(multi_pod=True)
meta = run_cell("mamba2-370m", "long_500k", mesh, "pod2", out_dir=None)
assert meta["peak_gb"] < 16.0, meta
print("OK", meta["peak_gb"])
""", devices=512, timeout=1200)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_elastic_remesh_across_devices():
    r = run_py("""
import jax
from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.core.health import HealthConfig
from repro.data.pipeline import DataConfig
from repro.train.elastic_runner import run_elastic_training
cfg = reduced(get_config("smollm-360m"), n_layers=2, d_model=32, n_heads=2,
              n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64)
model = build_model(cfg, remat=False, xent_chunk=8)
rep = run_elastic_training(
    model, steps=16, data_cfg=DataConfig(64, 16, 8), start_instances=1,
    health_cfg=HealthConfig(target_step_time=1e6, min_threshold=-1,
                            time_between_scaling=4, window=2,
                            max_threshold=0.0))   # load always 'high' -> scale out
assert rep.scale_events, rep
assert rep.final_n_instances > 1
print("OK", rep.scale_events)
""")
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_distributed_flash_decode_matches_unsharded():
    """Sequence-sharded KV decode (the long_500k SP path): softmax over the
    sharded KV axis must equal the unsharded computation."""
    r = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.models.attention import _chunked_attn

from repro.core.compat import AXIS_TYPE_AUTO, make_mesh
mesh = make_mesh((4,), ("data",), axis_types=(AXIS_TYPE_AUTO,))
B, S, H, hd = 1, 256, 2, 16
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)

ref = _chunked_attn(q, k, v, causal=False, window=0, q_offset=0,
                    kv_len=jnp.int32(200), q_chunk=1)

kv_sh = NamedSharding(mesh, P(None, "data", None, None))
k_s = jax.device_put(k, kv_sh)
v_s = jax.device_put(v, kv_sh)
out = jax.jit(lambda q_, k_, v_, n: _chunked_attn(
    q_, k_, v_, causal=False, window=0, q_offset=0, kv_len=n, q_chunk=1),
    in_shardings=(NamedSharding(mesh, P()), kv_sh, kv_sh,
                  NamedSharding(mesh, P())))(q, k_s, v_s, jnp.int32(200))
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                           rtol=1e-5)
# the compiled module must actually reduce over the sharded axis
txt = jax.jit(lambda q_, k_, v_, n: _chunked_attn(
    q_, k_, v_, causal=False, window=0, q_offset=0, kv_len=n, q_chunk=1),
    in_shardings=(NamedSharding(mesh, P()), kv_sh, kv_sh,
                  NamedSharding(mesh, P()))).lower(
        q, k_s, v_s, jnp.int32(200)).compile().as_text()
assert ("all-reduce" in txt) or ("all-gather" in txt)
print("OK")
""")
    assert "OK" in r.stdout, r.stdout + r.stderr
