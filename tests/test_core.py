"""Core middleware: DES determinism, MapReduce backends, partitioning, grid
backups, elastic scaling, speedup model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.cloudsim import (SimulationConfig, run_simulation,
                                 matchmaking_assign, simulate_completion)
from repro.core.elastic import Decision, ElasticController
from repro.core.grid import DataGrid
from repro.core.health import HealthConfig, HealthSample
from repro.core.mapreduce import MapReduceEngine, make_corpus, word_count_job
from repro.core.partition import (PartitionTable, get_partition_final,
                                  get_partition_init, partition_ranges)
from repro.core.speedup import SpeedupModel, model_from_roofline


def mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


@pytest.mark.parametrize("broker", ["round_robin", "matchmaking"])
def test_des_runs_and_is_deterministic(broker):
    cfg = SimulationConfig(n_vms=20, n_cloudlets=40, broker=broker)
    r1 = run_simulation(cfg, mesh1())
    r2 = run_simulation(cfg, mesh1())
    assert np.array_equal(r1.vm_assign, r2.vm_assign)
    np.testing.assert_allclose(r1.finish_times, r2.finish_times)
    assert r1.makespan > 0


def test_matchmaking_respects_requirements():
    cfg = SimulationConfig(n_vms=16, n_cloudlets=64, broker="matchmaking")
    r = run_simulation(cfg, mesh1())
    # every assigned VM id must be a valid VM
    assert (r.vm_assign[:64] < 16).all() and (r.vm_assign[:64] >= 0).all()
    # fairness: no VM monopolized (each adequate VM gets a bounded share)
    counts = np.bincount(r.vm_assign[:64], minlength=16)
    assert counts.max() <= 64  # sanity
    assert (counts > 0).sum() >= 4  # spread over multiple VMs


def test_time_shared_completion_waves():
    # two cloudlets of equal length on one VM finish together at 2x serial time
    finish, makespan = jax.jit(simulate_completion)(
        jnp.array([0, 0], jnp.int32), jnp.array([100.0, 100.0]),
        jnp.array([10.0]), jnp.array([True, True]))
    np.testing.assert_allclose(np.asarray(finish), [20.0, 20.0], rtol=1e-5)
    # a shorter cloudlet frees capacity: 100 and 200 MI on 10 MIPS
    finish, _ = jax.jit(simulate_completion)(
        jnp.array([0, 0], jnp.int32), jnp.array([100.0, 200.0]),
        jnp.array([10.0]), jnp.array([True, True]))
    np.testing.assert_allclose(np.asarray(finish), [20.0, 30.0], rtol=1e-5)


@pytest.mark.parametrize("backend", ["hazelcast", "infinispan"])
def test_mapreduce_word_count(backend):
    corpus = make_corpus(4, 256, vocab=32)
    eng = MapReduceEngine(mesh1(), backend=backend)
    out = eng.run(word_count_job(32), jnp.asarray(corpus))
    np.testing.assert_array_equal(
        np.asarray(out), np.bincount(corpus.reshape(-1), minlength=32))


def test_mapreduce_kernel_backend():
    corpus = make_corpus(2, 256, vocab=64)
    eng = MapReduceEngine(mesh1(), backend="hazelcast")
    out = eng.run(word_count_job(64, use_kernel=True), jnp.asarray(corpus))
    np.testing.assert_array_equal(
        np.asarray(out), np.bincount(corpus.reshape(-1), minlength=64))


def test_partition_util_paper_semantics():
    # the thesis's getPartitionInit/Final worked example
    assert partition_ranges(10, 4) == [(0, 3), (3, 6), (6, 9), (9, 10)]
    assert get_partition_init(271, 0, 4) == 0
    assert get_partition_final(271, 3, 4) == 271


def test_partition_table_minimal_movement():
    pt = PartitionTable(n_instances=4)
    moved = pt.rebalance(5)
    assert moved <= 271 // 5 + 2
    load = pt.load()
    assert load.max() - load.min() <= 1


def test_grid_backup_restore():
    grid = DataGrid(mesh1(), backup_count=1)
    v = grid.put("x", jnp.arange(16.0))
    restored = grid.restore_from_backup("x", lost_member=0)
    np.testing.assert_array_equal(np.asarray(restored), np.arange(16.0))


def test_grid_binary_format_is_bf16():
    grid = DataGrid(mesh1())
    v = grid.put("b", jnp.ones((4,), jnp.float32), in_memory_format="BINARY")
    assert v.dtype == jnp.bfloat16


def test_elastic_hysteresis_and_bounds():
    cfg = HealthConfig(target_step_time=1.0, time_between_scaling=3, window=2,
                       max_instances=8)
    ctl = ElasticController(cfg, n_instances=2)
    decisions = [int(ctl.on_step(HealthSample(step=i, step_time=2.0, loss=1.0,
                                              grad_norm=1.0)))
                 for i in range(12)]
    outs = [i for i, d in enumerate(decisions) if d == 1]
    assert outs and all(b - a >= 3 for a, b in zip(outs, outs[1:]))
    assert ctl.n_instances <= 8


def test_elastic_scale_in_on_low_load():
    cfg = HealthConfig(target_step_time=1.0, time_between_scaling=2, window=2,
                       min_threshold=0.5)
    ctl = ElasticController(cfg, n_instances=4)
    for i in range(8):
        ctl.on_step(HealthSample(step=i, step_time=0.1, loss=1.0, grad_norm=1.0))
    assert ctl.n_instances < 4


def test_speedup_model_regimes():
    # §5.1.1's four cases emerge from the term balance
    pos = SpeedupModel(t1=1000.0, k=0.999, c_per_n=0.1, fixed=1.0)
    assert pos.regime([1, 2, 3, 4, 5, 6]) == "positive"
    neg = SpeedupModel(t1=4.0, k=0.2, c_per_n=1.0, fixed=1.0)
    assert neg.regime([1, 2, 3, 4, 5, 6]) == "negative"
    common = SpeedupModel(t1=100.0, k=0.98, c_per_n=4.0, fixed=1.0)
    assert common.regime([1, 2, 3, 4, 5, 6]) == "positive-then-negative"


def test_speedup_model_identities():
    m = SpeedupModel(t1=100.0, k=0.9, c_per_n=0.5)
    n = 4
    s = m.speedup(n)
    assert abs(m.efficiency(n) - s / n) < 1e-12
    assert abs(m.improvement_pct(n) - (1 - 1 / s) * 100) < 1e-9


def test_model_from_roofline_theta():
    m = model_from_roofline(100.0, 0.95, coll_bytes_per_step=1e9,
                            working_set_bytes=64 * 2 ** 30)
    # once 8 nodes provide 128GiB, theta kicks in
    assert m.t_n(8, 8) < m.t_n(8, 2)


def test_executor_reduce_kinds():
    from repro.core.executor import DistributedExecutor
    import jax.numpy as jnp
    ex = DistributedExecutor(mesh1())
    data = jnp.arange(8.0)
    assert float(ex.map_reduce(lambda d: d.sum(), "sum", data)) == 28.0
    assert float(ex.map_reduce(lambda d: d.max(), "max", data)) == 7.0
    cat = ex.map_reduce(lambda d: d * 2, "concat", data)
    np.testing.assert_array_equal(np.asarray(cat), np.arange(8.0) * 2)


def test_health_straggler_skew():
    from repro.core.health import HealthConfig, HealthMonitor, HealthSample
    mon = HealthMonitor(HealthConfig())
    mon.observe(HealthSample(step=0, step_time=1.0, loss=1.0, grad_norm=1.0,
                             member_times=[1.0, 1.0, 1.0, 3.0]))
    assert mon.straggler_skew() == 3.0
    mon.observe(HealthSample(step=1, step_time=1.0, loss=float("nan"),
                             grad_norm=1.0))
    assert not mon.is_healthy()
    assert any("NON-FINITE" in e for e in mon.events)
