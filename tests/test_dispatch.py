"""ElasticDispatcher — the unified remesh-aware, chunk-streaming job layer.

Acceptance contract of the middleware refactor:

  * a scenario grid and a MapReduce word-count job submitted through the
    dispatcher survive a mid-stream scale-out 1→2→4 and scale-in 4→2 with
    results BIT-identical to a single-member run;
  * a grid with more variants than one dispatch chunk streams in ≥2 chunks
    with at most ONE compile per (geometry, job-signature) — verified via
    the CompileCache hit/build counters;
  * the elastic simulation cluster is a thin client of the dispatcher;
  * ``PartitionTable.rebalance`` with observed per-key weights spreads a hot
    key's partition load across members (locality-aware rebalance seed).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.dispatch import (CompileCache, DispatchJob, ElasticDispatcher,
                                 NonPow2ChunkWarning)
from repro.core.partition import (DEFAULT_PARTITION_COUNT, PartitionTable,
                                  partition_weights_from_keys)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ----------------------------------------------------------- CompileCache

def test_compile_cache_lru_and_counters():
    c = CompileCache(max_entries=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1                  # hit moves "a" to the back
    c.put("c", 3)                           # evicts "b" (LRU front)
    assert "b" not in c and "a" in c and "c" in c
    assert c.get("b") is None               # miss
    assert c.stats() == {"size": 2, "hits": 1, "misses": 1, "builds": 3}
    # dict-style peeking doesn't disturb recency or counters
    assert c["a"] == 1 and len(c) == 2 and set(c) == {"a", "c"}
    assert c.stats()["hits"] == 1
    built = []
    v = c.get_or_build("a", lambda: built.append(1) or 99)
    assert v == 1 and not built             # cached: builder never ran
    v = c.get_or_build("d", lambda: 42)
    assert v == 42 and c["d"] == 42


def test_compile_cache_invalidate_by_predicate():
    c = CompileCache()
    c.put(("m1", "x"), 1)
    c.put(("m1", "y"), 2)
    c.put(("m2", "x"), 3)
    assert c.invalidate(lambda k: k[0] == "m1") == 2
    assert set(c) == {("m2", "x")}
    assert c.invalidate() == 1 and len(c) == 0


def test_dispatch_job_validation():
    with pytest.raises(ValueError):
        DispatchJob(name="x", signature="x")              # no fn
    with pytest.raises(ValueError):
        DispatchJob(name="x", signature="x", member_fn=lambda *a: a,
                    global_fn=lambda *a: a)               # both fns
    with pytest.raises(ValueError):
        DispatchJob(name="x", signature="x", member_fn=lambda *a: a,
                    reduce="median")


# ------------------------------------------------- chunk-streamed submission

def test_grid_streams_chunks_with_one_compile():
    """≥2 chunks through one geometry: exactly ONE executable built, every
    later chunk a cache hit; a re-submit is all hits — the cache-hit-counter
    acceptance criterion on a single member."""
    from repro.core.cloudsim import SimulationConfig
    from repro.core.des_scan import make_scenario_grid, run_scenario_grid

    cfg = SimulationConfig(n_vms=8, n_cloudlets=32)
    grid = make_scenario_grid(seeds=range(10), mi_scales=[0.5, 2.0])
    B = len(grid["seeds"])
    ref = run_scenario_grid(cfg, grid)

    d = ElasticDispatcher(start_members=1)
    r = run_scenario_grid(cfg, grid, dispatcher=d, chunk=6)
    assert r.dispatch["n_chunks"] == -(-B // 6) >= 2
    assert r.dispatch["compiles"] == 1
    assert r.dispatch["cache_hits"] == r.dispatch["n_chunks"] - 1
    np.testing.assert_array_equal(ref.finish_times, r.finish_times)
    np.testing.assert_array_equal(ref.makespans, r.makespans)

    r2 = run_scenario_grid(cfg, grid, dispatcher=d, chunk=6)
    assert r2.dispatch["compiles"] == 0
    assert r2.dispatch["cache_hits"] == r2.dispatch["n_chunks"]
    np.testing.assert_array_equal(ref.makespans, r2.makespans)


def test_submit_validates_items():
    d = ElasticDispatcher(start_members=1)
    job = DispatchJob(name="j", signature="j",
                      member_fn=lambda x, v, *_: x, reduce="concat")
    with pytest.raises(ValueError):
        d.submit(job, ())
    with pytest.raises(ValueError):
        d.submit(job, (np.zeros(4), np.zeros(5)))   # ragged leading dims


def test_submit_empty_batch():
    """B = 0 must behave like the non-dispatcher vmap path: empty concat
    outputs with the right trailing shape, identity (zeros) sum outputs —
    one fully-padded all-invalid chunk, never a crash."""
    import jax.numpy as jnp

    d = ElasticDispatcher(start_members=1)
    job = DispatchJob(name="rows", signature="rows",
                      member_fn=lambda x, v, *_: x * 2.0, reduce="concat")
    out, rep = d.submit(job, np.zeros((0, 3), np.float32))
    assert out.shape == (0, 3) and rep.n_chunks == 1

    sum_job = DispatchJob(
        name="hist", signature="hist", reduce="sum",
        member_fn=lambda x, v, *_: jnp.where(v[:, None], x, 0).sum(axis=0))
    out, _ = d.submit(sum_job, np.ones((0, 5), np.int32))
    assert out.shape == (5,) and (np.asarray(out) == 0).all()

    # the dispatcher-routed grid matches the vmap path on an empty seed set
    from repro.core.cloudsim import SimulationConfig
    from repro.core.des_scan import run_simulation_batch
    cfg = SimulationConfig(n_vms=8, n_cloudlets=16)
    r = run_simulation_batch(cfg, np.zeros((0,), np.int32), dispatcher=d)
    assert r.finish_times.shape == (0, 16) and r.makespans.shape == (0,)


def test_grid_and_mapreduce_survive_scale_events():
    """THE acceptance test: scenario grid + MapReduce word count streamed
    through one dispatcher, IAS firing 1→2→4→2 between chunks, results
    bit-identical to the single-member run; compile counters show one
    executable per (geometry, job-signature)."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import numpy as np, jax, jax.numpy as jnp
from repro.core.dispatch import ElasticDispatcher
from repro.core.cloudsim import SimulationConfig
from repro.core.des_scan import make_scenario_grid, run_scenario_grid
from repro.core.health import HealthConfig
from repro.core.mapreduce import MapReduceEngine, make_corpus, word_count_job

hc = HealthConfig(target_step_time=1.0, max_threshold=0.8, min_threshold=0.2,
                  time_between_scaling=1, window=1, max_instances=4)
cfg = SimulationConfig(n_vms=12, n_cloudlets=48, broker="matchmaking")
grid = make_scenario_grid(seeds=range(6), mi_scales=[0.7, 1.3],
                          vm_counts=[6, 12])
B = len(grid["seeds"])
ref = run_scenario_grid(cfg, grid)                 # single-member oracle

def loads_feeder(seq):
    it = iter(seq)
    def on_chunk(disp, ci, n):
        l = next(it, None)
        if l is not None:
            disp.observe_load(l)
    return on_chunk

d = ElasticDispatcher(health_cfg=hc, start_members=1)
r = run_scenario_grid(cfg, grid, dispatcher=d, chunk=6,
                      on_chunk=loads_feeder([2.0, 2.0, 0.05]))
assert r.dispatch["members_per_chunk"] == [1, 2, 4, 2], r.dispatch
assert r.dispatch["n_chunks"] == 4 and r.dispatch["scale_events"] == 3
# bit-identical across the whole scale path
assert np.array_equal(ref.finish_times, r.finish_times)
assert np.array_equal(ref.makespans, r.makespans)
assert np.array_equal(ref.vm_assign, r.vm_assign)
# one compile per geometry visited (2-member mesh was retired at 2->4 and
# recompiled on the way back down: 1, 2, 4, 2 -> 4 builds, 0 hits)
assert r.dispatch["compiles"] == 4, r.dispatch
# each scale event retired the old geometry's grid-job executable
assert [ev["retired_jobs"] for ev in d.scale_events] == [1, 1, 1]

# stay at 2 members, stream again: chunk 3 of the first stream already
# rebuilt the 2-member executable (after 4->2), so this is ALL cache hits
r2 = run_scenario_grid(cfg, grid, dispatcher=d, chunk=6)
assert r2.dispatch["members_per_chunk"] == [2, 2, 2, 2]
assert r2.dispatch["compiles"] == 0 and r2.dispatch["cache_hits"] == 4
assert np.array_equal(ref.makespans, r2.makespans)

# ---- MapReduce word count through the SAME middleware, same scale path
d2 = ElasticDispatcher(health_cfg=hc, start_members=1)
corpus = make_corpus(10, 512, vocab=64)
expected = np.bincount(corpus.reshape(-1), minlength=64)
for backend in ("hazelcast", "infinispan"):
    eng = MapReduceEngine(backend=backend, dispatcher=ElasticDispatcher(
        health_cfg=hc, start_members=1))
    out = eng.run(word_count_job(64), jnp.asarray(corpus), chunk=3,
                  on_chunk=loads_feeder([2.0, 2.0, 0.05]))
    rep = eng.last_report
    assert rep.members_per_chunk == [1, 2, 4, 2], (backend, rep)
    assert np.array_equal(np.asarray(out), expected), backend

# DataGrid entries with a leading dim the new member count can't divide are
# downgraded to replicated placement instead of failing the scale event —
# and re-sharded automatically once a later remesh fits them again
from repro.core.grid import DataGrid
d3 = ElasticDispatcher(health_cfg=hc, start_members=2)
g = d3.ensure_grid()
g.put("odd", jnp.arange(6.0))                      # 6 % 4 != 0
sharded_spec = g.spec("odd")
d3.observe_load(2.0)                               # 2 -> 4 members
assert d3.n_members == 4
assert np.array_equal(np.asarray(g.get("odd")), np.arange(6.0))
assert "odd" in g.downgraded
d3.observe_load(0.05)                              # 4 -> 2: fits again
assert d3.n_members == 2
assert "odd" not in g.downgraded
assert g.spec("odd") == sharded_spec               # sharding restored
assert np.array_equal(np.asarray(g.get("odd")), np.arange(6.0))
# a put() AFTER a downgrade is authoritative: the stale record must not
# resurrect the old sharded spec on the next remesh
from jax.sharding import PartitionSpec as P
d3.observe_load(2.0)                               # 2 -> 4: downgrade again
assert "odd" in g.downgraded
g.put("odd", jnp.arange(8.0), spec=P())            # caller wants REPLICATED
d3.observe_load(0.05)                              # 4 -> 2
assert g.spec("odd") == P(), g.spec("odd")
# fail-over after a downgrade remesh: the entry's backup is the DEGENERATE
# (full replicated) copy — restore must NOT unroll it as if neighbor-rolled
from jax.sharding import Mesh
g2 = DataGrid(Mesh(np.array(jax.devices()[:2]), ("data",)), backup_count=1)
g2.put("six", jnp.arange(6.0))                     # 6 % 2 == 0: rolled
g2.remesh(Mesh(np.array(jax.devices()[:4]), ("data",)))  # 6 % 4: downgrade
assert "six" in g2.downgraded
restored = g2.restore_from_backup("six", lost_member=0)
assert np.array_equal(np.asarray(restored), np.arange(6.0)), restored
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_async_stream_bit_identical_with_chunks_in_flight():
    """Satellite acceptance: the async double-buffered stream survives
    1→2→4→2 scale events with ≥2 chunks IN FLIGHT at every remesh barrier,
    bit-identical to the synchronous baseline AND the no-dispatcher oracle;
    the deterministic float MapReduce job holds bit-identity over the same
    scale path; and auto_scale's EMA feeding scales out with no on_chunk
    feeder."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.core.dispatch import ElasticDispatcher
from repro.core.cloudsim import SimulationConfig
from repro.core.des_scan import make_scenario_grid, run_scenario_grid
from repro.core.health import HealthConfig
from repro.core.mapreduce import MapReduceEngine, make_corpus, word_weight_job

hc = HealthConfig(target_step_time=1.0, max_threshold=0.8, min_threshold=0.2,
                  time_between_scaling=1, window=1, max_instances=4)
cfg = SimulationConfig(n_vms=12, n_cloudlets=48, broker="matchmaking")
grid = make_scenario_grid(seeds=range(6), mi_scales=[0.7, 1.3],
                          vm_counts=[6, 12])           # B = 24, 6 chunks of 4
ref = run_scenario_grid(cfg, grid)                     # no-dispatcher oracle

def loads_feeder(seq):
    it = iter(seq)
    def on_chunk(disp, ci, n):
        l = next(it, None)
        if l is not None:
            disp.observe_load(l)
    return on_chunk

LOADS = [0.5, 2.0, 0.5, 2.0, 0.5, 0.05]                # events at ci 1, 3, 5
runs = {}
for label, ahead in (("async", 2), ("sync", 0)):
    d = ElasticDispatcher(health_cfg=hc, start_members=1,
                          dispatch_ahead=ahead)
    r = run_scenario_grid(cfg, grid, dispatcher=d, chunk=4,
                          on_chunk=loads_feeder(LOADS))
    assert r.dispatch["members_per_chunk"] == [1, 1, 2, 2, 4, 4], (label, r.dispatch)
    assert r.dispatch["scale_events"] == 3
    drained = [ev["drained_in_flight"] for ev in d.scale_events]
    if label == "async":
        # the pipeline really was >= 2 chunks ahead at EVERY remesh barrier
        assert all(n >= 2 for n in drained), drained
        assert r.dispatch["max_in_flight"] >= 2, r.dispatch
    else:
        assert all(n == 0 for n in drained), drained   # sync: nothing queued
    runs[label] = r

for label, r in runs.items():
    assert np.array_equal(ref.finish_times, r.finish_times), label
    assert np.array_equal(ref.makespans, r.makespans), label
    assert np.array_equal(ref.vm_assign, r.vm_assign), label

# ---- deterministic FLOAT MapReduce across the same scale path ----------
corpus = make_corpus(16, 512, vocab=64, seed=5)
base = None
for ahead in (2, 0):
    for backend in ("hazelcast", "infinispan"):
        eng = MapReduceEngine(backend=backend, dispatcher=ElasticDispatcher(
            health_cfg=hc, start_members=1, dispatch_ahead=ahead))
        out = np.asarray(eng.run(word_weight_job(64), jnp.asarray(corpus),
                                 chunk=4, on_chunk=loads_feeder([2.0, 2.0, 0.05])))
        assert eng.last_report.members_per_chunk == [1, 2, 4, 2], (backend, ahead)
        base = out if base is None else base
        assert np.array_equal(base, out), (backend, ahead)
# ... and, with a power-of-two chunking (pow2 chunks form exact subtrees of
# the global row-aligned tree), equals the single-member SINGLE-CHUNK run
# bit-for-bit despite the float dtype
eng1 = MapReduceEngine(backend="hazelcast",
                       dispatcher=ElasticDispatcher(start_members=1))
out1 = np.asarray(eng1.run(word_weight_job(64), jnp.asarray(corpus)))
assert np.array_equal(base, out1)

# ---- auto_scale: EMA feeding scales out with NO on_chunk feeder --------
from repro.core.des_scan import scenario_grid_job
hc2 = dataclasses.replace(hc, max_instances=2)
d2 = ElasticDispatcher(health_cfg=hc2, start_members=1, auto_scale=True,
                       dispatch_ahead=2)
d2.calibrate_target(scenario_grid_job(cfg, False), 1e-9)  # everything is slow
r2 = run_scenario_grid(cfg, grid, dispatcher=d2, chunk=3)
assert d2.n_members == 2, d2.n_members
assert r2.dispatch["scale_events"] >= 1
assert r2.dispatch["ema_step_s"] > 0.0
assert np.array_equal(ref.finish_times, r2.finish_times)

# ---- non-divisor member count on the device path -----------------------
# pad_to_shards(chunk, m) is NOT monotone in m (pad(4,3)=6 > pad(4,4)=4):
# the one-time device-source pad must cover the widest reachable window or
# dynamic_slice would clamp and compute on the wrong rows
from repro.core.dispatch import DispatchJob
d3 = ElasticDispatcher(devices=jax.devices(), start_members=3)
d3.device_slice_min_bytes = 0
j = DispatchJob(name="rows", signature="rows",
                member_fn=lambda x, v, *_: x * 2.0)
x = jnp.arange(16.0, dtype=jnp.float32).reshape(8, 2)
out, rep = d3.submit(j, x, chunk=4)
assert rep.staged_device == rep.n_chunks == 2, rep
assert np.array_equal(np.asarray(out), np.asarray(x) * 2.0)
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_cluster_auto_wires_exchange_load_into_rebalance():
    """ROADMAP exchange follow-on (c), retired: every ``scan_dist`` run
    feeds its measured per-VM exchange load into the dispatcher's
    ``observe_key_weights`` automatically, so the next scale event
    rebalances locality-aware with NO caller cooperation — and the sample
    is consumed by that event (one-shot)."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import numpy as np
from repro.core.cloudsim import ElasticSimulationCluster, SimulationConfig
from repro.core.health import HealthConfig

hc = HealthConfig(target_step_time=1.0, max_threshold=0.8, min_threshold=0.2,
                  time_between_scaling=1, window=1, max_instances=2)
cl = ElasticSimulationCluster(start_members=1, health_cfg=hc)
cfg = SimulationConfig(n_vms=16, n_cloudlets=64, core="scan_dist")
res = cl.simulate(cfg)
kw = cl.dispatcher._key_weights
assert kw is not None, "simulate() did not auto-feed key weights"
assert kw.sum() == cfg.n_cloudlets                 # one weight per cloudlet
counts = np.bincount(res.vm_assign, minlength=kw.shape[0])
assert np.array_equal(kw.astype(np.int64), counts), (kw, counts)
cl.observe_load(2.0)                               # scale out 1 -> 2
assert cl.n_members == 2
assert cl.dispatcher._key_weights is None          # one-shot: consumed
# the run after the event re-feeds a fresh observation, bit-identically
res2 = cl.simulate(cfg)
assert cl.dispatcher._key_weights is not None
assert np.array_equal(res.finish_times, res2.finish_times)
print("OK")
"""], env=env, capture_output=True, text=True, timeout=600)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_auto_block_cache_writes_only_on_measurement():
    """Steady-state auto-capacity hits must not rewrite the block cache:
    only the first call measures (one miss, one metadata write that does
    NOT count as an executable build), later calls hit — churn-free
    counters stay meaningful."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import des_scan
    from repro.core.executor import DistributedExecutor

    des_scan.invalidate_dist_core()
    ex = DistributedExecutor(Mesh(np.array(jax.devices()[:1]), ("data",)))
    args = (jnp.zeros(16, jnp.int32), jnp.ones(16), jnp.ones(4),
            jnp.ones(16, bool))
    cache = des_scan._AUTO_BLOCK_CACHE
    b0, h0, m0 = cache.builds, cache.hits, cache.misses
    for _ in range(3):                      # 1 measurement + 2 cached hits
        des_scan.simulate_completion_distributed(*args, ex)
    assert cache.builds == b0                 # metadata, not an executable
    assert cache.misses == m0 + 1 and cache.hits == h0 + 2
    des_scan.invalidate_dist_core()


def test_cluster_rejects_conflicting_topology_kwargs():
    from repro.core.cloudsim import ElasticSimulationCluster

    d = ElasticDispatcher(start_members=1)
    with pytest.raises(ValueError):
        ElasticSimulationCluster(dispatcher=d, start_members=2)
    with pytest.raises(ValueError):
        from repro.core.health import HealthConfig
        ElasticSimulationCluster(dispatcher=d, health_cfg=HealthConfig())


def test_elastic_cluster_is_thin_dispatcher_client():
    """The cluster owns NO topology of its own: table, controller, mesh,
    executor, grid, entity_pad and scale_events all live in the dispatcher."""
    from repro.core.cloudsim import ElasticSimulationCluster

    cl = ElasticSimulationCluster(start_members=1)
    d = cl.dispatcher
    assert isinstance(d, ElasticDispatcher)
    assert cl.table is d.table
    assert cl.controller is d.controller
    assert cl.mesh is d.mesh
    assert cl.executor is d.executor
    assert cl.entity_pad == d.entity_pad
    assert cl.scale_events is d.scale_events
    assert cl.n_members == d.n_members
    assert np.array_equal(np.asarray(cl.vm_owner(8)),
                          np.asarray(d.vm_owner(8)))
    # an externally-built dispatcher can be shared with the cluster
    cl2 = ElasticSimulationCluster(dispatcher=d)
    assert cl2.dispatcher is d


# ------------------------------------------------- async dispatch pipeline

def test_in_flight_drains_cleanly_on_exception():
    """A failing ``on_chunk`` mid-stream must not leak launched buffers:
    the in-flight queue is drained by the cleanup path and the dispatcher
    stays fully usable for the next stream (tier-1 smoke of the async
    pipeline's exception safety)."""
    import jax.numpy as jnp

    d = ElasticDispatcher(start_members=1, dispatch_ahead=3)
    job = DispatchJob(name="j", signature="j",
                      member_fn=lambda x, v, *_: x * 2.0, reduce="concat")
    seen_in_flight = []

    def boom(disp, ci, n):
        seen_in_flight.append(disp.in_flight)
        if ci == 2:
            raise RuntimeError("boom")

    with pytest.raises(RuntimeError, match="boom"):
        d.submit(job, np.ones((12, 2), np.float32), chunk=2, on_chunk=boom)
    assert max(seen_in_flight) >= 2          # the pipeline really was ahead
    assert d.in_flight == 0                  # nothing leaked
    out, rep = d.submit(job, np.ones((4, 2), np.float32), chunk=2)
    assert np.asarray(out).shape == (4, 2) and d.in_flight == 0
    # sum jobs drain too (partials queue through the same pipeline)
    sum_job = DispatchJob(
        name="s", signature="s", reduce="sum",
        member_fn=lambda x, v, *_: jnp.where(v[:, None], x, 0).sum(axis=0))
    with pytest.raises(RuntimeError, match="boom"):
        d.submit(sum_job, np.ones((12, 2), np.float32), chunk=2,
                 on_chunk=boom)
    assert d.in_flight == 0


def test_device_resident_items_zero_host_copies(monkeypatch):
    """Device-resident item sets stay on device: chunks are cut by
    ``executor.slice_chunk`` (host staging is patched to FAIL), outputs are
    device arrays that chain into the next job, and a counting
    ``executor.put`` shim sees no host (numpy) operand on the global path."""
    import jax
    import jax.numpy as jnp

    d = ElasticDispatcher(start_members=1)
    d.device_slice_min_bytes = 0         # force device slicing at any size
    monkeypatch.setattr(
        ElasticDispatcher, "_stage_host",
        staticmethod(lambda *a: (_ for _ in ()).throw(
            AssertionError("host staging touched on the device path"))))

    job = DispatchJob(name="j", signature="j",
                      member_fn=lambda x, v, *_: x + 1.0, reduce="concat")
    items = jnp.arange(20.0, dtype=jnp.float32).reshape(10, 2)
    out, rep = d.submit(job, items, chunk=3)
    assert rep.staged_device == rep.n_chunks == 4 and rep.staged_host == 0
    leaf = jax.tree_util.tree_leaves(out)[0]
    assert isinstance(leaf, jax.Array)       # exposed lazily, still on device
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(items) + 1.0)

    # a previous job's device output feeds the next submit host-copy-free
    out2, rep2 = d.submit(job, out, chunk=4)
    assert rep2.staged_device == rep2.n_chunks and rep2.staged_host == 0
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(items) + 2.0)

    # global (auto-SPMD) path: the counting put shim must never see numpy
    host_puts = []
    orig_put = d.executor.put

    def counting_put(value, spec=None):
        if isinstance(value, np.ndarray):
            host_puts.append(value.shape)
        return orig_put(value, spec)

    monkeypatch.setattr(d.executor, "put", counting_put)
    gjob = DispatchJob(name="g", signature="g",
                       global_fn=lambda x, v, *_: x * 3.0, reduce="concat")
    out3, rep3 = d.submit(gjob, out2, chunk=5)
    assert rep3.staged_device == rep3.n_chunks and rep3.staged_host == 0
    assert host_puts == []                   # zero host copies end to end
    np.testing.assert_array_equal(np.asarray(out3),
                                  (np.asarray(items) + 2.0) * 3.0)


def test_deterministic_sum_requires_sum_reduce():
    with pytest.raises(ValueError):
        DispatchJob(name="x", signature="x", member_fn=lambda *a: a,
                    reduce="concat", deterministic=True)


def test_deterministic_float_sum_bit_identical_across_chunkings():
    """The fixed-arity pairwise tree keyed on chunk index: float sums are
    bit-identical across power-of-two chunk sizes (equal pow2 chunks form
    exact subtrees of the global row-aligned tree) and across host/device
    item staging — the int32 word-count guarantee, extended to floats."""
    import jax.numpy as jnp

    d = ElasticDispatcher(start_members=1)
    job = DispatchJob(name="det", signature="det", reduce="sum",
                      deterministic=True,
                      member_fn=lambda x, v, *_: x)
    rng = np.random.RandomState(0)
    x = (rng.randn(22, 5) * 10 ** rng.uniform(-3, 3, (22, 5))).astype(
        np.float32)
    outs = [np.asarray(d.submit(job, x, chunk=c)[0]) for c in (2, 4, 8, 16)]
    outs += [np.asarray(d.submit(job, jnp.asarray(x), chunk=c)[0])
             for c in (2, 8)]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
    # a non-pow2 chunking is still deterministic run-to-run, but the stream
    # WARNS that the cross-chunking guarantee is forfeited (ROADMAP hygiene
    # note, now surfaced at submit instead of silently lost)
    with pytest.warns(NonPow2ChunkWarning):
        a = np.asarray(d.submit(job, x, chunk=3)[0])
    with pytest.warns(NonPow2ChunkWarning):
        b = np.asarray(d.submit(job, x, chunk=3)[0])
    np.testing.assert_array_equal(a, b)


def test_nonpow2_warning_exactly_once_per_submit():
    """NonPow2ChunkWarning fires EXACTLY once per offending submit — and
    never for pow2 chunkings, single-chunk streams, or non-deterministic
    jobs (their reduce order doesn't depend on the chunking)."""
    import warnings as _warnings

    d = ElasticDispatcher(start_members=1)
    det = DispatchJob(name="det", signature="detw", reduce="sum",
                      deterministic=True, member_fn=lambda x, v, *_: x)
    x = np.ones((12, 2), np.float32)

    def count(job, **kw):
        with _warnings.catch_warnings(record=True) as rec:
            _warnings.simplefilter("always")
            d.submit(job, x, **kw)
        return sum(issubclass(w.category, NonPow2ChunkWarning) for w in rec)

    assert count(det, chunk=3) == 1            # non-pow2, multi-chunk
    assert count(det, chunk=3) == 1            # once per submit, not once ever
    assert count(det, chunk=4) == 0            # pow2
    assert count(det, chunk=12) == 0           # single chunk: no cross-chunk
    plain = DispatchJob(name="p", signature="pw", reduce="concat",
                        member_fn=lambda x, v, *_: x * 2.0)
    assert count(plain, chunk=3) == 0          # non-deterministic job


def test_auto_scale_ema_and_target_calibration():
    """auto_scale feeds an EMA of retirement-to-retirement step times: the
    synchronous baseline still samples per chunk, compile chunks reset the
    timer instead of polluting the EMA, an explicit per-job-class target
    dominates, and an uncalibrated job class self-calibrates so its first
    sample lands at the neutral midpoint of the scaling thresholds."""
    d = ElasticDispatcher(start_members=1, auto_scale=True, dispatch_ahead=0)
    job = DispatchJob(name="j", signature="jsig",
                      member_fn=lambda x, v, *_: x * 2.0, reduce="concat",
                      target_step_time=1e9)
    d.submit(job, np.ones((12, 2), np.float32), chunk=2)
    assert d.job_targets == {}            # explicit target: no calibration
    assert d.controller.monitor.load() < 0.1      # huge target => tiny load

    job2 = DispatchJob(name="k", signature="ksig",
                       member_fn=lambda x, v, *_: x * 2.0, reduce="concat")
    _, rep = d.submit(job2, np.ones((12, 2), np.float32), chunk=2)
    assert rep.ema_step_s > 0.0
    target = d.job_targets.get("ksig")
    assert target is not None and target > 0.0    # self-calibrated
    # the calibrating sample itself lands at the neutral threshold midpoint
    mid = 0.5 * (d.health_cfg.max_threshold + d.health_cfg.min_threshold)
    assert d._job_target(job2, 1.0) == target     # sticky once calibrated
    d.calibrate_target(job2, 123.0)
    assert d.job_targets["ksig"] == 123.0         # explicit API overrides
    fresh = DispatchJob(name="f", signature="fsig",
                        member_fn=lambda x, v, *_: x, reduce="concat")
    assert d._job_target(fresh, 2.0) == pytest.approx(2.0 / mid)

    # PIPELINED short streams (n_chunks <= depth, nothing ever retires
    # mid-loop) still sample: the auto_scale end-drain falls back to
    # launch-to-completion walls, so the IAS is never starved
    d2 = ElasticDispatcher(start_members=1, auto_scale=True,
                           dispatch_ahead=2)
    sj = DispatchJob(name="s", signature="ssig", target_step_time=1e9,
                     member_fn=lambda x, v, *_: x * 2.0, reduce="concat")
    d2.submit(sj, np.ones((4, 2), np.float32), chunk=2)     # compile chunk
    _, rep2 = d2.submit(sj, np.ones((4, 2), np.float32), chunk=2)
    assert rep2.ema_step_s > 0.0 and rep2.max_in_flight == 2
    assert d2.in_flight == 0


# ------------------------------------------- locality-aware rebalance (seed)

def test_weighted_rebalance_spreads_hot_vm():
    """A hot VM (huge observed exchange_load) must not drag a full share of
    cold partitions onto its member: weighted leveling gives the hot
    partition's owner far FEWER partitions than the balanced count, while
    total weighted load stays near-balanced."""
    n_keys, n_members = DEFAULT_PARTITION_COUNT, 4
    key_w = np.ones(n_keys)
    hot_key = 17
    key_w[hot_key] = 300.0                 # one hot VM
    w = partition_weights_from_keys(key_w)
    assert w.shape == (DEFAULT_PARTITION_COUNT,)
    assert w[hot_key % DEFAULT_PARTITION_COUNT] == 300.0

    pt = PartitionTable(n_instances=1)
    moved = pt.rebalance(n_members, weights=w)
    assert moved > 0
    assert (pt.owner >= 0).all() and (pt.owner < n_members).all()
    hot_owner = pt.owner[hot_key % DEFAULT_PARTITION_COUNT]
    counts = np.bincount(pt.owner, minlength=n_members)
    balanced = DEFAULT_PARTITION_COUNT // n_members
    # the hot member carries far fewer partitions than a count-balanced table
    assert counts[hot_owner] < balanced // 2, counts
    # ... and weighted loads are leveled AROUND the irreducible hot
    # partition: the hot member takes almost nothing on top of it, while the
    # cold members split the remaining weight evenly
    loads = np.zeros(n_members)
    np.add.at(loads, pt.owner, w)
    assert loads[hot_owner] <= 300.0 * 1.1, loads
    cold = np.delete(loads, hot_owner)
    assert cold.max() - cold.min() <= 0.2 * cold.mean(), loads
    # unweighted rebalance (the default) still levels by COUNT
    pt2 = PartitionTable(n_instances=1)
    pt2.rebalance(n_members)
    c2 = pt2.load()
    assert c2.max() - c2.min() <= 1


def test_weighted_rebalance_validates_and_covers_departures():
    pt = PartitionTable(n_instances=4)
    with pytest.raises(ValueError):
        pt.rebalance(2, weights=np.ones(3))        # wrong shape
    w = np.ones(DEFAULT_PARTITION_COUNT)
    pt.rebalance(2, weights=w)                     # departed members re-home
    assert (pt.owner < 2).all()
    # uniform weights behave like count-leveling (spread stays tight)
    load = pt.load()
    assert load.max() - load.min() <= DEFAULT_PARTITION_COUNT // 20


def test_dispatcher_observe_key_weights_feeds_remesh():
    """After ``observe_key_weights``, the next scale event rebalances by
    weight: the hot key's member ends up with a small partition count."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import numpy as np
from repro.core.dispatch import ElasticDispatcher
from repro.core.health import HealthConfig

hc = HealthConfig(target_step_time=1.0, max_threshold=0.8, min_threshold=0.2,
                  time_between_scaling=1, window=1, max_instances=2)
d = ElasticDispatcher(start_members=1, health_cfg=hc)
key_w = np.ones(100)
key_w[3] = 500.0                                   # VM 3 is hot
d.observe_key_weights(key_w)
d.observe_load(2.0)                                # scale out 1 -> 2
assert d.n_members == 2, d.n_members
owner = np.asarray(d.vm_owner(100))
hot_member = owner[3]
loads = np.zeros(2)
np.add.at(loads, owner, key_w)
counts = np.bincount(owner, minlength=2)
# weighted load near-balanced => the hot member holds few other keys
assert counts[hot_member] < counts[1 - hot_member], (counts, loads)
print("OK")
"""], env=env, capture_output=True, text=True, timeout=600)
    assert "OK" in r.stdout, r.stdout + r.stderr
