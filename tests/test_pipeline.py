"""Pipeline parallelism: pipelined forward/backward == sequential reference."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code, devices=4, timeout=900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_gpipe_matches_sequential():
    r = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.train.pipeline import pipelined_apply
from repro.core.compat import AXIS_TYPE_AUTO, make_mesh

mesh = make_mesh((2, 2), ("pipe", "data"),
                 axis_types=(AXIS_TYPE_AUTO,)*2)
L, B, S, D = 4, 8, 4, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (L, D, D)) / jnp.sqrt(D)
x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, D))

def layer_fn(w, h):
    return jnp.tanh(h @ w) + h

def seq(ws, x):
    for i in range(L):
        x = layer_fn(ws[i], x)
    return x

y_ref = seq(ws, x)
y_pipe = jax.jit(lambda w_, x_: pipelined_apply(
    layer_fn, w_, x_, mesh, n_microbatch=4))(ws, x)
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                           atol=1e-5, rtol=1e-5)

# gradients flow through the reverse pipeline identically
g_ref = jax.grad(lambda w_: seq(w_, x).sum())(ws)
g_pipe = jax.grad(lambda w_: pipelined_apply(
    layer_fn, w_, x, mesh, n_microbatch=4).sum())(ws)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_ref),
                           atol=1e-4, rtol=1e-4)
print("OK")
""")
    assert "OK" in r.stdout, r.stdout + r.stderr
