"""Durable dispatch: journal round-trips, checkpoint alignment, coordinator
crash + resume.

Covers the PR acceptance gauntlet: the coordinator killed at EVERY chunk
index of a scaled 8-chunk stream (both the injected ``coordinator_crash``
fault and a hard SIGKILL / ``os._exit`` in a subprocess) resumes
bit-identically; a COMPLETE journal resumes idempotently with zero chunk
executions and zero compiles; environment mismatches and corrupted
checkpoints are loud ``ResumeMismatchError``s; graceful SIGTERM drain is the
resumable twin of the crash.  Property layer (hypothesis when available,
seeded sweep otherwise): pytree encode/decode/digest round-trips and the
binary-counter prefix property that makes pow2-aligned checkpoints exact
subtree states.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.dispatch import DispatchJob, ElasticDispatcher
from repro.core.faults import (CoordinatorCrashError, FaultInjector,
                               FaultSpec, JobFailedError, RetryPolicy)
from repro.core.journal import (CheckpointPolicy, DrainInterrupted,
                                JobJournal, ResumeMismatchError, counter_drain,
                                counter_push, journal_dir, load_checkpoint,
                                load_journal, stable_signature, tree_decode,
                                tree_digest, tree_encode)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _job():
    return DispatchJob(name="affine", signature="affine-journal",
                       member_fn=lambda x, v, w: x * w + 1.0,
                       reduce="concat")


def _det_job():
    import jax.numpy as jnp
    return DispatchJob(name="det", signature="det-journal", reduce="sum",
                       deterministic=True,
                       member_fn=lambda x, v, w: jnp.sqrt(x * x + w))


def _items(n=32):
    rng = np.random.RandomState(0)
    return (rng.randn(n, 4) * 10 ** rng.uniform(-2, 2, (n, 4))).astype(
        np.float32)


# ---------------------------------------------------------------- unit layer

def test_checkpoint_policy_validation_and_pow2_rounding(tmp_path):
    with pytest.raises(ValueError):
        CheckpointPolicy(path=str(tmp_path), every_n_chunks=0)
    with pytest.raises(ValueError):
        CheckpointPolicy(path=str(tmp_path), fsync="sometimes")
    # every_n_chunks rounds UP to a power of two: boundaries must sit on
    # pow2 subtree roots of the deterministic chunk tree
    for ask, want in ((1, 1), (2, 2), (3, 4), (4, 4), (5, 8), (9, 16)):
        assert CheckpointPolicy(path=str(tmp_path),
                                every_n_chunks=ask).every_n_chunks == want


def test_stable_signature_is_process_stable():
    # callables render module.qualname, not a repr with a memory address
    s = stable_signature(("mapreduce", "hazelcast", _job, 7))
    assert "0x" not in s and "test_journal._job" in s
    assert stable_signature(_job) == stable_signature(_job)
    assert stable_signature({"b": 1, "a": 2}) == \
        stable_signature({"a": 2, "b": 1})


def _tree_case(rng, depth=2):
    """One random nested pytree with array leaves, scalars, and Nones."""
    def node(d):
        r = rng.randint(0, 6 if d > 0 else 3)
        if r == 0:
            return rng.randn(rng.randint(1, 4),
                             rng.randint(1, 4)).astype(np.float32)
        if r == 1:
            return rng.randint(-5, 5, size=rng.randint(1, 5)).astype(np.int32)
        if r == 2:
            return [None, float(rng.randn()), int(rng.randint(10)),
                    bool(rng.randint(2)), "s%d" % rng.randint(9)][
                        rng.randint(5)]
        if r == 3:
            return {("k%d" % i): node(d - 1) for i in range(rng.randint(1, 3))}
        if r == 4:
            return tuple(node(d - 1) for _ in range(rng.randint(1, 3)))
        return [node(d - 1) for _ in range(rng.randint(1, 3))]
    return node(depth)


def _assert_tree_equal(a, b):
    if isinstance(a, dict):
        assert isinstance(b, dict) and list(a) == list(b)
        for k in a:
            _assert_tree_equal(a[k], b[k])
    elif isinstance(a, tuple):
        assert isinstance(b, tuple) and len(a) == len(b)
        for x, y in zip(a, b):
            _assert_tree_equal(x, y)
    elif isinstance(a, list):
        assert isinstance(b, list) and len(a) == len(b)
        for x, y in zip(a, b):
            _assert_tree_equal(x, y)
    elif isinstance(a, np.ndarray):
        assert isinstance(b, np.ndarray)
        assert a.dtype == b.dtype and a.shape == b.shape
        assert a.tobytes() == b.tobytes()
    else:
        assert a == b and type(a) is type(b)


def _roundtrip_case(seed):
    rng = np.random.RandomState(seed)
    tree = _tree_case(rng)
    spec, leaves = tree_encode(tree)
    json.dumps(spec)                       # spec must be JSON-serializable
    back = tree_decode(spec, leaves)
    _assert_tree_equal(tree, back)
    assert tree_digest(tree) == tree_digest(back)


def test_tree_encode_decode_digest_roundtrip():
    """Property: encode/decode is the identity on nested pytrees (exact
    bytes, dtypes, container types and key order) and the digest is stable
    under the round trip but sensitive to any leaf bit flip."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for seed in range(25):
            _roundtrip_case(seed)
    else:
        @settings(max_examples=25, deadline=None)
        @given(seed=st.integers(0, 10 ** 6))
        def run(seed):
            _roundtrip_case(seed)
        run()

    # digest sensitivity: one changed element changes the digest
    a = {"x": np.arange(6, dtype=np.float32), "y": (1, None)}
    b = {"x": np.arange(6, dtype=np.float32), "y": (1, None)}
    assert tree_digest(a) == tree_digest(b)
    b["x"][3] += 1
    assert tree_digest(a) != tree_digest(b)
    # ...and dtype matters even when bytes agree elementwise
    assert tree_digest(np.zeros(4, np.int32)) != \
        tree_digest(np.zeros(4, np.float32))


def _counter_case(n, split):
    """The checkpoint-alignment property in miniature: pushing ``split``
    parts, snapshotting the counter, and continuing from the snapshot folds
    to the SAME bytes as the uninterrupted run — for any prefix length, not
    just pow2 ones — because the counter state after k pushes is exactly
    the pow2 subtrees of k's binary decomposition."""
    rng = np.random.RandomState(1000 * n + split)
    parts = [(rng.randn(3) * 10 ** rng.uniform(-2, 2, 3)).astype(np.float32)
             for _ in range(n)]
    combine = np.add

    full = {}
    for p in parts:
        counter_push(full, p, combine)

    head = {}
    for p in parts[:split]:
        counter_push(head, p, combine)
    # occupied levels == binary decomposition of the prefix length
    assert set(head) == {i for i in range(split.bit_length())
                         if (split >> i) & 1}
    snap = {lvl: np.array(t) for lvl, t in head.items()}   # the checkpoint
    for p in parts[split:]:
        counter_push(snap, p, combine)

    assert sorted(full) == sorted(snap)
    ref = counter_drain(full, combine)
    out = counter_drain(snap, combine)
    assert np.asarray(ref).tobytes() == np.asarray(out).tobytes()


def test_counter_prefix_resume_property():
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for n in (1, 2, 3, 5, 8, 13, 16, 21):
            for split in range(n + 1):
                _counter_case(n, split)
        return

    @settings(max_examples=40, deadline=None)
    @given(n=st.integers(1, 24), data=st.data())
    def run(n, data):
        _counter_case(n, data.draw(st.integers(0, n)))
    run()


def test_journal_roundtrip_torn_tail_and_dir_normalization(tmp_path):
    pol = CheckpointPolicy(path=str(tmp_path / "j"), async_write=False)
    j = JobJournal.create(pol, {"env": {"job": "t"}, "n_members": 1,
                                "every_n_chunks": pol.every_n_chunks})
    j.append({"type": "chunk", "chunk": 0, "attempt": 0, "digest": "d0"})
    j.write_checkpoint(1, "pending", {0: np.arange(3.0)}, {})
    j.append({"type": "chunk", "chunk": 1, "attempt": 0, "digest": "d1"})
    j.close()
    # a torn tail line (the coordinator died mid-append) is ignored on load
    with open(j.journal_file, "a") as f:
        f.write('{"type": "chunk", "chunk": 2, "att')

    for ref in (str(tmp_path / "j"), j.journal_file):   # dir or file both ok
        st = load_journal(ref)
        assert st.header is not None
        assert sorted(st.chunks) == [0, 1]
        assert [c["k"] for c in st.checkpoints] == [1]
        assert st.complete is None
    assert journal_dir(j.journal_file) == str(tmp_path / "j")

    # the checkpoint loads and integrity-checks
    state, manifest = load_checkpoint(str(tmp_path / "j"), st.checkpoints[0])
    assert np.array_equal(state[0], np.arange(3.0))

    # tampering with the stored arrays is loud
    d = tmp_path / "j" / st.checkpoints[0]["dir"]
    arr = np.load(d / "a0.npy")
    arr[0] += 1
    np.save(d / "a0.npy", arr)
    with pytest.raises(ResumeMismatchError):
        load_checkpoint(str(tmp_path / "j"), st.checkpoints[0])


def test_checkpoint_rotation_keeps_latest_and_final(tmp_path):
    pol = CheckpointPolicy(path=str(tmp_path), async_write=False, keep=2)
    j = JobJournal.create(pol, {"env": {}, "n_members": 1})
    for k in range(1, 6):
        j.write_checkpoint(k, "pending", {0: np.full(2, float(k))}, {})
    j.write_checkpoint(8, "final", np.arange(4.0), {})
    j.close()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("ck_"))
    assert dirs == ["ck_00000004", "ck_00000005", "ck_final"]
    st = load_journal(str(tmp_path))
    # rotated records remain in the journal; usable_checkpoint skips them
    assert len(st.checkpoints) == 6
    assert st.usable_checkpoint()["k"] == 5
    assert st.usable_checkpoint(final=True)["kind"] == "final"


# ------------------------------------------------- in-process crash + resume

def _run_crash_resume(tmp_path, job, items, w, crash_at, *, chunk=4,
                      every=1, deliver="host"):
    """Crash a journaled stream at ``crash_at`` via the injected
    ``coordinator_crash`` fault, then resume on a FRESH dispatcher."""
    d0 = ElasticDispatcher(start_members=1, dispatch_ahead=0)
    ref, _ = d0.submit(job, items, replicated=(w,), chunk=chunk,
                       deliver="host")
    ref = np.asarray(ref)

    ck = str(tmp_path / f"ck{crash_at}")
    d1 = ElasticDispatcher(start_members=1, dispatch_ahead=2)
    with pytest.raises(CoordinatorCrashError):
        d1.submit(job, items, replicated=(w,), chunk=chunk, deliver=deliver,
                  checkpoint=CheckpointPolicy(path=ck, every_n_chunks=every),
                  fault_injector=FaultInjector(
                      [FaultSpec("coordinator_crash", chunk=crash_at)]))
    st = load_journal(ck)
    assert st.header is not None and st.complete is None
    assert all(ci < crash_at for ci in st.chunks)   # nothing past the crash

    d2 = ElasticDispatcher(start_members=1, dispatch_ahead=2)
    out, rep = d2.resume(ck, job, items, replicated=(w,), chunk=chunk)
    assert np.asarray(out).tobytes() == ref.tobytes()
    assert rep.resumed_from == ck
    assert rep.chunks_skipped + rep.chunks_replayed == rep.n_chunks
    assert load_journal(ck).complete is not None
    return ck, ref


def test_crash_resume_bit_identical_concat_and_det_sum(tmp_path):
    items, w = _items(), np.float32(1.7)
    _run_crash_resume(tmp_path / "c", _job(), items, w, crash_at=3)
    _run_crash_resume(tmp_path / "s", _det_job(), items, w, crash_at=5)
    # int reduce (word-count shape): associative, any alignment
    ints = np.arange(64, dtype=np.int32).reshape(16, 4)
    ijob = DispatchJob(name="isum", signature="isum-journal", reduce="sum",
                       member_fn=lambda x, v, w: (x * 0 + 1).sum(0))
    _run_crash_resume(tmp_path / "i", ijob, ints, np.int32(1), crash_at=2)


def test_completed_journal_resumes_idempotently_zero_compiles(tmp_path):
    job, items, w = _det_job(), _items(), np.float32(1.7)
    ck = str(tmp_path / "ck")
    d1 = ElasticDispatcher(start_members=1, dispatch_ahead=2)
    out, rep = d1.submit(job, items, replicated=(w,), chunk=4, deliver="host",
                         checkpoint=CheckpointPolicy(path=ck,
                                                     every_n_chunks=2))
    assert rep.journal_path == ck and rep.checkpoints > 0
    assert len(rep.checkpoint_write_s) == rep.checkpoints
    st = load_journal(ck)
    assert st.complete is not None and sorted(st.chunks) == list(range(8))

    # resume of a COMPLETE journal: the final checkpoint is loaded and
    # returned with ZERO chunk executions and ZERO executable builds
    d2 = ElasticDispatcher(start_members=1, dispatch_ahead=2)
    out2, rep2 = d2.resume(ck, job, items, replicated=(w,), chunk=4)
    assert np.asarray(out2).tobytes() == np.asarray(out).tobytes()
    assert rep2.chunks_replayed == 0 and rep2.chunks_skipped == rep2.n_chunks
    assert d2.cache.builds == 0 and d2.in_flight == 0


def test_resume_mismatch_is_loud(tmp_path):
    job, items, w = _job(), _items(), np.float32(1.7)
    ck, _ = _run_crash_resume(tmp_path, job, items, w, crash_at=3)

    d = ElasticDispatcher(start_members=1)
    with pytest.raises(ResumeMismatchError, match="chunk"):
        d.resume(ck, job, items, replicated=(w,), chunk=8)   # different plan
    other = DispatchJob(name="affine", signature="other",
                        member_fn=lambda x, v, w: x * w + 1.0,
                        reduce="concat")
    with pytest.raises(ResumeMismatchError, match="signature"):
        d.resume(ck, other, items, replicated=(w,), chunk=4)
    with pytest.raises(ResumeMismatchError, match="n_items"):
        d.resume(ck, job, items[:16], replicated=(w,), chunk=4)
    with pytest.raises(ResumeMismatchError, match="nothing to resume"):
        d.resume(str(tmp_path / "nowhere"), job, items, replicated=(w,),
                 chunk=4)


def test_drain_request_checkpoints_and_resumes(tmp_path):
    job, items, w = _job(), _items(), np.float32(2.5)
    d0 = ElasticDispatcher(start_members=1, dispatch_ahead=0)
    ref = np.asarray(d0.submit(job, items, replicated=(w,), chunk=4,
                               deliver="host")[0])
    ck = str(tmp_path / "drain")
    d1 = ElasticDispatcher(start_members=1, dispatch_ahead=2)

    def preempt(disp, ci, n):
        if ci == 2:
            disp.request_drain()

    with pytest.raises(DrainInterrupted) as exc:
        d1.submit(job, items, replicated=(w,), chunk=4, deliver="host",
                  on_chunk=preempt,
                  checkpoint=CheckpointPolicy(path=ck, every_n_chunks=1))
    assert exc.value.journal_path == ck
    assert exc.value.report.journal_path == ck
    assert d1.in_flight == 0
    st = load_journal(ck)
    assert st.chunks and st.complete is None     # partial progress persisted

    d2 = ElasticDispatcher(start_members=1, dispatch_ahead=2)
    out, rep = d2.resume(ck, job, items, replicated=(w,), chunk=4)
    assert np.asarray(out).tobytes() == ref.tobytes()
    assert rep.chunks_skipped >= 1


def test_job_failure_report_persisted_to_journal(tmp_path):
    job, items, w = _job(), _items(), np.float32(2.0)
    ck = str(tmp_path / "fail")
    d = ElasticDispatcher(
        start_members=1, dispatch_ahead=2,
        fault_injector=FaultInjector(
            [FaultSpec("nan_poison", chunk=1, times=10)]),
        retry_policy=RetryPolicy(max_attempts=2, quarantine_after=0,
                                 check_finite=True))
    with pytest.raises(JobFailedError):
        d.submit(job, items, replicated=(w,), chunk=4, deliver="host",
                 checkpoint=CheckpointPolicy(path=ck, every_n_chunks=1))
    st = load_journal(ck)
    assert st.failed is not None                 # the post-mortem survives
    assert "nan_poison" in json.dumps(st.failed)
    # fault records landed alongside the failure report
    assert any(r.get("type") == "fault" for r in st.records)


def test_checkpoint_latency_in_stats_summary(tmp_path):
    job, items, w = _job(), _items(), np.float32(1.0)
    d = ElasticDispatcher(start_members=1, dispatch_ahead=2)
    _, rep = d.submit(job, items, replicated=(w,), chunk=4, deliver="host",
                      collect_stats=True,
                      checkpoint=CheckpointPolicy(path=str(tmp_path / "s"),
                                                  every_n_chunks=1))
    assert rep.checkpoints >= 8                  # every chunk + final
    assert all(s >= 0 for s in rep.checkpoint_write_s)
    summ = rep.stats
    assert summ and "checkpoint" in summ
    assert summ["checkpoint"]["n"] == rep.checkpoints
    assert summ["checkpoint"]["total_s"] == pytest.approx(
        sum(rep.checkpoint_write_s))


def test_random_schedule_includes_coordinator_crash():
    """Satellite: the chaos pool now carries ``coordinator_crash`` and a
    seeded schedule that drew it fires it deterministically."""
    from repro.core.faults import FAULT_KINDS
    assert "coordinator_crash" in FAULT_KINDS
    # same seed -> same schedule, even with the enlarged pool
    a = FaultInjector.random_schedule(seed=3, n_chunks=8, n_faults=40)
    b = FaultInjector.random_schedule(seed=3, n_chunks=8, n_faults=40)
    assert [vars(s) for s in a.schedule] == [vars(s) for s in b.schedule]
    drawn = {s.kind for s in a.schedule}
    assert "coordinator_crash" in drawn          # 40 draws over 5 kinds
    # and a drawn coordinator_crash actually kills the coordinator
    import jax
    inj = FaultInjector([s for s in a.schedule
                         if s.kind == "coordinator_crash"][:1])
    chunk = inj.schedule[0].chunk
    with pytest.raises(CoordinatorCrashError):
        inj.on_launch(chunk, jax.devices()[:1])


def test_mapreduce_resume_run_bit_identical(tmp_path):
    """The MapReduce face: a float word-weight stream crashed mid-corpus
    resumes through ``resume_run`` to the exact bytes of the uninterrupted
    run — the job signature (which contains ``map_fn``) survives the process
    boundary via ``stable_signature``."""
    from repro.core.mapreduce import (MapReduceEngine, make_corpus,
                                      word_weight_job)
    files = make_corpus(n_files=16, file_len=64, vocab=50, seed=4)
    wj = word_weight_job(50)
    eng0 = MapReduceEngine(dispatcher=ElasticDispatcher(start_members=1))
    ref = np.asarray(eng0.run(wj, files, chunk=4))

    ck = str(tmp_path / "mr")
    eng1 = MapReduceEngine(dispatcher=ElasticDispatcher(
        start_members=1, fault_injector=FaultInjector(
            [FaultSpec("coordinator_crash", chunk=2)])))
    with pytest.raises(CoordinatorCrashError):
        eng1.run(wj, files, chunk=4,
                 checkpoint=CheckpointPolicy(path=ck, every_n_chunks=1))
    assert load_journal(ck).header is not None

    eng2 = MapReduceEngine(dispatcher=ElasticDispatcher(start_members=1))
    out = np.asarray(eng2.resume_run(ck, wj, files, chunk=4))
    assert out.tobytes() == ref.tobytes()
    rep = eng2.last_report
    # crash at launch of chunk 2 with dispatch_ahead=2: chunks 0-1 may die
    # in flight unvalidated, so everything is legitimately replayable — the
    # invariant is full coverage, not a particular split
    assert rep.chunks_skipped + rep.chunks_replayed == rep.n_chunks
    assert rep.resumed_from == ck


def test_scenario_grid_resume_bit_identical(tmp_path):
    from repro.core.cloudsim import ElasticSimulationCluster, SimulationConfig
    from repro.core.des_scan import make_scenario_grid

    cfg = SimulationConfig(n_cloudlets=24, n_vms=6, core="scan")
    grid = make_scenario_grid(seeds=range(8), mi_scales=(1.0, 1.5))

    ref = ElasticSimulationCluster(start_members=1).simulate_grid(
        cfg, grid, chunk=4)

    ck = str(tmp_path / "grid")
    cl = ElasticSimulationCluster(start_members=1)
    from repro.core.des_scan import grid_batch_args
    args, job, _ = grid_batch_args(cfg, grid)
    with pytest.raises(CoordinatorCrashError):
        cl.dispatcher.submit(
            job, args, chunk=4, deliver="host",
            checkpoint=CheckpointPolicy(path=ck, every_n_chunks=1),
            fault_injector=FaultInjector(
                [FaultSpec("coordinator_crash", chunk=2)]))

    out, rep = ElasticSimulationCluster(start_members=1).resume_grid(
        ck, cfg, grid, chunk=4)
    _, _, makespans, _ = out
    assert np.asarray(makespans).tobytes() == ref.makespans.tobytes()
    assert rep.chunks_skipped + rep.chunks_replayed == rep.n_chunks
    assert rep.resumed_from == ck


# ------------------------------------------- acceptance (subprocess, 8 dev)

def test_coordinator_killed_every_chunk_index_resumes_bit_identical(tmp_path):
    """THE acceptance test: the coordinator dies at EVERY chunk index of an
    8-chunk async stream riding a 1→2→4→2 scale sequence — hard
    (``SIGKILL`` / ``os._exit(137)`` in a victim subprocess, alternating to
    cover both death shapes) — and a fresh process resumes each journal to
    bytes identical to the uninterrupted run; the injected in-process
    ``coordinator_crash`` sweep covers the same indices cheaply."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    victim = tmp_path / "victim.py"
    victim.write_text("""
import os, signal, sys
import numpy as np, jax, jax.numpy as jnp
from repro.core.dispatch import DispatchJob, ElasticDispatcher
from repro.core.faults import FaultInjector, FaultSpec
from repro.core.health import HealthConfig
from repro.core.journal import CheckpointPolicy

kill_at, ck, mode = int(sys.argv[1]), sys.argv[2], sys.argv[3]
job = DispatchJob(name="det", signature="det", reduce="sum",
                  deterministic=True,
                  member_fn=lambda x, v, w: jnp.sqrt(x * x + w))
rng = np.random.RandomState(0)
items = (rng.randn(32, 4) * 10 ** rng.uniform(-2, 2, (32, 4))).astype(
    np.float32)
w = np.float32(1.7)
hc = HealthConfig(target_step_time=1.0, max_threshold=0.8, min_threshold=0.2,
                  time_between_scaling=1, window=1, max_instances=4)
LOADS = [2.0, 2.0, 0.05]
it = iter(LOADS)

def on_chunk(disp, ci, n):
    if mode == "sigkill" and ci == kill_at:
        os.kill(os.getpid(), signal.SIGKILL)
    l = next(it, None)
    if l is not None:
        disp.observe_load(l)

inj = (FaultInjector([FaultSpec("coordinator_crash", chunk=kill_at)],
                     hard_exit=True)
       if mode == "exit137" else FaultInjector())
d = ElasticDispatcher(devices=jax.devices(), health_cfg=hc,
                      start_members=1, dispatch_ahead=2, fault_injector=inj)
d.submit(job, items, replicated=(w,), chunk=4, deliver="host",
         on_chunk=on_chunk,
         checkpoint=CheckpointPolicy(path=ck, every_n_chunks=1))
print("SURVIVED")                       # only the fault-free control reaches
""")
    r = subprocess.run([sys.executable, "-c", """
import os, subprocess, sys
import numpy as np, jax, jax.numpy as jnp
from repro.core.dispatch import DispatchJob, ElasticDispatcher
from repro.core.faults import CoordinatorCrashError, FaultInjector, FaultSpec
from repro.core.health import HealthConfig
from repro.core.journal import CheckpointPolicy, load_journal

victim, workdir = sys.argv[1], sys.argv[2]
job = DispatchJob(name="det", signature="det", reduce="sum",
                  deterministic=True,
                  member_fn=lambda x, v, w: jnp.sqrt(x * x + w))
rng = np.random.RandomState(0)
items = (rng.randn(32, 4) * 10 ** rng.uniform(-2, 2, (32, 4))).astype(
    np.float32)
w = np.float32(1.7)

def hc():
    return HealthConfig(target_step_time=1.0, max_threshold=0.8,
                        min_threshold=0.2, time_between_scaling=1,
                        window=1, max_instances=4)

LOADS = [2.0, 2.0, 0.05]          # 1 -> 2 -> 4 -> 2 across the stream

def feeder():
    it = iter(LOADS)
    def on_chunk(disp, ci, n):
        l = next(it, None)
        if l is not None:
            disp.observe_load(l)
    return on_chunk

# uninterrupted oracle (deterministic sum: member-count invariant)
d0 = ElasticDispatcher(devices=jax.devices()[:1], health_cfg=hc(),
                       start_members=1, dispatch_ahead=0)
ref = np.asarray(d0.submit(job, items, replicated=(w,), chunk=4,
                           deliver="host")[0])

# (a) injected coordinator_crash at every index, resumed in THIS process
for kill_at in range(8):
    ck = os.path.join(workdir, "inj%d" % kill_at)
    d = ElasticDispatcher(devices=jax.devices(), health_cfg=hc(),
                          start_members=1, dispatch_ahead=2,
                          fault_injector=FaultInjector(
                              [FaultSpec("coordinator_crash",
                                         chunk=kill_at)]))
    try:
        d.submit(job, items, replicated=(w,), chunk=4, deliver="host",
                 on_chunk=feeder(),
                 checkpoint=CheckpointPolicy(path=ck, every_n_chunks=1))
        raise SystemExit("crash %d did not fire" % kill_at)
    except CoordinatorCrashError:
        pass
    assert d.in_flight == 0
    d2 = ElasticDispatcher(devices=jax.devices(), health_cfg=hc(),
                           start_members=1, dispatch_ahead=2)
    out, rep = d2.resume(ck, job, items, replicated=(w,), chunk=4)
    assert np.asarray(out).tobytes() == ref.tobytes(), kill_at
    assert rep.chunks_skipped + rep.chunks_replayed == 8
    assert load_journal(ck).complete is not None
print("INJECTED OK")

# (b) hard death: SIGKILL / os._exit(137) victims, resumed here
for kill_at in range(8):
    mode = "sigkill" if kill_at % 2 == 0 else "exit137"
    ck = os.path.join(workdir, "hard%d" % kill_at)
    r = subprocess.run([sys.executable, victim, str(kill_at), ck, mode],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode in (-9, 137), (kill_at, r.returncode, r.stderr)
    assert "SURVIVED" not in r.stdout
    st = load_journal(ck)
    assert st.header is not None, kill_at   # header always hits disk first
    d2 = ElasticDispatcher(devices=jax.devices(), health_cfg=hc(),
                           start_members=1, dispatch_ahead=2)
    out, rep = d2.resume(ck, job, items, replicated=(w,), chunk=4)
    assert np.asarray(out).tobytes() == ref.tobytes(), (kill_at, mode)
    assert load_journal(ck).complete is not None
print("HARD-KILL OK")

# control: the fault-free victim config completes and its journal resumes
# idempotently (zero replay)
ck = os.path.join(workdir, "ctl")
r = subprocess.run([sys.executable, victim, "-1", ck, "none"],
                   capture_output=True, text=True, timeout=600)
assert r.returncode == 0 and "SURVIVED" in r.stdout, r.stderr
d2 = ElasticDispatcher(devices=jax.devices(), health_cfg=hc(),
                       start_members=1, dispatch_ahead=2)
out, rep = d2.resume(ck, job, items, replicated=(w,), chunk=4)
assert np.asarray(out).tobytes() == ref.tobytes()
assert rep.chunks_replayed == 0 and d2.cache.builds == 0
print("IDEMPOTENT-CONTROL-DONE")
""", str(victim), str(tmp_path)], env=env, capture_output=True, text=True,
                       timeout=900)
    for sentinel in ("INJECTED OK", "HARD-KILL OK", "IDEMPOTENT-CONTROL-DONE"):
        assert sentinel in r.stdout, (sentinel, r.stdout, r.stderr)
