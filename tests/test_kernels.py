"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.moe_gmm.kernel import grouped_matmul
from repro.kernels.moe_gmm.ref import grouped_matmul_ref
from repro.kernels.moe_gmm import ops as gmm_ops
from repro.kernels.histogram.kernel import histogram_kernel
from repro.kernels.histogram.ref import histogram_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.seg_scan.kernel import seg_cumsum
from repro.kernels.seg_scan.ref import seg_cumsum_ref


@pytest.mark.parametrize("BH,Sq,Skv,hd,causal,window,bq,bk", [
    (2, 128, 128, 32, True, 0, 32, 32),
    (2, 128, 128, 32, False, 0, 64, 32),
    (1, 256, 256, 16, True, 64, 64, 64),
    (3, 64, 64, 64, True, 0, 64, 64),
    (1, 128, 128, 8, True, 32, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(BH, Sq, Skv, hd, causal, window, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (BH, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (BH, Skv, hd), dtype)
    v = jax.random.normal(ks[2], (BH, Skv, hd), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_model_layout_and_grad():
    B, S, H, hd = 2, 64, 4, 16
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(key, (B, S, H, hd))
    v = jax.random.normal(key, (B, S, H, hd))
    out = fa_ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    from repro.models.attention import _chunked_attn
    ref = _chunked_attn(q, k, v, causal=True, window=0, q_offset=0,
                        kv_len=None, q_chunk=32)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
    g = jax.grad(lambda q_: fa_ops.flash_attention(
        q_, k, v, block_q=32, block_k=32).sum())(q)
    assert np.isfinite(np.asarray(g)).all()


@pytest.mark.parametrize("E,C,D,F,bc,bf,bd", [
    (4, 64, 32, 48, 32, 16, 16),
    (2, 128, 128, 128, 128, 128, 64),
    (8, 32, 16, 32, 32, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_sweep(E, C, D, F, bc, bf, bd, dtype):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (E, C, D), dtype)
    w = jax.random.normal(key, (E, D, F), dtype)
    out = grouped_matmul(x, w, block_c=bc, block_f=bf, block_d=bd,
                         interpret=True)
    ref = grouped_matmul_ref(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_gmm_grad():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    g = jax.grad(lambda w_: gmm_ops.gmm(x, w_, block_c=32, block_f=32,
                                        block_d=16).sum())(w)
    gr = jax.grad(lambda w_: grouped_matmul_ref(x, w_).sum())(w)
    np.testing.assert_allclose(g, gr, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("T,V,bt,bv", [(2048, 128, 256, 64), (512, 512, 128, 512),
                                       (256, 64, 256, 32)])
def test_histogram_sweep(T, V, bt, bv):
    toks = jax.random.randint(jax.random.PRNGKey(2), (T,), 0, V).astype(
        jnp.int32)
    out = histogram_kernel(toks, V, block_t=bt, block_v=bv, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(histogram_ref(toks, V)))


@pytest.mark.parametrize("BH,S,P,N,chunk", [(3, 128, 16, 8, 32),
                                            (1, 64, 32, 16, 64),
                                            (2, 96, 8, 8, 16)])
def test_ssd_sweep(BH, S, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (BH, S, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    A = -jnp.exp(jax.random.normal(ks[2], (BH,)))
    B = jax.random.normal(ks[3], (BH, S, N))
    C = jax.random.normal(ks[4], (BH, S, N))
    out = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    ref = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-4, rtol=3e-3)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 48), (False, 0)])
def test_flash_attention_pallas_backward(causal, window):
    """Pallas dq/dkv kernels vs jax.grad of the oracle."""
    BH, S, hd, bq, bk = 2, 128, 32, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (BH, S, hd))
    k = jax.random.normal(ks[1], (BH, S, hd))
    v = jax.random.normal(ks[2], (BH, S, hd))
    dout = jax.random.normal(ks[3], (BH, S, hd))

    def loss_kernel(q, k, v):
        return jnp.sum(fa_ops._fa(q, k, v, causal, window, bq, bk) * dout)

    def loss_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=causal,
                                     window=window) * dout)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


def test_gmm_pallas_backward():
    """gmm backward = two grouped matmuls through the same Pallas kernel."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    g = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 32))

    def loss_k(x, w):
        return jnp.sum(gmm_ops.gmm(x, w, block_c=32, block_f=32,
                                   block_d=16) * g)

    def loss_r(x, w):
        return jnp.sum(grouped_matmul_ref(x, w) * g)

    gk = jax.grad(loss_k, argnums=(0, 1))(x, w)
    gr = jax.grad(loss_r, argnums=(0, 1))(x, w)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("C,chunk,p_reset", [
    (128, 128, 0.1),
    (300, 64, 0.25),
    (1000, 128, 0.02),
    (17, 128, 0.5),
])
def test_seg_cumsum_sweep(C, chunk, p_reset):
    """Chunked segmented cumsum (DES scan core) vs the jnp rebase oracle."""
    rng = np.random.default_rng(C)
    term = jnp.asarray(rng.uniform(0, 5, C).astype(np.float32))
    reset = jnp.asarray((rng.uniform(size=C) < p_reset).astype(np.float32))
    out = seg_cumsum(term, reset, chunk=chunk, interpret=True)
    ref = seg_cumsum_ref(term, reset)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-5)
