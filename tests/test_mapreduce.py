"""MapReduce correctness — word count vs a numpy oracle, backend parity.

The thesis's dual-backend design promises the SAME job result from the
Hazelcast-style (member-local map + collective reduce) and Infinispan-style
(global auto-SPMD) execution models.  Word count reduces in int32, so the
contract here is exact: both backends BIT-identical to ``np.bincount`` and
to each other, across member counts {1, 2, 4}, chunked streaming included,
and through the Pallas histogram-kernel path (interpret mode off-TPU).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.mapreduce import MapReduceEngine, make_corpus, word_count_job

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


@pytest.mark.parametrize("backend", ["hazelcast", "infinispan"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_word_count_vs_numpy_oracle(backend, use_kernel):
    # file_len a multiple of the histogram kernel's 256-token block
    corpus = make_corpus(6, 512, vocab=48, seed=7)
    oracle = np.bincount(corpus.reshape(-1), minlength=48)
    eng = MapReduceEngine(mesh1(), backend=backend)
    out = eng.run(word_count_job(48, use_kernel=use_kernel),
                  jnp.asarray(corpus))
    np.testing.assert_array_equal(np.asarray(out), oracle)


@pytest.mark.parametrize("backend", ["hazelcast", "infinispan"])
def test_word_count_chunked_streaming_exact(backend):
    """Streaming the corpus in chunks (including a ragged last chunk) is
    bit-identical to the one-dispatch run — padding rows are masked out of
    the int32 reduction, never counted."""
    corpus = make_corpus(7, 256, vocab=32, seed=1)      # 7 % chunk != 0
    oracle = np.bincount(corpus.reshape(-1), minlength=32)
    eng = MapReduceEngine(mesh1(), backend=backend)
    for chunk in (1, 2, 3, 7):
        out = eng.run(word_count_job(32), jnp.asarray(corpus), chunk=chunk)
        np.testing.assert_array_equal(np.asarray(out), oracle), chunk
    assert eng.last_report.n_chunks == 1                # chunk=7: one go


def test_word_count_empty_and_degenerate():
    # single file, vocab larger than any token
    corpus = np.zeros((1, 16), np.int32)
    eng = MapReduceEngine(mesh1(), backend="hazelcast")
    out = np.asarray(eng.run(word_count_job(8), jnp.asarray(corpus)))
    assert out[0] == 16 and out[1:].sum() == 0


def test_backends_bit_identical_across_member_counts():
    """{1, 2, 4} members × both backends × kernel path: every run equals the
    numpy oracle EXACTLY (int32 reduction ⇒ bit-identity), including a file
    count (10) that no member count divides evenly."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.mapreduce import MapReduceEngine, make_corpus, word_count_job

devs = jax.devices()
corpus = make_corpus(10, 512, vocab=64, seed=3)    # 10 files: ragged shards
oracle = np.bincount(corpus.reshape(-1), minlength=64)
outs = {}
for M in (1, 2, 4):
    mesh = Mesh(np.array(devs[:M]), ("data",))
    for backend in ("hazelcast", "infinispan"):
        for use_kernel in (False, True):
            out = np.asarray(MapReduceEngine(mesh, backend=backend).run(
                word_count_job(64, use_kernel=use_kernel),
                jnp.asarray(corpus)))
            assert np.array_equal(out, oracle), (M, backend, use_kernel)
            outs[(M, backend, use_kernel)] = out
# all configurations agree bit-for-bit with each other
base = outs[(1, "hazelcast", False)]
for k, v in outs.items():
    assert np.array_equal(base, v), k
# chunked streaming on 4 members, ragged chunks, kernel path
eng = MapReduceEngine(Mesh(np.array(devs), ("data",)), backend="hazelcast")
out = np.asarray(eng.run(word_count_job(64, use_kernel=True),
                         jnp.asarray(corpus), chunk=3))
assert np.array_equal(out, oracle)
assert eng.last_report.n_chunks == 4
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr
