"""Multi-tenant serve front end: admission control, weighted fairness,
shared-cache amortization, per-tenant fault isolation, overload shedding
with resumable drain markers, and the 16-tenant chaos + scale-event
acceptance test (subprocess, 8 fake devices)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dispatch import DispatchJob, ElasticDispatcher
from repro.core.faults import (FaultInjector, FaultSpec, JobFailedError,
                               RetryPolicy)
from repro.core.health import HealthConfig
from repro.core.journal import CheckpointPolicy
from repro.serve.frontend import (AdmissionDecision, TenantFrontEnd,
                                  TenantRequest, TokenBucket)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _job():
    def gfn(x, valid, *_):
        return jnp.where(valid[:, None], x * 2.0, 0.0)
    return DispatchJob(name="double", signature=("double",), global_fn=gfn,
                       reduce="concat")


def _items(n=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, 1)).astype(np.float32)


class FakeClock:
    """Deterministic injected clock: +tick per reading, plus manual jumps."""

    def __init__(self, tick=1e-3):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t

    def advance(self, dt):
        self.t += dt


# ------------------------------------------------------------------ admission

def test_admission_decisions_are_structured():
    fe = TenantFrontEnd(ElasticDispatcher(start_members=1), backlog_max=3)
    fe.register_tenant("a", burst=2.0, rate=0.0)
    fe.register_tenant("b", max_queue=1)
    job, items = _job(), _items()

    d = fe.submit(TenantRequest(tenant="ghost", job=job, items=items))
    assert (not d.admitted) and d.reason == "unknown_tenant"
    a1 = fe.submit(TenantRequest(tenant="a", job=job, items=items))
    a2 = fe.submit(TenantRequest(tenant="a", job=job, items=items))
    assert a1.admitted and a2.admitted and a1.req_id != a2.req_id
    d = fe.submit(TenantRequest(tenant="a", job=job, items=items))
    assert (not d.admitted) and d.reason == "quota_exhausted"
    b1 = fe.submit(TenantRequest(tenant="b", job=job, items=items))
    assert b1.admitted
    d = fe.submit(TenantRequest(tenant="b", job=job, items=items))
    assert (not d.admitted) and d.reason == "tenant_backlog_full"
    # global backlog: 3 queued == backlog_max — nobody else gets in
    fe.register_tenant("c")
    d = fe.submit(TenantRequest(tenant="c", job=job, items=items))
    assert (not d.admitted) and d.reason == "backlog_full"
    # every refusal is journaled and counted — never silent
    assert [r["reason"] for r in fe.journal_records] == [
        "unknown_tenant", "quota_exhausted", "tenant_backlog_full",
        "backlog_full"]
    assert fe.stats.rejections == {"unknown_tenant": 1,
                                   "quota_exhausted": 1,
                                   "tenant_backlog_full": 1,
                                   "backlog_full": 1}
    with pytest.raises(ValueError):
        AdmissionDecision(admitted=False, reason="bogus", tenant="a")


def test_token_bucket_refill_and_retry_after():
    clock = FakeClock(tick=0.0)
    b = TokenBucket(rate=2.0, burst=2.0)
    assert b.take(0.0) and b.take(0.0) and not b.take(0.0)
    assert b.retry_after() == pytest.approx(0.5)
    assert b.take(0.6)                   # 0.6 s later: 1.2 tokens refilled
    b.debit(10.0)
    assert b.tokens == 0.0               # penalty floors at zero


def test_deadline_expired_is_a_structured_rejection():
    clock = FakeClock(tick=0.0)
    fe = TenantFrontEnd(ElasticDispatcher(start_members=1), clock=clock)
    fe.register_tenant("a", deadline_s=0.5)
    job, items = _job(), _items()
    fe.submit(TenantRequest(tenant="a", job=job, items=items, chunk=8))
    clock.advance(1.0)                   # waited past the deadline
    fe.submit(TenantRequest(tenant="a", job=job, items=items, chunk=8))
    outs = fe.run()
    assert len(outs) == 1                # only the fresh request ran
    assert fe.tenants["a"].stats.rejections == {"deadline_expired": 1}
    assert any(r["event"] == "reject"
               and r["reason"] == "deadline_expired"
               for r in fe.journal_records)


# ------------------------------------------------------------------- fairness

def test_drr_weighted_fairness_two_to_one():
    fe = TenantFrontEnd(ElasticDispatcher(start_members=1), backlog_max=100)
    fe.register_tenant("heavy", weight=2.0)
    fe.register_tenant("light", weight=1.0)
    job, items = _job(), _items(4)
    for _ in range(12):
        fe.submit(TenantRequest(tenant="heavy", job=job, items=items,
                                chunk=4))
        fe.submit(TenantRequest(tenant="light", job=job, items=items,
                                chunk=4))
    order = [o["tenant"] for o in fe.run()]
    assert len(order) == 24
    # while both queues are backlogged, service is 2:1 in every rotation
    for k in (6, 9, 12, 18):
        assert order[:k].count("heavy") == 2 * order[:k].count("light")


def _drain_picks(fe):
    """Drain the DRR queues WITHOUT dispatching (pure scheduler check)."""
    served = []
    while True:
        picked = fe._pick()
        if picked is None:
            return served
        st, req = picked
        served.append((st.name, req.req_id))


def _frontend_starvation_case(seed):
    rng = np.random.default_rng(seed)
    fe = TenantFrontEnd(ElasticDispatcher(start_members=1),
                        backlog_max=10_000)
    names = [f"t{i}" for i in range(int(rng.integers(2, 6)))]
    for n in names:
        fe.register_tenant(n, weight=float(rng.integers(1, 4)))
    job = _job()
    admitted = set()
    for _ in range(int(rng.integers(10, 40))):
        n = names[int(rng.integers(0, len(names)))]
        # cost varies: 1..8 chunks per request
        items = _items(int(rng.integers(1, 33)))
        dec = fe.submit(TenantRequest(tenant=n, job=job, items=items,
                                      chunk=4))
        assert dec.admitted
        admitted.add(dec.req_id)
    served = {rid for _, rid in _drain_picks(fe)}
    # no starvation: every admitted (always-feasible) request is served
    assert served == admitted, (seed, admitted - served)


def test_frontend_no_starvation_property():
    """Every admitted request is eventually picked by the DRR scheduler,
    for random tenant counts, weights, and request costs — hypothesis-
    driven when available, a seeded sweep otherwise."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for seed in range(25):
            _frontend_starvation_case(seed)
        return

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10 ** 6))
    def run(seed):
        _frontend_starvation_case(seed)

    run()


# --------------------------------------------------------------- amortization

def test_compile_cache_amortizes_across_tenants():
    d = ElasticDispatcher(start_members=1)
    fe = TenantFrontEnd(d, backlog_max=64)
    job, items = _job(), _items(8)
    for i in range(4):
        fe.register_tenant(f"t{i}")
        fe.submit(TenantRequest(tenant=f"t{i}", job=job, items=items,
                                chunk=4))
    fe.run()
    # one executable serves all four tenants: a single build, 7 cache hits
    assert d.cache.builds == 1
    assert d.cache.hits >= 7
    s = fe.summary()
    assert s["cache"]["builds"] == 1
    assert all(t["completed"] == 1 for t in s["tenants"].values())


# ------------------------------------------------------------------ isolation

@pytest.mark.parametrize("kind", ["nan_poison", "stall", "compile_fail",
                                  "member_crash"])
def test_tenant_addressed_fault_fires_only_for_its_tenant(kind):
    """Chaos aimed at one tenant via every tenant-addressable kind: the
    victim alone sees the fault; the bystander's bytes match its isolated
    single-tenant run.  (coordinator_crash is the process-death path —
    PR 8's journaled resume covers it, not in-process isolation.)"""
    job, items_a, items_v = _job(), _items(8, seed=1), _items(8, seed=2)
    ref = np.asarray(ElasticDispatcher(start_members=1).submit(
        job, items_a, chunk=4, deliver="host")[0])
    inj = FaultInjector([FaultSpec(kind=kind, chunk=0, tenant="victim")])
    fe = TenantFrontEnd(ElasticDispatcher(start_members=1),
                        fault_injector=inj)
    fe.register_tenant("bystander")
    fe.register_tenant("victim",
                       retry_policy=RetryPolicy(max_attempts=3,
                                                check_finite=True))
    fe.submit(TenantRequest(tenant="victim", job=job, items=items_v,
                            chunk=4))
    fe.submit(TenantRequest(tenant="bystander", job=job, items=items_a,
                            chunk=4))
    fe.run()
    fired = [f for f in inj.fired if f["kind"] == kind]
    assert fired and all(f.get("tenant") == "victim" for f in fired)
    by = fe.tenants["bystander"]
    assert np.asarray(list(by.results.values())[0]).tobytes() == ref.tobytes()
    victim = fe.tenants["victim"]
    if kind == "member_crash":
        # killing the sole member of a 1-member cluster is unrecoverable
        # (survivors < min_instances) — but the failure stays CONTAINED:
        # structured, attributed, and the bystander still ran clean above
        assert len(victim.failures) == 1
        assert isinstance(victim.failures[0]["error"], JobFailedError)
    else:
        # single-shot faults are survivable under the victim's retry budget
        assert victim.completed == 1


def test_faulty_tenant_fails_structured_with_journal_intact(tmp_path):
    """An unrecoverable tenant fault is contained: JobFailedError recorded
    (not raised through the loop), quota debited, stream journal intact on
    disk, and the other tenant's results bit-identical."""
    job, items = _job(), _items(8, seed=3)
    ref = np.asarray(ElasticDispatcher(start_members=1).submit(
        job, _items(8, seed=4), chunk=4, deliver="host")[0])
    inj = FaultInjector([FaultSpec(kind="nan_poison", chunk=0, times=99,
                                   tenant="bad")])
    fe = TenantFrontEnd(ElasticDispatcher(start_members=1),
                        fault_injector=inj,
                        journal_root=str(tmp_path))
    fe.register_tenant("good")
    fe.register_tenant("bad", burst=4.0, rate=0.0,
                       retry_policy=RetryPolicy(max_attempts=2,
                                                check_finite=True))
    ck = CheckpointPolicy(path=str(tmp_path / "bad_stream"))
    fe.submit(TenantRequest(tenant="bad", job=job, items=items, chunk=4,
                            checkpoint=ck))
    fe.submit(TenantRequest(tenant="good", job=job, items=_items(8, seed=4),
                            chunk=4))
    outs = fe.run()
    assert len(outs) == 2                       # the loop survived the fail
    bad = fe.tenants["bad"]
    assert len(bad.failures) == 1
    f = bad.failures[0]
    assert isinstance(f["error"], JobFailedError)
    assert f["report"].tenant == "bad"
    assert bad.bucket.tokens < 4.0 - 1.0        # quota debited (penalty)
    # the stream journal survived the failure (post-mortem intact)
    jpath = f["journal_path"]
    assert jpath and os.path.exists(os.path.join(jpath, "journal.jsonl"))
    # ... and the frontend's own journal recorded the fail event durably
    lines = [json.loads(l) for l in
             (tmp_path / "frontend.jsonl").read_text().splitlines()]
    assert any(r["event"] == "fail" and r["tenant"] == "bad" for r in lines)
    good = fe.tenants["good"]
    assert np.asarray(list(good.results.values())[0]).tobytes() \
        == ref.tobytes()


def test_random_schedule_tenant_draws_preserve_rng_order():
    """``tenants=`` adds one draw per spec AFTER the existing ones, so a
    seed's (kind, chunk, member) triples are unchanged — pinned so
    pre-existing chaos schedules stay reproducible."""
    base = FaultInjector.random_schedule(7, n_chunks=6, max_members=4,
                                         n_faults=5)
    scoped = FaultInjector.random_schedule(7, n_chunks=6, max_members=4,
                                           n_faults=5,
                                           tenants=["a", "b", "c"])
    for s0, s1 in zip(base.schedule, scoped.schedule):
        assert (s0.kind, s0.chunk, s0.member) == (s1.kind, s1.chunk,
                                                  s1.member)
        assert s0.tenant is None and s1.tenant in ("a", "b", "c")


# ------------------------------------------------------------------- shedding

def test_overload_sheds_lowest_priority_first_resumable(tmp_path):
    """Past the utilization knee at max scale, queued work of the LOWEST
    priority tenant sheds first — every shed a journaled, structured,
    resumable marker; ``reclaim_shed`` recovers the parked work so nothing
    is lost."""
    clock = FakeClock(tick=1e-3)
    hc = HealthConfig(policy="mmn", shed_utilization=0.5, max_instances=1,
                      min_instances=1)
    # shed_target 7: the post-serve backlog is 15 (8 bronze + 7 gold), so
    # draining to 7 consumes EXACTLY the bronze queue — gold must survive
    fe = TenantFrontEnd(ElasticDispatcher(start_members=1, health_cfg=hc),
                        backlog_max=64, shed_target=7,
                        journal_root=str(tmp_path), clock=clock)
    fe.register_tenant("gold", priority=2)
    fe.register_tenant("bronze", priority=0)
    job = _job()
    for i in range(8):
        assert fe.submit(TenantRequest(tenant="gold", job=job,
                                       items=_items(4, seed=i),
                                       chunk=4)).admitted
        assert fe.submit(TenantRequest(tenant="bronze", job=job,
                                       items=_items(4, seed=100 + i),
                                       chunk=4)).admitted
    fe.step()    # first completion computes the snapshot: backlog 15 on 1
    #              member saturates the mmn queue-pressure term -> shed
    shed_recs = [r for r in fe.journal_records if r["event"] == "shed_marker"]
    assert shed_recs and all(r["resumable"] for r in shed_recs)
    assert all(r["tenant"] == "bronze" for r in shed_recs)   # lowest first
    assert fe.backlog() == fe.shed_target
    assert fe.stats.rejections.get("shed_overload") == len(shed_recs)
    # shed decisions are structured AdmissionDecisions, never silent drops
    shed_dec = [d for d in fe.rejections if d.reason == "shed_overload"]
    assert len(shed_dec) == len(shed_recs)
    # the markers are resumable: reclaim re-queues in admission order
    parked = len(fe.tenants["bronze"].shed)
    assert fe.reclaim_shed("bronze") == parked
    fe.dispatcher.health_cfg.shed_utilization = 1.0     # drain phase
    fe.run()
    assert fe.tenants["bronze"].completed == 8          # nothing lost
    assert fe.tenants["gold"].completed == 8
    # durable journal has marker + reclaim records
    lines = [json.loads(l) for l in
             (tmp_path / "frontend.jsonl").read_text().splitlines()]
    assert sum(r["event"] == "reclaim" for r in lines) == parked


# --------------------------------------------- 16-tenant chaos acceptance test

def test_sixteen_tenant_chaos_isolation_with_scale_event():
    """THE acceptance test (subprocess, 8 fake devices): a live 16-tenant
    stream with mmn scale events firing under traffic and a chaos schedule
    (member crash + NaN poison + stall + compile fail) aimed at ONE
    tenant.  All 15 non-faulty tenants' results must be bit-identical to
    their isolated single-tenant runs; the faulty tenant must fail with a
    structured JobFailedError whose stream journal is intact."""
    code = """
import os, tempfile
import numpy as np
import jax.numpy as jnp
from repro.core.dispatch import DispatchJob, ElasticDispatcher
from repro.core.faults import FaultInjector, FaultSpec, JobFailedError, \\
    RetryPolicy
from repro.core.health import HealthConfig
from repro.core.journal import CheckpointPolicy
from repro.serve.frontend import TenantFrontEnd, TenantRequest

def gfn(x, valid, *_):
    return jnp.where(valid[:, None], x * 2.0 + 1.0, 0.0)

job = DispatchJob(name="double", signature=("double",), global_fn=gfn,
                  reduce="concat")
items = {f"t{i}": np.random.default_rng(i).standard_normal(
    (24, 1)).astype(np.float32) for i in range(16)}

# isolated single-tenant references (one frozen single-member dispatcher)
ref = {}
d0 = ElasticDispatcher(start_members=1)
for name, it in items.items():
    ref[name] = np.asarray(d0.submit(job, it, chunk=4, deliver="host")[0])

faulty = "t3"
inj = FaultInjector([
    FaultSpec(kind="member_crash", chunk=1, member=1, tenant="t5"),
    FaultSpec(kind="stall", chunk=2, delay_s=0.05, tenant="t7"),
    FaultSpec(kind="compile_fail", chunk=0, tenant="t9"),
    FaultSpec(kind="nan_poison", chunk=1, times=99, tenant=faulty),
])
hc = HealthConfig(policy="mmn", max_threshold=0.8, min_threshold=0.05,
                  time_between_scaling=1, window=1, max_instances=4,
                  target_step_time=1.0)
tmp = tempfile.mkdtemp()
fe = TenantFrontEnd(ElasticDispatcher(start_members=1, health_cfg=hc),
                    backlog_max=64, fault_injector=inj, journal_root=tmp)
for i in range(16):
    fe.register_tenant(f"t{i}", weight=1.0 + (i % 3),
                       retry_policy=RetryPolicy(max_attempts=2,
                                                check_finite=True))
for i in range(16):
    name = f"t{i}"
    ck = (CheckpointPolicy(path=os.path.join(tmp, "faulty_stream"))
          if name == faulty else None)
    dec = fe.submit(TenantRequest(tenant=name, job=job, items=items[name],
                                  chunk=4, checkpoint=ck))
    assert dec.admitted, dec
outs = fe.run()
assert len(outs) == 16, len(outs)

# >= 1 scale event fired under live traffic (queue pressure on 1 member)
assert len(fe.dispatcher.scale_events) >= 1, fe.dispatcher.scale_events

# the faulty tenant: structured JobFailedError, journal intact
bad = fe.tenants[faulty]
assert len(bad.failures) == 1
f = bad.failures[0]
assert isinstance(f["error"], JobFailedError)
assert f["report"].tenant == faulty
assert os.path.exists(os.path.join(f["journal_path"], "journal.jsonl"))

# every OTHER tenant: bit-identical to its isolated run, despite the
# member crash, the stall, the compile fault, and the scale events
for i in range(16):
    name = f"t{i}"
    if name == faulty:
        continue
    st = fe.tenants[name]
    assert st.completed == 1, (name, st.failures)
    got = np.asarray(list(st.results.values())[0])
    assert got.tobytes() == ref[name].tobytes(), name

# the chaos really fired, each within its addressed tenant only
fired = {(r["kind"], r.get("tenant")) for r in inj.fired}
assert ("member_crash", "t5") in fired, fired
assert ("nan_poison", faulty) in fired, fired
print("OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
