"""Training substrate: convergence, checkpoint/restart determinism, fault
tolerance, compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.health import HealthConfig
from repro.data.pipeline import DataConfig, DataPipeline, synthetic_batch
from repro.models.model import build_model
from repro.train import checkpoint as ck
from repro.train.compression import (compressed_grads, init_residuals)
from repro.train.elastic_runner import run_elastic_training
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, schedule
from repro.train.step import init_train_state, make_train_step


def tiny_model():
    cfg = reduced(get_config("smollm-360m"), n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64)
    return build_model(cfg, remat=False, xent_chunk=8), cfg


def test_loss_decreases_on_learnable_data():
    model, cfg = tiny_model()
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
    rep = run_elastic_training(
        model, steps=30, data_cfg=data,
        opt_cfg=AdamWConfig(lr=1e-2, warmup_steps=3, total_steps=30),
        health_cfg=HealthConfig(target_step_time=1e9))
    first = np.mean(rep.losses[:5])
    last = np.mean(rep.losses[-5:])
    assert last < first - 0.1, (first, last)


def test_checkpoint_resume_bit_identical():
    """train(10) == train(5) + restore + train(5) — fault-tolerance contract."""
    model, cfg = tiny_model()
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    step_fn = jax.jit(make_train_step(model, opt))
    pipe = DataPipeline(data, cfg)

    s_a = init_train_state(model, jax.random.PRNGKey(0))
    for i in range(10):
        s_a, _ = step_fn(s_a, pipe.at(i))

    with tempfile.TemporaryDirectory() as d:
        s_b = init_train_state(model, jax.random.PRNGKey(0))
        for i in range(5):
            s_b, _ = step_fn(s_b, pipe.at(i))
        ck.save(d, s_b, 5, data_cursor=5)
        r = ck.restore(d, s_b)
        s_c, cursor = r["state"], r["data_cursor"]
        for i in range(cursor, 10):
            s_c, _ = step_fn(s_c, pipe.at(i))

    la = jax.tree_util.tree_leaves(s_a["params"])
    lc = jax.tree_util.tree_leaves(s_c["params"])
    for a, c in zip(la, lc):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_failure_injection_recovers():
    model, cfg = tiny_model()
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    with tempfile.TemporaryDirectory() as d:
        rep = run_elastic_training(
            model, steps=25, data_cfg=data, ckpt_dir=d,
            opt_cfg=AdamWConfig(warmup_steps=2, total_steps=25),
            health_cfg=HealthConfig(target_step_time=1e9),
            inject_failure_at=15)
        assert rep.restarts == 1
        assert rep.steps == 25
        assert all(np.isfinite(l) for l in rep.losses)


def test_adamw_moments_dtype():
    params = {"w": jnp.ones((4, 4))}
    opt = init_opt_state(params, jnp.bfloat16)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    c = AdamWConfig()
    grads = {"w": jnp.full((4, 4), 0.1)}
    new_p, new_opt, metrics = adamw_update(c, params, grads, opt,
                                           jnp.int32(50))  # warmed-up lr > 0
    assert new_opt["m"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(float(metrics["grad_norm"]))
    assert (np.asarray(new_p["w"]) != 1.0).all()


def test_schedule_warmup_and_decay():
    c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(schedule(c, jnp.int32(0))) == 0.0
    assert abs(float(schedule(c, jnp.int32(10))) - 1.0) < 1e-6
    assert float(schedule(c, jnp.int32(110))) <= 0.1 + 1e-6


def test_error_feedback_bounds_cumulative_error():
    g = {"a": jnp.linspace(-1, 1, 512)}
    res = init_residuals(g)
    acc_t = jnp.zeros(512)
    acc_c = jnp.zeros(512)
    for _ in range(40):
        dq, res, _ = compressed_grads(g, res)
        acc_t += g["a"]
        acc_c += dq["a"]
    assert float(jnp.abs(acc_t - acc_c).max()) < 0.05
