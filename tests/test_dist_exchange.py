"""Owner-keyed exchange core (compute-partitioned phase 4).

The bucket-sort distributed core re-homes each cloudlet to the member that
owns its VM with one padded all-to-all, and each member lexsorts + scans
only its own ~C/M cloudlets.  These tests pin the contract:

  * finish vectors BIT-identical to ``simulate_completion_scan`` across
    member counts {1, 2, 4, 8}, maximally-skewed ownership maps, explicit
    slack capacities, and a scale-out 1→2→4 / scale-in 4→2 sequence mid-run
    with entity sizes divisible by nothing;
  * capacity violations raise ``ExchangeCapacityError`` — loud, never a
    silently-truncated finish vector;
  * the compiled-core cache is LRU (hits move to the back), so long sweeps
    can't evict the hottest mesh.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import des_scan
from repro.core.des_scan import (ExchangeCapacityError, _pow2_ceil,
                                 simulate_completion_distributed,
                                 simulate_completion_scan)
from repro.core.executor import DistributedExecutor
from repro.core.partition import (exchange_block_size, exchange_load,
                                  pad_to_shards)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _case(rng, C=180, V=32):
    """Degenerate-heavy random case: invalid rows, dead VMs, zero lengths,
    duplicate lengths (sort-tie coverage)."""
    assign = rng.integers(0, V, C).astype(np.int32)
    mi = rng.uniform(1.0, 200.0, C).astype(np.float32)
    mi[rng.uniform(size=C) < 0.15] = 50.0          # ties within segments
    mi[rng.uniform(size=C) < 0.1] = 0.0
    mips = rng.uniform(5.0, 20.0, V).astype(np.float32)
    mips[rng.uniform(size=V) < 0.2] = 0.0
    valid = rng.uniform(size=C) < 0.85
    return (jnp.asarray(assign), jnp.asarray(mi), jnp.asarray(mips),
            jnp.asarray(valid))


def test_exchange_bitwise_vs_scan_single_member():
    """M=1 exchange (bucket + identity all-to-all + local scan) is already a
    full layout round-trip — it must be bit-identical, not just close."""
    rng = np.random.default_rng(11)
    ex = DistributedExecutor(mesh1())
    scan = jax.jit(simulate_completion_scan)
    for _ in range(8):
        args = _case(rng)
        f_ref, m_ref = scan(*args)
        f, m = simulate_completion_distributed(*args, ex)
        assert np.array_equal(np.asarray(f), np.asarray(f_ref))
        assert float(m) == float(m_ref)


def test_capacity_overflow_fails_loudly():
    rng = np.random.default_rng(3)
    args = _case(rng, C=64, V=8)
    ex = DistributedExecutor(mesh1())
    with pytest.raises(ExchangeCapacityError, match="block capacity 1"):
        simulate_completion_distributed(*args, ex, block=1)
    # ... and the auto capacity on the same inputs succeeds bit-exactly
    f_ref, _ = jax.jit(simulate_completion_scan)(*args)
    f, _ = simulate_completion_distributed(*args, ex)
    assert np.array_equal(np.asarray(f), np.asarray(f_ref))


def test_exchange_capacity_helpers():
    # balanced expectation × slack, clamped to the shard size
    assert exchange_block_size(80, 4, slack=2.0) == 10     # 20 * 2 / 4
    assert exchange_block_size(80, 4, slack=100.0) == 20   # ≤ shard
    assert exchange_block_size(1, 4, slack=0.1) == 1       # ≥ 1
    assert _pow2_ceil(1) == 1 and _pow2_ceil(3) == 4 and _pow2_ceil(8) == 8
    # exact owner histogram: 2 shards of 4, all VMs owned by member 1
    owner = np.array([1, 1], np.int32)
    assign = np.array([0, 1, 0, 1, 0, 0, 1, 1], np.int32)
    valid = np.array([1, 1, 1, 0, 1, 1, 1, 1], bool)
    load = exchange_load(owner, assign, valid, 2)
    assert load.shape == (2, 2)
    assert load[0].tolist() == [0, 3] and load[1].tolist() == [0, 4]
    # load.max() is exactly the block the exchange needs — on the 1-member
    # executor the requirement is the whole valid count (7) ...
    load1 = exchange_load(np.zeros(2, np.int32), assign, valid, 1)
    assert load1.tolist() == [[7]]
    args = (jnp.asarray(assign), jnp.ones(8) * 5.0, jnp.ones(2) * 10.0,
            jnp.asarray(valid))
    ex = DistributedExecutor(mesh1())
    f_ref, _ = jax.jit(simulate_completion_scan)(*args)
    f, _ = simulate_completion_distributed(
        *args, ex, vm_owner=np.zeros(2, np.int32), block=int(load1.max()))
    assert np.array_equal(np.asarray(f), np.asarray(f_ref))
    # ... and one less overflows loudly
    with pytest.raises(ExchangeCapacityError):
        simulate_completion_distributed(
            *args, ex, vm_owner=np.zeros(2, np.int32), block=6)


def test_dist_core_cache_is_lru(monkeypatch):
    """Regression: FIFO eviction used to evict the HOTTEST mesh during long
    grid sweeps; a hit must move the entry to the back."""
    monkeypatch.setattr(des_scan, "_DIST_CORE_CACHE_MAX", 2)
    des_scan.invalidate_dist_core()
    ex = DistributedExecutor(mesh1())
    rng = np.random.default_rng(0)

    def run(V):
        assign, mi, mips, valid = _case(rng, C=16, V=V)
        simulate_completion_distributed(assign, mi, mips, valid, ex, block=16)

    run(4)                                     # A
    key_a = next(iter(des_scan._DIST_CORE_CACHE))
    fn_a = des_scan._DIST_CORE_CACHE[key_a]
    run(8)                                     # B — cache is now full
    run(4)                                     # HIT A: moves A to the back
    run(16)                                    # C — evicts B (LRU), not A
    cache = des_scan._DIST_CORE_CACHE
    assert len(cache) == 2
    assert key_a in cache and cache[key_a] is fn_a
    assert {k[3] for k in cache} == {4, 16}    # V=8 (B) was evicted
    des_scan.invalidate_dist_core()


def test_reachable_member_counts():
    from repro.core.elastic import reachable_member_counts
    from repro.core.health import HealthConfig

    hc = HealthConfig(min_instances=1, max_instances=8)
    assert reachable_member_counts(hc, 1) == frozenset({1, 2, 4, 8})
    assert reachable_member_counts(hc, 3) == frozenset({1, 2, 3, 4, 6, 8})
    hc = HealthConfig(min_instances=2, max_instances=6)
    assert reachable_member_counts(hc, 2) == frozenset({2, 3, 4, 6})


def test_exchange_bit_identical_members_skew_and_slack():
    """Property sweep on 8 emulated members: random degenerate cases ×
    member counts {1,2,4,8} × ownership maps (balanced / all-on-first /
    all-on-last / random) × capacity modes (auto / generous slack) are ALL
    bit-identical to the single-member scan; an undersized slack on a
    maximally-skewed map fails loudly."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.des_scan import (ExchangeCapacityError,
                                 simulate_completion_distributed,
                                 simulate_completion_scan)
from repro.core.executor import DistributedExecutor

devs = jax.devices()
rng = np.random.default_rng(0)
scan = jax.jit(simulate_completion_scan)
C, V = 210, 48                                  # divisible by neither 4 nor 8
for case in range(3):
    assign = jnp.asarray(rng.integers(0, V, C).astype(np.int32))
    mi = np.asarray(rng.uniform(1.0, 200.0, C).astype(np.float32))
    mi[rng.uniform(size=C) < 0.15] = 50.0       # sort ties
    mi = jnp.asarray(mi)
    mips = np.asarray(rng.uniform(5.0, 20.0, V).astype(np.float32))
    mips[rng.uniform(size=V) < 0.2] = 0.0
    mips = jnp.asarray(mips)
    valid = jnp.asarray(rng.uniform(size=C) < 0.85)
    f_ref, m_ref = scan(assign, mi, mips, valid)
    f_ref, m_ref = np.asarray(f_ref), float(m_ref)
    for M in (1, 2, 4, 8):
        ex = DistributedExecutor(Mesh(np.array(devs[:M]), ("data",)))
        owners = [None, np.zeros(V, np.int32), np.full(V, M - 1, np.int32),
                  rng.integers(0, M, V).astype(np.int32)]
        for oi, owner in enumerate(owners):
            for kw in ({}, {"slack": float(M)}):
                f, m = simulate_completion_distributed(
                    assign, mi, mips, valid, ex, vm_owner=owner, **kw)
                assert np.array_equal(np.asarray(f), f_ref), (case, M, oi, kw)
                assert float(m) == m_ref, (case, M, oi, kw)
# undersized slack on a maximally-skewed map: loud, not silent
ex = DistributedExecutor(Mesh(np.array(devs[:8]), ("data",)))
try:
    simulate_completion_distributed(assign, mi, mips, valid, ex,
                                    vm_owner=np.zeros(V, np.int32), slack=1.0)
    raise SystemExit("expected ExchangeCapacityError")
except ExchangeCapacityError:
    pass
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_elastic_autopad_nondivisible_entities():
    """Auto-padding satellite: the cluster pads entity sizes to the LCM of
    reachable member counts, so a cfg divisible by NOTHING stays bit-stable
    across scale-out 1→2→4 and scale-in 4→2 — and matches a fixed 1-member
    scan run at the same padded shapes."""
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import dataclasses
import jax, numpy as np
from jax.sharding import Mesh
from repro.core.cloudsim import (ElasticSimulationCluster, SimulationConfig,
                                 run_simulation)
from repro.core.health import HealthConfig

devs = jax.devices()
cfg = SimulationConfig(n_vms=41, n_cloudlets=83, broker="matchmaking",
                       core="scan_dist")                  # prime-ish sizes
hc = HealthConfig(target_step_time=1.0, max_threshold=0.8, min_threshold=0.2,
                  time_between_scaling=1, window=1, max_instances=4)
cl = ElasticSimulationCluster(devices=devs, health_cfg=hc, start_members=1)
assert cl.entity_pad == 4, cl.entity_pad

# fixed-mesh oracle at the SAME padded shapes the cluster uses
fixed = run_simulation(dataclasses.replace(cfg, core="scan"),
                       Mesh(np.array(devs[:1]), ("data",)),
                       pad_multiple=cl.entity_pad)
ref = fixed.finish_times[:cfg.n_cloudlets]

results = [cl.simulate(cfg)]
for load, expect in [(2.0, 2), (2.0, 4), (0.05, 2)]:
    cl.observe_load(load)
    assert cl.n_members == expect, (cl.n_members, expect)
    results.append(cl.simulate(cfg))
for i, r in enumerate(results):
    assert r.finish_times.shape == (cfg.n_cloudlets,), r.finish_times.shape
    assert np.array_equal(r.finish_times, ref), i
    assert r.makespan == fixed.makespan, i
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr
