"""Roofline HLO parser: exact FLOP counting through scan loops (the
cost_analysis while-body-once correction), collective byte accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlo_parse import analyze, parse_module


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jnp.ones((64, 32))
    ws = jnp.ones((8, 32, 32))
    txt = _compile_text(f, x, ws)
    assert "known_trip_count" in txt
    c = analyze(txt)
    assert c.flops == 2 * 64 * 32 * 32 * 8          # trip-corrected, exact


def test_unrolled_matches_scan():
    def f_scan(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y.sum()

    def f_unroll(x, ws):
        for i in range(4):
            x = x @ ws[i]
        return x.sum()

    x = jnp.ones((16, 16))
    ws = jnp.ones((4, 16, 16))
    c1 = analyze(_compile_text(f_scan, x, ws))
    c2 = analyze(_compile_text(f_unroll, x, ws))
    assert c1.flops == c2.flops == 2 * 16 * 16 * 16 * 4


def test_nested_scans_multiply():
    def f(x, ws):
        def outer(c, _):
            def inner(ci, w):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, ws)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y.sum()

    x = jnp.ones((8, 8))
    ws = jnp.ones((5, 8, 8))
    c = analyze(_compile_text(f, x, ws))
    assert c.flops == 2 * 8 * 8 * 8 * 5 * 3


def test_dot_general_batched_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b).sum()

    a = jnp.ones((4, 8, 16))
    b = jnp.ones((4, 16, 32))
    c = analyze(_compile_text(f, a, b))
    assert c.flops == 2 * 4 * 8 * 16 * 32


def test_parse_module_finds_computations():
    def f(x):
        return jnp.tanh(x @ x.T).sum()
    txt = _compile_text(f, jnp.ones((32, 32)))
    comps = parse_module(txt)
    assert comps and sum(len(v) for v in comps.values()) > 0
    ndots = sum(1 for v in comps.values() for i in v if i.opcode == "dot")
    assert ndots == txt.count(" dot(")
