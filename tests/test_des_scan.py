"""Closed-form segmented-scan DES core vs the wave-loop oracle.

The scan core must be numerically equivalent (atol 1e-3; an rtol of 1e-5
covers f32 rounding on large finish-time magnitudes, where the *oracle's*
sequential `now` accumulation itself drifts by ~eps·|t|·√waves).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core.cloudsim import (SimulationConfig, run_simulation,
                                 simulate_completion)
from repro.core.des_scan import (make_scenario_grid, run_scenario_grid,
                                 run_simulation_batch,
                                 simulate_completion_distributed,
                                 simulate_completion_scan)
from repro.core.executor import DistributedExecutor
from repro.kernels.seg_scan.kernel import seg_cumsum
from repro.kernels.seg_scan.ref import seg_cumsum_ref

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


def _random_case(rng, C_max=150, V_max=24, fixed_shape=False):
    """Randomized config with the degenerate cases mixed in: invalid padding
    rows, zero-MIPS padded VMs, zero-length cloudlets, empty VMs (V > used),
    single-cloudlet VMs (C can be < V).  ``fixed_shape`` keeps (C, V) static
    so shape-specialized paths (shard_map) compile once."""
    C = C_max if fixed_shape else int(rng.integers(1, C_max))
    V = V_max if fixed_shape else int(rng.integers(1, V_max))
    assign = rng.integers(0, V, C).astype(np.int32)
    mi = rng.uniform(1.0, 200.0, C).astype(np.float32)
    mips = rng.uniform(5.0, 20.0, V).astype(np.float32)
    valid = rng.uniform(size=C) < 0.8
    mips[rng.uniform(size=V) < 0.2] = 0.0
    mi[rng.uniform(size=C) < 0.1] = 0.0
    return (jnp.asarray(assign), jnp.asarray(mi), jnp.asarray(mips),
            jnp.asarray(valid))


def _assert_matches_oracle(core_fn, n_cases=25, seed=0, fixed_shape=False,
                           **tol):
    tol = tol or dict(atol=1e-3, rtol=1e-5)
    rng = np.random.default_rng(seed)
    wave = jax.jit(simulate_completion)
    for _ in range(n_cases):
        args = _random_case(rng, fixed_shape=fixed_shape)
        f1, m1 = wave(*args)
        f2, m2 = core_fn(*args)
        np.testing.assert_allclose(np.asarray(f2), np.asarray(f1), **tol)
        np.testing.assert_allclose(float(m2), float(m1), **tol)


def test_scan_matches_wave_randomized():
    _assert_matches_oracle(jax.jit(simulate_completion_scan))


def test_scan_known_closed_form():
    # equal lengths share fairly: both finish at 2x serial time
    f, m = jax.jit(simulate_completion_scan)(
        jnp.array([0, 0], jnp.int32), jnp.array([100.0, 100.0]),
        jnp.array([10.0]), jnp.array([True, True]))
    np.testing.assert_allclose(np.asarray(f), [20.0, 20.0], rtol=1e-5)
    # the shorter one frees capacity for the longer one
    f, m = jax.jit(simulate_completion_scan)(
        jnp.array([0, 0], jnp.int32), jnp.array([100.0, 200.0]),
        jnp.array([10.0]), jnp.array([True, True]))
    np.testing.assert_allclose(np.asarray(f), [20.0, 30.0], rtol=1e-5)
    np.testing.assert_allclose(float(m), 30.0, rtol=1e-5)


def test_scan_degenerate_cases():
    scan = jax.jit(simulate_completion_scan)
    # all-invalid padding rows -> everything 0
    f, m = scan(jnp.array([0, 1], jnp.int32), jnp.array([100.0, 200.0]),
                jnp.array([10.0, 10.0]), jnp.array([False, False]))
    assert np.asarray(f).tolist() == [0.0, 0.0] and float(m) == 0.0
    # zero-MIPS (padded) VM: its cloudlets never run, finish stays 0
    f, m = scan(jnp.array([0, 1], jnp.int32), jnp.array([100.0, 200.0]),
                jnp.array([10.0, 0.0]), jnp.array([True, True]))
    np.testing.assert_allclose(np.asarray(f), [10.0, 0.0], rtol=1e-5)
    np.testing.assert_allclose(float(m), 10.0, rtol=1e-5)
    # single cloudlet per VM, plus empty VMs
    f, m = scan(jnp.array([0, 3], jnp.int32), jnp.array([100.0, 30.0]),
                jnp.array([10.0, 10.0, 10.0, 10.0]),
                jnp.array([True, True]))
    np.testing.assert_allclose(np.asarray(f), [10.0, 3.0], rtol=1e-5)
    # zero-length cloudlet neither runs nor inflates sharer counts
    f, m = scan(jnp.array([0, 0], jnp.int32), jnp.array([0.0, 100.0]),
                jnp.array([10.0]), jnp.array([True, True]))
    np.testing.assert_allclose(np.asarray(f), [0.0, 10.0], rtol=1e-5)


def test_kernel_path_matches_jnp():
    # the Pallas segmented-cumsum (interpret mode off-TPU) == the jnp rebase
    rng = np.random.default_rng(3)
    for C in (1, 7, 130, 700):
        term = jnp.asarray(rng.uniform(0, 5, C).astype(np.float32))
        reset = jnp.asarray((rng.uniform(size=C) < 0.1).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(seg_cumsum(term, reset, interpret=True)),
            np.asarray(seg_cumsum_ref(term, reset)), atol=1e-3, rtol=1e-5)
    # ... and the full scan core with use_kernel=True matches the oracle
    _assert_matches_oracle(
        jax.jit(lambda *a: simulate_completion_scan(
            *a, use_kernel=True, interpret=True)), n_cases=8, seed=4)


def test_distributed_matches_oracle():
    ex = DistributedExecutor(mesh1())
    _assert_matches_oracle(
        lambda *a: simulate_completion_distributed(*a, ex), n_cases=6, seed=5,
        fixed_shape=True)


def test_distributed_identical_across_member_counts():
    # phase 4 on 1/2/4 members is BIT-identical (thesis accuracy claim): the
    # PartitionTable ownership map only masks disjoint output partials
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import jax, numpy as np
from jax.sharding import Mesh
from repro.core.cloudsim import SimulationConfig, run_simulation
import dataclasses
devs = jax.devices()
cfg = SimulationConfig(n_vms=40, n_cloudlets=80, broker="matchmaking",
                       core="scan_dist")
base = None
for n in (1, 2, 4):
    r = run_simulation(cfg, Mesh(np.array(devs[:n]), ("data",)))
    if base is None:
        base = r
    else:
        assert np.array_equal(base.vm_assign, r.vm_assign)
        assert np.array_equal(base.finish_times, r.finish_times), n
        assert base.makespan == r.makespan, n
# ... and bit-identical to the single-device scan core itself
s = run_simulation(dataclasses.replace(cfg, core="scan"),
                   Mesh(np.array(devs[:1]), ("data",)))
assert np.array_equal(base.finish_times, s.finish_times)
# and the distributed core equals the wave oracle on the same entities
w = run_simulation(dataclasses.replace(cfg, core="wave"),
                   Mesh(np.array(devs[:1]), ("data",)))
np.testing.assert_allclose(base.finish_times, w.finish_times,
                           atol=1e-3, rtol=1e-5)
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.parametrize("core", ["scan", "wave", "scan_dist"])
def test_run_simulation_core_dispatch(core):
    cfg = SimulationConfig(n_vms=20, n_cloudlets=40, core=core)
    r1 = run_simulation(cfg, mesh1())
    r2 = run_simulation(cfg, mesh1())
    assert np.array_equal(r1.vm_assign, r2.vm_assign)
    np.testing.assert_allclose(r1.finish_times, r2.finish_times)
    assert r1.makespan > 0


def test_run_simulation_batch_32_scenarios_one_jit():
    cfg = SimulationConfig(n_vms=32, n_cloudlets=200, broker="matchmaking")
    r = run_simulation_batch(cfg, np.arange(32),
                             mi_scale=np.linspace(0.5, 2.0, 32))
    assert r.n_scenarios == 32
    assert r.finish_times.shape == (32, 200)
    assert (r.makespans > 0).all()
    # scenarios genuinely differ (different seeds + length scales) ...
    assert len(np.unique(r.makespans)) > 16
    # ... and the sweep is deterministic
    r2 = run_simulation_batch(cfg, np.arange(32),
                              mi_scale=np.linspace(0.5, 2.0, 32))
    np.testing.assert_array_equal(r.makespans, r2.makespans)
    # per-scenario invariant: makespan is the max finish time
    np.testing.assert_allclose(r.makespans, r.finish_times.max(axis=1),
                               rtol=1e-6)
    # every assignment respects the VM table
    assert (r.vm_assign >= 0).all() and (r.vm_assign < 32).all()


def test_batch_grid_multi_axis_one_jit():
    """A ≥96-variant MIXED-SHAPE grid (seeds × scale × broker × VM-count ×
    cloudlet-count × MIPS-distribution) in a single jitted vmap, with exact
    shape-padding semantics."""
    cfg = SimulationConfig(n_vms=16, n_cloudlets=120, broker="matchmaking")
    grid = make_scenario_grid(
        seeds=range(2), mi_scales=[0.5, 2.0],
        brokers=["round_robin", "matchmaking"], vm_counts=[8, 16],
        cloudlet_counts=[60, 120], mips_dists=["uniform", "fixed", "bimodal"])
    B = len(grid["seeds"])
    assert B >= 96
    r = run_scenario_grid(cfg, grid)
    assert r.n_scenarios == B
    assert r.finish_times.shape == (B, 120)
    assert (r.makespans > 0).all()
    for b in range(B):
        nc, nv = int(r.n_cloudlets[b]), int(r.n_vms[b])
        # padded cloudlet rows keep finish time EXACTLY 0 ...
        assert (r.finish_times[b, nc:] == 0.0).all(), b
        # ... live rows all finish, and no broker binds to a padded VM
        assert (r.finish_times[b, :nc] > 0.0).all(), b
        assert (r.vm_assign[b] >= 0).all() and (r.vm_assign[b] < nv).all(), b
    # the axes genuinely vary the outcome
    assert len(np.unique(r.makespans)) > B // 2
    # determinism across re-dispatch
    r2 = run_scenario_grid(cfg, grid)
    np.testing.assert_array_equal(r.makespans, r2.makespans)
    # oversized live counts are rejected, not silently gather-clamped
    with pytest.raises(ValueError):
        run_simulation_batch(cfg, np.arange(2), n_vms=[32, 16])
    with pytest.raises(ValueError):
        run_simulation_batch(cfg, np.arange(2), n_cloudlets=[200, 64])


def test_batch_grid_matches_unbatched_scan():
    """Every grid variant equals an UNBATCHED simulate_completion_scan run on
    the same (padded) entities + broker decision — vmap adds nothing."""
    from repro.core.cloudsim import matchmaking_assign_masked
    from repro.core.des_scan import grid_scenario_inputs

    cfg = SimulationConfig(n_vms=12, n_cloudlets=64)
    grid = make_scenario_grid(seeds=[3, 7], mi_scales=[0.7, 1.3],
                              brokers=["round_robin", "matchmaking"],
                              vm_counts=[5, 12], cloudlet_counts=[40, 64],
                              mips_dists=["uniform", "bimodal"])
    r = run_scenario_grid(cfg, grid)
    for b in range(0, r.n_scenarios, 3):       # every 3rd variant
        vm_mips, vm_valid, mi, valid = grid_scenario_inputs(
            cfg, int(grid["seeds"][b]), float(grid["mi_scale"][b]),
            int(r.n_vms[b]), int(r.n_cloudlets[b]), int(r.mips_dist[b]))
        ids = jnp.arange(cfg.n_cloudlets, dtype=jnp.int32)
        if int(r.broker[b]) == 0:
            assign = (ids % int(r.n_vms[b])).astype(jnp.int32)
        else:
            assign = matchmaking_assign_masked(ids, mi, vm_mips, vm_valid)
        f, m = simulate_completion_scan(assign, mi, vm_mips, valid)
        np.testing.assert_array_equal(r.vm_assign[b], np.asarray(assign))
        np.testing.assert_allclose(r.finish_times[b], np.asarray(f),
                                   rtol=1e-6, atol=0)


def test_batch_grid_topology_and_loaded_axes():
    """The datacenter-topology and ``is_loaded`` axes (ROADMAP): topology 0
    is a bit-exact no-op, topologies genuinely change outcomes, the workload
    checksum is nonzero exactly for loaded variants, and shape padding keeps
    padded cloudlets at finish EXACTLY 0 under both axes."""
    cfg = SimulationConfig(n_vms=12, n_cloudlets=64, workload_dim=4,
                           workload_iters_per_gmi=0.02)
    grid = make_scenario_grid(seeds=[3, 9], cloudlet_counts=[40, 64],
                              dc_counts=[0, 2, 5], loaded=[0, 1])
    r = run_scenario_grid(cfg, grid)
    B = r.n_scenarios
    assert B == 2 * 2 * 3 * 2
    # flat (0) topology == the axis-free grid, bitwise
    flat = np.asarray(grid["n_datacenters"]) == 0
    base = make_scenario_grid(seeds=[3, 9], cloudlet_counts=[40, 64])
    r0 = run_scenario_grid(cfg, base)
    np.testing.assert_array_equal(r.finish_times[flat],
                                  np.repeat(r0.finish_times, 2, axis=0))
    # differing topologies genuinely change makespans
    m2 = r.makespans[np.asarray(grid["n_datacenters"]) == 2]
    m5 = r.makespans[np.asarray(grid["n_datacenters"]) == 5]
    assert not np.array_equal(m2, m5)
    # workload checksum: nonzero iff loaded; finish times untouched by it
    loaded = np.asarray(grid["is_loaded"]) == 1
    assert (r.workload_checksum[~loaded] == 0.0).all()
    assert (r.workload_checksum[loaded] != 0.0).all()
    np.testing.assert_array_equal(r.finish_times[loaded],
                                  r.finish_times[~loaded])
    # padded rows keep finish exactly 0 under every axis combination
    for b in range(B):
        nc = int(r.n_cloudlets[b])
        assert (r.finish_times[b, nc:] == 0.0).all(), b
        assert (r.finish_times[b, :nc] > 0.0).all(), b
    # axis bounds are validated, not silently clamped
    with pytest.raises(ValueError):
        run_simulation_batch(cfg, np.arange(2), n_datacenters=[0, 99])
    with pytest.raises(ValueError):
        run_simulation_batch(cfg, np.arange(2), is_loaded=[0, 2])


def test_batch_grid_sharded_across_members():
    # the multi-member batched path (scenario vmap inside the partitioned
    # member_fn) matches the single-member batch, including the B % n pad
    env = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import jax, numpy as np
from jax.sharding import Mesh
from repro.core.cloudsim import SimulationConfig
from repro.core.des_scan import make_scenario_grid, run_scenario_grid
from repro.core.executor import DistributedExecutor
devs = jax.devices()
cfg = SimulationConfig(n_vms=16, n_cloudlets=96, broker="matchmaking")
grid = make_scenario_grid(seeds=range(5), brokers=["matchmaking"],
                          vm_counts=[8, 16], mips_dists=["bimodal"])
assert len(grid["seeds"]) % 4 != 0        # exercises the pad-to-shard path
r1 = run_scenario_grid(cfg, grid)
ex = DistributedExecutor(Mesh(np.array(devs), ("data",)))
r2 = run_scenario_grid(cfg, grid, executor=ex)
assert np.array_equal(r1.finish_times, r2.finish_times)
assert np.array_equal(r1.makespans, r2.makespans)
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_scan_matches_wave_100k_cloudlets():
    """The full-scale equivalence run: the scan on 100k cloudlets against the
    wave-loop oracle run in f64 (dtype-generic under enable_x64), so the
    tolerance measures ONLY the scan's own f32 error, not the oracle's
    sequential f32 drift (~eps·|t|·√waves) it used to include.

    The oracle replays the cloudlets of VMs [0, 64) only: time-shared VMs
    are mutually independent (each VM's rate depends only on its own active
    count — the same property the distributed core partitions on), so the
    wave loop on that projection yields the EXACT finish times for those
    ~12.5k cloudlets at the full 100k per-segment length distribution, while
    the full-problem f64 replay would be O(waves×C×V) ≈ hours of CPU (the
    f32 version was already a ~46-min extrapolated lower bound in
    BENCH_core.json).  The scan still runs on the full 100k problem."""
    from jax.experimental import enable_x64

    rng = np.random.default_rng(0)
    C, V, V_ORACLE = 100_000, 512, 64
    assign = rng.integers(0, V, C).astype(np.int32)
    mi64 = rng.uniform(1e3, 5e4, C)
    mips64 = rng.uniform(500, 2000, V)
    valid = np.ones(C, bool)

    sub = assign < V_ORACLE                       # the oracle's projection
    with enable_x64():
        f1, _ = jax.jit(simulate_completion)(
            jnp.asarray(assign[sub]), jnp.asarray(mi64[sub], jnp.float64),
            jnp.asarray(mips64[:V_ORACLE], jnp.float64),
            jnp.asarray(valid[sub]))
        f1 = np.asarray(f1)
    assert f1.dtype == np.float64 and f1.shape[0] > 10_000

    f2, m2 = jax.jit(simulate_completion_scan)(
        jnp.asarray(assign), jnp.asarray(mi64.astype(np.float32)),
        jnp.asarray(mips64.astype(np.float32)), jnp.asarray(valid))
    f2 = np.asarray(f2)
    np.testing.assert_allclose(f2[sub], f1, atol=1e-4, rtol=1e-5)
    # makespan is the max finish; validate the invariant on the full scan
    np.testing.assert_allclose(float(m2), f2.max(), rtol=1e-6)
