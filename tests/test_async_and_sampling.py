"""Async checkpointing and decode sampling."""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.step import sample_tokens
from repro.train import checkpoint as ck
from repro.train.async_ckpt import AsyncCheckpointer


def test_async_checkpoint_roundtrip():
    state = {"w": jnp.arange(32.0).reshape(4, 8), "step": jnp.int32(3)}
    with tempfile.TemporaryDirectory() as d:
        acp = AsyncCheckpointer(d)
        acp.save(state, step=3, data_cursor=30)
        acp.save(state, step=4, data_cursor=40)
        acp.wait()
        assert ck.latest_step(d) == 4
        r = ck.restore(d, state)
        np.testing.assert_array_equal(np.asarray(r["state"]["w"]),
                                      np.asarray(state["w"]))
        assert r["data_cursor"] == 40
        acp.close()


def test_async_checkpoint_nonblocking():
    state = {"w": jnp.zeros((256, 256))}
    with tempfile.TemporaryDirectory() as d:
        acp = AsyncCheckpointer(d)
        t0 = time.perf_counter()
        acp.save(state, step=1)
        enqueue_s = time.perf_counter() - t0
        acp.wait()
        acp.close()
        assert enqueue_s < 2.0      # snapshot only; write happens off-thread
        assert ck.latest_step(d) == 1


def test_sampling_greedy_and_temperature():
    logits = jnp.array([[0.0, 5.0, 1.0, -2.0]])
    key = jax.random.PRNGKey(0)
    assert int(sample_tokens(logits, key, temperature=0.0)[0]) == 1
    # low temperature concentrates on the argmax
    hits = [int(sample_tokens(logits, jax.random.fold_in(key, i),
                              temperature=0.1)[0]) for i in range(16)]
    assert all(h == 1 for h in hits)


def test_sampling_top_k_restricts_support():
    logits = jnp.array([[1.0, 5.0, 4.0, -2.0]])
    key = jax.random.PRNGKey(1)
    draws = {int(sample_tokens(logits, jax.random.fold_in(key, i),
                               temperature=2.0, top_k=2)[0])
             for i in range(64)}
    assert draws <= {1, 2}


def test_sampling_top_p_restricts_support():
    # p(1)=.88 p(2)=.12 others ~0: top_p=0.5 -> only token 1 survives
    logits = jnp.array([[0.0, 10.0, 8.0, -10.0]])
    key = jax.random.PRNGKey(2)
    draws = {int(sample_tokens(logits, jax.random.fold_in(key, i),
                               temperature=1.0, top_p=0.5)[0])
             for i in range(32)}
    assert draws == {1}
