"""Fault-tolerant dispatch: injection, detection, retry, member recovery.

Fast deterministic tier-1 coverage of every fault kind once (PR acceptance),
the member-killed-at-EVERY-chunk-index bit-identical-replay acceptance test
(subprocess, 8 fake devices), the two satellite bugfix regressions
(non-pow2 deterministic chunk warning; failure-path calibration reset), and
a slow-marked hypothesis chaos test over randomized fault schedules.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.dispatch import (DispatchJob, ElasticDispatcher,
                                 NonPow2ChunkWarning)
from repro.core.faults import (FAULT_KINDS, CompileFailedError, FaultInjector,
                               FaultSpec, JobFailedError, MemberFailedError,
                               RetryPolicy)
from repro.core.health import HealthConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _job():
    return DispatchJob(name="affine", signature="affine",
                       member_fn=lambda x, v, w: x * w + 1.0,
                       reduce="concat")


def _items(n=32):
    return np.arange(n * 2, dtype=np.float32).reshape(n, 2)


def _ref(items, w):
    return np.asarray(items) * w + 1.0


# ---------------------------------------------------------------- unit layer

def test_fault_spec_and_policy_validation():
    with pytest.raises(ValueError):
        FaultSpec(kind="meteor_strike", chunk=0)
    with pytest.raises(ValueError):
        FaultSpec(kind="stall", chunk=-1)
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(chunk_timeout_s=0.0)
    assert not RetryPolicy().active
    assert RetryPolicy(chunk_timeout_s=1.0).active
    assert RetryPolicy(check_finite=True).active
    p = RetryPolicy(backoff_s=0.1, backoff_factor=2.0)
    assert p.backoff_for(1) == pytest.approx(0.1)
    assert p.backoff_for(3) == pytest.approx(0.4)


def test_random_schedule_is_reproducible():
    a = FaultInjector.random_schedule(seed=7, n_chunks=10, max_members=4,
                                      n_faults=5)
    b = FaultInjector.random_schedule(seed=7, n_chunks=10, max_members=4,
                                      n_faults=5)
    assert [vars(s) for s in a.schedule] == [vars(s) for s in b.schedule]
    c = FaultInjector.random_schedule(seed=8, n_chunks=10, max_members=4,
                                      n_faults=5)
    assert [vars(s) for s in a.schedule] != [vars(s) for s in c.schedule]
    for s in a.schedule:
        assert s.kind in FAULT_KINDS and 0 <= s.chunk < 10


def test_random_schedule_fired_log_deterministic_across_runs():
    """Two FULL dispatcher runs under the same seeded schedule produce an
    IDENTICAL ``fired`` log (same kinds, chunks, members, stall delays, in
    the same order) with bit-identical outputs; a different seed yields a
    different schedule.  Member crashes are excluded: a 1-device pool can't
    drop a member, and their recovery path is covered elsewhere."""
    job, items, w = _job(), _items(), np.float32(2.0)
    kinds = ("nan_poison", "stall", "compile_fail")

    def run(seed):
        inj = FaultInjector.random_schedule(
            seed=seed, n_chunks=8, max_members=1, n_faults=4, kinds=kinds,
            stall_delay_s=0.01)
        d = ElasticDispatcher(
            start_members=1, dispatch_ahead=2, fault_injector=inj,
            retry_policy=RetryPolicy(max_attempts=6, check_finite=True))
        out, _ = d.submit(job, items, replicated=(w,), chunk=4,
                          deliver="host")
        return np.asarray(out), inj.fired

    out_a, fired_a = run(11)
    out_b, fired_b = run(11)
    assert fired_a == fired_b and fired_a      # full-run log is reproducible
    assert out_a.tobytes() == out_b.tobytes()
    np.testing.assert_array_equal(out_a, _ref(items, 2.0))
    # stall entries carry the injected latency for cross-checking against
    # the collector's stall histogram
    for f in fired_a:
        if f["kind"] == "stall":
            assert f["delay_s"] == pytest.approx(0.01)
        else:
            assert "delay_s" not in f
    _, fired_c = run(12)
    assert fired_a != fired_c                  # seeds differentiate schedules


def test_injector_hooks_fire_once_and_log():
    inj = FaultInjector([FaultSpec("compile_fail", chunk=2)])
    inj.on_compile(0)                      # wrong chunk: no fire
    with pytest.raises(CompileFailedError):
        inj.on_compile(2)
    inj.on_compile(2)                      # consumed: fires once
    assert inj.fired == [{"kind": "compile_fail", "chunk": 2, "member": None}]
    assert inj.pending() == {}

    import jax
    inj2 = FaultInjector([FaultSpec("member_crash", chunk=1, member=0)])
    devs = jax.devices()[:1]
    inj2.on_launch(0, devs)
    with pytest.raises(MemberFailedError):
        inj2.on_launch(1, devs)
    # the dead member keeps failing launches until retired from the mesh
    with pytest.raises(MemberFailedError):
        inj2.on_launch(2, devs)


# ---------------------------------------- one fast deterministic test / kind

def test_nan_poison_detected_retried_bit_identical():
    job, items, w = _job(), _items(), np.float32(2.0)
    d0 = ElasticDispatcher(start_members=1, dispatch_ahead=0)
    ref, _ = d0.submit(job, items, replicated=(w,), chunk=4, deliver="host")
    np.testing.assert_array_equal(np.asarray(ref), _ref(items, 2.0))

    inj = FaultInjector([FaultSpec("nan_poison", chunk=2, member=0)])
    d = ElasticDispatcher(start_members=1, dispatch_ahead=2,
                          fault_injector=inj)
    out, rep = d.submit(job, items, replicated=(w,), chunk=4, deliver="host")
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    assert [f["kind"] for f in rep.failures] == ["nan_poison"]
    assert rep.failures[0]["chunk"] == 2 and rep.failures[0]["member"] == 0
    assert "recovered_after_s" in rep.failures[0]
    assert rep.retries == 1 and d.in_flight == 0
    # the detector monitor logged the non-finite sample (health.py's own
    # "member crash" signal, finally wired in)
    assert any("NON-FINITE" in e for e in d.fault_monitor.events)


def test_stall_deadline_detected_and_replayed():
    job, items, w = _job(), _items(), np.float32(3.0)
    inj = FaultInjector([FaultSpec("stall", chunk=1, member=0, delay_s=0.8)])
    d = ElasticDispatcher(start_members=1, dispatch_ahead=2,
                          fault_injector=inj,
                          retry_policy=RetryPolicy(chunk_timeout_s=0.6,
                                                   quarantine_after=0))
    # prewarm so genuine compile walls don't trip the tight deadline
    d.submit(job, items, replicated=(w,), chunk=4, deliver="host",
             fault_injector=FaultInjector())
    out, rep = d.submit(job, items, replicated=(w,), chunk=4, deliver="host")
    np.testing.assert_array_equal(np.asarray(out), _ref(items, 3.0))
    stalls = [f for f in rep.failures if f["kind"] == "stall"]
    assert stalls and stalls[0]["chunk"] == 1
    assert stalls[0]["wall_s"] > 0.6
    assert d.in_flight == 0


def test_compile_fail_retried():
    job, items, w = _job(), _items(), np.float32(1.5)
    inj = FaultInjector([FaultSpec("compile_fail", chunk=0)])
    d = ElasticDispatcher(start_members=1, dispatch_ahead=2,
                          fault_injector=inj)
    out, rep = d.submit(job, items, replicated=(w,), chunk=4, deliver="host")
    np.testing.assert_array_equal(np.asarray(out), _ref(items, 1.5))
    assert [f["kind"] for f in rep.failures] == ["compile_fail"]
    assert inj.pending() == {}


def test_attempts_exhausted_raises_jobfailed_with_report_and_reusable():
    job, items, w = _job(), _items(), np.float32(2.0)
    inj = FaultInjector([FaultSpec("nan_poison", chunk=1, times=10)])
    d = ElasticDispatcher(start_members=1, dispatch_ahead=2,
                          fault_injector=inj,
                          retry_policy=RetryPolicy(max_attempts=3,
                                                   quarantine_after=0,
                                                   check_finite=True))
    with pytest.raises(JobFailedError) as exc:
        d.submit(job, items, replicated=(w,), chunk=4, deliver="host")
    rep = exc.value.report
    assert len(rep.failures) == 3 and rep.retries == 2
    assert all(f["chunk"] == 1 for f in rep.failures)
    assert d.in_flight == 0
    # drained and reusable: a clean stream on the same dispatcher succeeds
    out, rep2 = d.submit(job, items, replicated=(w,), chunk=4, deliver="host",
                         fault_injector=FaultInjector())
    np.testing.assert_array_equal(np.asarray(out), _ref(items, 2.0))
    assert rep2.failures == []


def test_check_finite_catches_natural_nan_without_injector():
    """The detector is not injection-only: a job that genuinely emits NaN
    trips the same guarded path under a bare RetryPolicy."""
    bad = DispatchJob(name="bad", signature="bad",
                      member_fn=lambda x, v, *_: x / 0.0 * 0.0,  # NaN rows
                      reduce="concat")
    d = ElasticDispatcher(start_members=1, dispatch_ahead=2,
                          retry_policy=RetryPolicy(max_attempts=2,
                                                   quarantine_after=0,
                                                   check_finite=True))
    with pytest.raises(JobFailedError) as exc:
        d.submit(bad, _items(8), chunk=4, deliver="host")
    assert exc.value.report.failures
    assert exc.value.report.failures[0]["kind"] == "nan_poison"
    assert d.in_flight == 0


# ------------------------------------------------------ satellite regressions

def test_non_pow2_deterministic_chunk_warns():
    job = DispatchJob(name="det", signature="det2", reduce="sum",
                      deterministic=True, member_fn=lambda x, v, *_: x)
    d = ElasticDispatcher(start_members=1)
    x = np.ones((10, 3), np.float32)
    with pytest.warns(NonPow2ChunkWarning):
        d.submit(job, x, chunk=3)
    # pow2 chunkings and single-chunk streams stay silent
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", NonPow2ChunkWarning)
        d.submit(job, x, chunk=4)
        d.submit(job, x, chunk=10)         # one chunk: nothing to cross


def test_failure_resets_self_calibrated_target_not_explicit():
    """Regression (satellite): a failing stream's compile/retry-inflated
    self-calibration must not leak into the next stream's IAS target;
    explicit calibrate_target pins survive."""
    job, items = _job(), _items(8)
    d = ElasticDispatcher(start_members=1, auto_scale=True, dispatch_ahead=0)

    def boom(disp, ci, n):
        if ci == 1:
            raise RuntimeError("boom")

    with pytest.raises(RuntimeError):
        d.submit(job, items, replicated=(np.float32(1.0),), chunk=2,
                 on_chunk=boom)
    assert job.signature not in d.job_targets   # poisoned calibration dropped
    assert d.in_flight == 0

    d.calibrate_target(job, 123.0)
    with pytest.raises(RuntimeError):
        d.submit(job, items, replicated=(np.float32(1.0),), chunk=2,
                 on_chunk=boom)
    assert d.job_targets[job.signature] == 123.0   # explicit pin survives

    # JobFailedError takes the same reset path
    d2 = ElasticDispatcher(start_members=1, auto_scale=True, dispatch_ahead=2,
                           fault_injector=FaultInjector(
                               [FaultSpec("nan_poison", chunk=0, times=10)]),
                           retry_policy=RetryPolicy(max_attempts=2,
                                                    quarantine_after=0,
                                                    check_finite=True))
    with pytest.raises(JobFailedError):
        d2.submit(job, items, replicated=(np.float32(1.0),), chunk=2)
    assert job.signature not in d2.job_targets


# ------------------------------------------------- member failure (multi-dev)

def test_member_crash_recovery_bit_identical_every_chunk_index():
    """THE acceptance test: a member killed at EVERY chunk index of an
    8-chunk async stream (dispatch_ahead=2) riding a 1→2→4→2 scale
    sequence recovers — forced failure remesh onto the survivors, lost
    in-flight chunks replayed — with results bit-identical to the
    fault-free synchronous path; and when the survivors can't carry the
    job, JobFailedError is raised and the dispatcher stays reusable."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import numpy as np, jax, jax.numpy as jnp
from repro.core.dispatch import DispatchJob, ElasticDispatcher
from repro.core.faults import FaultInjector, FaultSpec, JobFailedError
from repro.core.health import HealthConfig

# the per-row contribution ends in sqrt so XLA cannot fuse the producer
# into the reduction adds as FMA — member-count-stable like word_weight's
# scatter (see docs/robustness.md on the fusion caveat)
job = DispatchJob(name="det", signature="det", reduce="sum",
                  deterministic=True,
                  member_fn=lambda x, v, w: jnp.sqrt(x * x + w))
rng = np.random.RandomState(0)
items = (rng.randn(32, 4) * 10 ** rng.uniform(-2, 2, (32, 4))).astype(
    np.float32)
w = np.float32(1.7)

def hc():
    return HealthConfig(target_step_time=1.0, max_threshold=0.8,
                        min_threshold=0.2, time_between_scaling=1,
                        window=1, max_instances=4)

def feeder(seq):
    it = iter(seq)
    def on_chunk(disp, ci, n):
        l = next(it, None)
        if l is not None:
            disp.observe_load(l)
    return on_chunk

LOADS = [2.0, 2.0, 0.05]          # 1 -> 2 -> 4 -> 2 across the stream

# fault-free synchronous oracle (deterministic sum: member-count invariant)
d0 = ElasticDispatcher(devices=jax.devices()[:1], health_cfg=hc(),
                       start_members=1, dispatch_ahead=0)
ref = np.asarray(d0.submit(job, items, replicated=(w,), chunk=4,
                           deliver="host")[0])

for kill_at in range(8):
    inj = FaultInjector([FaultSpec("member_crash", chunk=kill_at, member=0)])
    d = ElasticDispatcher(devices=jax.devices(), health_cfg=hc(),
                          start_members=1, dispatch_ahead=2,
                          fault_injector=inj)
    out, rep = d.submit(job, items, replicated=(w,), chunk=4, deliver="host",
                        on_chunk=feeder(LOADS))
    assert np.array_equal(np.asarray(out), ref), (kill_at, np.asarray(out))
    assert rep.n_chunks == 8 and d.in_flight == 0
    assert len(rep.recovery_events) == 1, (kill_at, rep.recovery_events)
    ev = rep.recovery_events[0]
    assert ev["reason"] == "member_failure" and ev["failed_chunk"] == kill_at
    assert kill_at in ev["replayed_chunks"]
    assert ev.get("recovery_s", 0) > 0, ev
    assert rep.retries >= 1
    assert [f["kind"] for f in rep.failures] == ["member_crash"]
    # the stream still rode voluntary scale events around the failure one
    assert any(e["reason"] == "scale" for e in d.scale_events), d.scale_events
print("EVERY-INDEX OK")

# spare-pool semantics: the dead device left the pool, a spare absorbed it
assert len(d.devices) == 7 and len(d.dead_devices) == 1

# survivors < min_instances: loud JobFailedError, dispatcher degrades but
# stays reusable
hc2 = HealthConfig(target_step_time=1.0, time_between_scaling=1, window=1,
                   min_instances=2, max_instances=2)
inj = FaultInjector([FaultSpec("member_crash", chunk=3, member=1)])
d = ElasticDispatcher(devices=jax.devices()[:2], health_cfg=hc2,
                      start_members=2, dispatch_ahead=2, fault_injector=inj)
try:
    d.submit(job, items, replicated=(w,), chunk=4, deliver="host")
    raise SystemExit("expected JobFailedError")
except JobFailedError as e:
    assert e.report.failures and e.report.failures[0]["kind"] == "member_crash"
assert d.in_flight == 0 and d.n_members == 1
out, rep = d.submit(job, items, replicated=(w,), chunk=4, deliver="host")
assert np.array_equal(np.asarray(out), ref)       # degraded but correct
print("EXHAUSTION OK")

# quarantine: repeated poison attributed to one member of a 2-member mesh
# forces the failure remesh (concat job keeps the row dim for attribution)
cjob = DispatchJob(name="rows", signature="rows",
                   member_fn=lambda x, v, w: x * w, reduce="concat")
cref = np.asarray(items) * w
hc3 = HealthConfig(target_step_time=1.0, time_between_scaling=1, window=1,
                   min_instances=1, max_instances=2)
inj = FaultInjector([FaultSpec("nan_poison", chunk=2, member=1, times=2)])
from repro.core.faults import RetryPolicy
d = ElasticDispatcher(devices=jax.devices()[:4], health_cfg=hc3,
                      start_members=2, dispatch_ahead=2, fault_injector=inj,
                      retry_policy=RetryPolicy(quarantine_after=2,
                                               max_attempts=5,
                                               check_finite=True))
out, rep = d.submit(cjob, items, replicated=(w,), chunk=4, deliver="host")
assert np.array_equal(np.asarray(out), cref)
assert len(rep.recovery_events) == 1, rep.recovery_events
assert "quarantined" in rep.recovery_events[0]["cause"]
print("QUARANTINE OK")
print("OK")
"""], env=env, capture_output=True, text=True, timeout=900)
    assert "OK" in r.stdout, r.stdout + r.stderr


def test_grid_fail_over_restores_backed_up_entries():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.grid import DataGrid

g = DataGrid(Mesh(np.array(jax.devices()[:4]), ("data",)), backup_count=1)
g.put("a", jnp.arange(8.0))
g.put("b", jnp.arange(16.0).reshape(8, 2))
restored = g.fail_over(lost_member=2)
assert restored == ["a", "b"], restored
assert np.array_equal(np.asarray(g.get("a")), np.arange(8.0))
assert np.array_equal(np.asarray(g.get("b")), np.arange(16.0).reshape(8, 2))
g2 = DataGrid(Mesh(np.array(jax.devices()[:4]), ("data",)))  # no backups
g2.put("c", jnp.arange(8.0))
assert g2.fail_over(lost_member=0) == []
print("OK")
"""], env=env, capture_output=True, text=True, timeout=600)
    assert "OK" in r.stdout, r.stdout + r.stderr


# ------------------------------------------------------------- chaos (slow)

def _chaos_case(seed, n_faults, max_attempts, job, items, w, ref):
    """One chaos example: a seeded random fault schedule either recovers
    BIT-IDENTICALLY or fails loudly with a populated report — and the
    dispatcher is reusable either way.  member_crash is exercised by the
    multi-device subprocess tests; in-process there is one real device, so
    killing it could only ever fail."""
    inj = FaultInjector.random_schedule(
        seed=seed, n_chunks=6, max_members=1, n_faults=n_faults,
        kinds=("nan_poison", "stall", "compile_fail"), stall_delay_s=0.05)
    d = ElasticDispatcher(start_members=1, dispatch_ahead=2,
                          fault_injector=inj,
                          retry_policy=RetryPolicy(max_attempts=max_attempts,
                                                   quarantine_after=0,
                                                   check_finite=True))
    try:
        out, rep = d.submit(job, items, replicated=(w,), chunk=4,
                            deliver="host")
        assert np.array_equal(np.asarray(out), ref)
    except JobFailedError as e:
        assert e.report.failures                # loud, with the evidence
    assert d.in_flight == 0
    # reusable: a fault-free stream on the same dispatcher still works
    out2, _ = d.submit(job, items, replicated=(w,), chunk=4,
                       deliver="host", fault_injector=FaultInjector())
    assert np.array_equal(np.asarray(out2), ref)


@pytest.mark.slow
def test_chaos_schedules_recover_or_fail_loudly():
    """Randomized chaos over (kind × chunk × member × retry budget):
    hypothesis-driven when available, a seeded sweep otherwise (the
    schedules themselves are always derived reproducibly from the seed)."""
    job, items, w = _job(), _items(24), np.float32(2.0)
    d0 = ElasticDispatcher(start_members=1, dispatch_ahead=0)
    ref = np.asarray(d0.submit(job, items, replicated=(w,), chunk=4,
                               deliver="host")[0])
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for seed in range(12):
            _chaos_case(seed, 1 + seed % 4, 1 + seed % 3,
                        job, items, w, ref)
        return

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10 ** 6),
           n_faults=st.integers(1, 4),
           max_attempts=st.integers(1, 3))
    def run(seed, n_faults, max_attempts):
        _chaos_case(seed, n_faults, max_attempts, job, items, w, ref)

    run()
