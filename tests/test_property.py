"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.partition import (PartitionTable, key_partition,
                                  partition_ranges)
from repro.core.speedup import SpeedupModel
from repro.models.moe import matchmaking_route
from repro.kernels.histogram.ref import histogram_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.models.ssm import ssd_chunked

SETTINGS = settings(max_examples=25, deadline=None)


@given(n=st.integers(1, 10_000), k=st.integers(1, 64))
@SETTINGS
def test_partition_ranges_cover_exactly(n, k):
    """PartitionUtil ranges tile [0, n) disjointly, in order (§4.1.3)."""
    ranges = partition_ranges(n, k)
    assert ranges[0][0] == 0 and ranges[-1][1] == n
    for (a0, a1), (b0, b1) in zip(ranges, ranges[1:]):
        assert a1 == b0 and a0 <= a1 and b0 <= b1


@given(start=st.integers(1, 16), new=st.integers(1, 16))
@SETTINGS
def test_partition_table_balanced_after_rebalance(start, new):
    pt = PartitionTable(n_instances=start)
    pt.rebalance(new)
    load = pt.load()
    assert load.sum() == 271
    assert load.max() - load.min() <= 1


@given(start=st.integers(1, 16),
       seq=st.lists(st.integers(1, 16), min_size=1, max_size=8))
@SETTINGS
def test_partition_table_rebalance_sequences(start, seq):
    """Across random join/leave sequences: every partition owned by a live
    member, load spread ≤ 1, and movement minimal — at most the partitions
    owned by departed members (forced) plus those above the balanced floor
    on overfull survivors (leveling excess)."""
    pt = PartitionTable(n_instances=start)
    for n_new in seq:
        before = pt.owner.copy()
        counts = np.bincount(before[before < n_new], minlength=n_new)
        forced = int((before >= n_new).sum())
        excess = int(np.maximum(counts - pt.partition_count // n_new,
                                0).sum())
        moved = pt.rebalance(n_new)
        load = pt.load()
        assert load.sum() == pt.partition_count
        assert (pt.owner >= 0).all() and (pt.owner < n_new).all()
        assert load.max() - load.min() <= 1
        assert moved <= forced + excess
        # unchanged membership never shuffles anything
        assert pt.rebalance(n_new) == 0


@given(key=st.one_of(st.integers(0, 2 ** 40), st.text(max_size=32),
                     st.binary(max_size=32)),
       count=st.sampled_from([7, 271, 1024]))
@SETTINGS
def test_key_partition_in_range_and_pure(key, count):
    """key_partition is a pure total function into [0, count) — and str/bytes
    agree, since str keys are crc32-hashed over their UTF-8 encoding."""
    p = key_partition(key, count)
    assert 0 <= p < count
    assert key_partition(key, count) == p
    if isinstance(key, str):
        assert key_partition(key.encode("utf-8"), count) == p


@given(t=st.integers(4, 64), e=st.integers(2, 8), k=st.integers(1, 3),
       cap=st.integers(1, 32), seed=st.integers(0, 100))
@SETTINGS
def test_matchmaking_capacity_invariant(t, e, k, cap, seed):
    """The fair-matchmaking router NEVER overfills an expert (VM) slot."""
    k = min(k, e)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
    probs, ids, keep, pos = matchmaking_route(logits, k, cap)
    ids_np, keep_np = np.asarray(ids), np.asarray(keep)
    counts = np.zeros(e, np.int64)
    for i in range(t):
        for j in range(k):
            if keep_np[i, j]:
                counts[ids_np[i, j]] += 1
    assert (counts <= cap).all()
    # kept slots have positions strictly inside capacity
    assert (np.asarray(pos)[keep_np] < cap).all()


@given(t=st.integers(1, 500), v=st.integers(2, 300), seed=st.integers(0, 50))
@SETTINGS
def test_histogram_matches_numpy(t, v, seed):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (t,), 0, v).astype(
        jnp.int32)
    out = histogram_ref(toks, v)
    np.testing.assert_array_equal(
        np.asarray(out), np.bincount(np.asarray(toks), minlength=v))
    assert int(out.sum()) == t


@given(chunk=st.sampled_from([8, 16, 32]), s_mult=st.integers(1, 4),
       seed=st.integers(0, 20))
@SETTINGS
def test_ssd_chunked_invariant_to_chunk_size(chunk, s_mult, seed):
    """SSD chunked scan == exact recurrence for ANY chunking (duality)."""
    BH, P, N = 2, 4, 4
    S = chunk * s_mult
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (BH, S, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    A = -jnp.exp(jax.random.normal(ks[2], (BH,)))
    B = jax.random.normal(ks[3], (BH, S, N))
    C = jax.random.normal(ks[4], (BH, S, N))
    ref = ssd_ref(x, dt, A, B, C)
    # models/ssm.ssd_chunked uses (B,S,H,P) layout
    y, _ = ssd_chunked(x.transpose(1, 0, 2).reshape(1, S, BH, P),
                       dt.T.reshape(1, S, BH), A,
                       B.transpose(1, 0, 2).reshape(1, S, BH, N),
                       C.transpose(1, 0, 2).reshape(1, S, BH, N), chunk)
    np.testing.assert_allclose(np.asarray(y[0].transpose(1, 0, 2)),
                               np.asarray(ref), atol=1e-3, rtol=1e-2)


@given(t1=st.floats(1.0, 1e4), k=st.floats(0.0, 1.0), n=st.integers(2, 64))
@SETTINGS
def test_speedup_amdahl_bound(t1, k, n):
    """With zero overheads, Eq 3.6 reduces to Amdahl's law: S_n <= 1/(1-k)."""
    m = SpeedupModel(t1=t1, k=k)
    s = m.speedup(n)
    assert s <= 1.0 / max(1.0 - k, 1.0 / n) + 1e-6
    assert s >= 1.0 - 1e-9


@given(seed=st.integers(0, 30), shards=st.sampled_from([1, 2, 4]))
@SETTINGS
def test_des_scheduling_member_count_invariant(seed, shards):
    """The DES produces identical scheduling decisions for any member count
    (the thesis's accuracy claim) — here via the partitioned matchmaking math
    on a single device with different partition counts."""
    from repro.core.cloudsim import matchmaking_assign
    key = jax.random.PRNGKey(seed)
    n_vms, n_cl = 16, 32
    vm = jax.random.uniform(key, (n_vms,), minval=500., maxval=2000.)
    mi = jax.random.uniform(jax.random.fold_in(key, 1), (n_cl,),
                            minval=1000., maxval=50000.)
    ids = jnp.arange(n_cl, dtype=jnp.int32)
    full = matchmaking_assign(ids, mi, vm, n_vms)
    per = n_cl // shards
    parts = [matchmaking_assign(ids[i * per:(i + 1) * per],
                                mi[i * per:(i + 1) * per], vm, n_vms)
             for i in range(shards)]
    np.testing.assert_array_equal(np.asarray(full),
                                  np.concatenate([np.asarray(p) for p in parts]))


# ------------------------------------------- queueing stats (ISSUE 7 tentpole)

@given(w=st.integers(0, 10), c=st.integers(0, 10),
       samples=st.lists(st.floats(0.0, 1e6), max_size=40))
@SETTINGS
def test_stats_window_trim_is_slice(w, c, samples):
    """Warm-up/cool-down trimming is EXACTLY the slice samples[w : n-c] —
    over-trimmed windows are empty and every statistic degrades to NaN."""
    import math
    from repro.core.stats import StatsWindow
    win = StatsWindow(warmup=w, cooldown=c)
    win.extend(samples)
    n = len(samples)
    expect = samples[w:n - c] if w + c < n else []
    np.testing.assert_array_equal(win.trimmed(), expect)
    np.testing.assert_array_equal(win.raw(), samples)
    if not expect:
        assert math.isnan(win.mean()) and math.isnan(win.percentile(50))
    else:
        assert win.mean() == pytest.approx(np.mean(expect))


@given(samples=st.lists(st.floats(1e-2, 1e2), min_size=1, max_size=200),
       growth=st.floats(1.05, 2.0),
       q=st.sampled_from([50.0, 90.0, 95.0, 99.0]))
@SETTINGS
def test_histogram_quantile_bounded_error(samples, growth, q):
    """The log-bucket contract: for in-range samples the reported quantile
    q̂ satisfies  q_true ≤ q̂ ≤ q_true · growth."""
    from repro.core.stats import Histogram
    h = Histogram(lo=1e-3, hi=1e3, growth=growth)
    for v in samples:
        h.add(v)
    true = float(np.quantile(samples, q / 100.0, method="inverted_cdf"))
    est = h.quantile(q)
    assert true * (1 - 1e-9) <= est <= true * growth * (1 + 1e-9), \
        (true, est, growth)


@given(jobs=st.lists(st.tuples(st.floats(0.01, 2.0),    # inter-arrival gap
                               st.floats(0.0, 3.0),     # queue wait
                               st.floats(0.001, 3.0)),  # service time
                     min_size=2, max_size=50))
@SETTINGS
def test_littles_law_exact_on_any_event_log(jobs):
    """Little's law L = λW holds EXACTLY (not asymptotically) on any
    consistent record stream: the horizon time-integral of the in-system
    count equals the sojourn sum, so mean_in_system == arrival_rate × mean
    sojourn to float precision — the conservation check the operational-law
    view is built on.  Same identity for the waiting room (Lq = λWq)."""
    from repro.core.stats import DispatchStats
    stats = DispatchStats(warmup=0, serialized=False)
    t, sojourns, waits = 0.0, [], []
    for i, (gap, wait, service) in enumerate(jobs):
        t += gap
        stats.record(i, t_enqueue=t, t_dispatch=t + wait,
                     t_retire=t + wait + service)
        sojourns.append(wait + service)
        waits.append(wait)
    q = stats.queue_summary(n_servers=1)
    lam = q["arrival_rate"]
    assert q["mean_in_system"] == pytest.approx(
        lam * float(np.mean(sojourns)), rel=1e-9)
    assert q["mean_queue_length"] == pytest.approx(
        lam * float(np.mean(waits)), rel=1e-9, abs=1e-12)


# ------------------------- guarded vs legacy retirement equivalence (ISSUE 7)

_EQ_PLAIN = None
_EQ_GUARDED = None


def _equivalence_dispatchers():
    """Module-level dispatcher pair so hypothesis examples share compile
    caches in lockstep (same submit sequence on each side)."""
    global _EQ_PLAIN, _EQ_GUARDED
    if _EQ_PLAIN is None:
        from repro.core.dispatch import ElasticDispatcher
        _EQ_PLAIN = ElasticDispatcher(start_members=1)
        _EQ_GUARDED = ElasticDispatcher(start_members=1)
    return _EQ_PLAIN, _EQ_GUARDED


@given(b=st.integers(1, 24), chunk=st.integers(1, 8),
       depth=st.integers(0, 3), seed=st.integers(0, 5))
@SETTINGS
def test_guarded_noop_retirement_equals_legacy_path(b, chunk, depth, seed):
    """A no-op guard (huge deadline, no finite check, no injector) is
    byte-for-byte the unguarded pipeline: identical output payloads,
    identical on_chunk firing order, and identical report shape minus
    wall-clock fields."""
    import dataclasses as _dc
    from repro.core.dispatch import DispatchJob
    from repro.core.faults import RetryPolicy
    d_plain, d_guard = _equivalence_dispatchers()
    job = DispatchJob(name="affine", signature="affine-eq",
                      member_fn=lambda x, v, *_: x * 3.0 - 1.0,
                      reduce="concat")
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, 2)).astype(np.float32)

    def run(d, policy):
        fired = []
        out, rep = d.submit(
            job, x, chunk=chunk, dispatch_ahead=depth, deliver="host",
            retry_policy=policy,
            on_chunk=lambda _d, ci, n: fired.append((ci, n)))
        return np.asarray(out), rep, fired

    out_p, rep_p, fired_p = run(d_plain, None)
    noop = RetryPolicy(chunk_timeout_s=1e9)
    assert noop.active                      # actually exercises the guard
    out_g, rep_g, fired_g = run(d_guard, noop)

    assert out_p.tobytes() == out_g.tobytes()
    assert fired_p == fired_g               # same callback order
    sp, sg = _dc.asdict(rep_p), _dc.asdict(rep_g)
    for volatile in ("wall_s", "ema_step_s", "stats"):
        sp.pop(volatile), sg.pop(volatile)
    assert sp == sg                         # reports agree field by field
    assert rep_g.failures == [] and rep_g.retries == 0
