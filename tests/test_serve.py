"""Serving: scheduler brokers, continuous batching engine."""
import numpy as np
import pytest

import jax

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serve.scheduler import Request, Scheduler, ServeEngine


def test_matchmaking_prefers_smallest_adequate_bucket():
    s = Scheduler(n_slots=4, max_len=64, policy="matchmaking",
                  bucket_lens=[16, 16, 64, 64])
    s.submit(Request(0, np.zeros(4, np.int32), max_new_tokens=4))
    placed = s.schedule()
    assert placed and placed[0].slot in (0, 1)   # fits a small bucket
    s.submit(Request(1, np.zeros(40, np.int32), max_new_tokens=8))
    placed = s.schedule()
    assert placed and placed[0].slot in (2, 3)   # needs a large bucket


def test_matchmaking_fairness_round_robins_ties():
    s = Scheduler(n_slots=4, max_len=64, policy="matchmaking",
                  bucket_lens=[64, 64, 64, 64])
    slots = []
    for i in range(4):
        s.submit(Request(i, np.zeros(2, np.int32), max_new_tokens=2))
        slots.append(s.schedule()[0].slot)
    assert len(set(slots)) == 4      # no slot monopolized


def test_round_robin_cycles():
    s = Scheduler(n_slots=3, max_len=32, policy="round_robin",
                  bucket_lens=[32, 32, 32])
    slots = []
    for i in range(3):
        s.submit(Request(i, np.zeros(2, np.int32), max_new_tokens=2))
        slots.append(s.schedule()[0].slot)
    assert slots == [0, 1, 2]


def test_oversize_requests_dropped_waiting_queue_drains():
    s = Scheduler(n_slots=1, max_len=16, policy="matchmaking",
                  bucket_lens=[16])
    s.submit(Request(0, np.zeros(30, np.int32), max_new_tokens=4))  # too big
    s.submit(Request(1, np.zeros(4, np.int32), max_new_tokens=2))
    s.submit(Request(2, np.zeros(4, np.int32), max_new_tokens=2))
    placed = s.schedule()
    assert s.dropped == 1 and len(placed) == 1 and len(s.queue) == 1


@pytest.mark.parametrize("policy", ["round_robin", "matchmaking"])
def test_engine_completes_requests(policy):
    cfg = reduced(get_config("smollm-360m"), n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=64)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, n_slots=2, max_len=24, policy=policy)
    rng = np.random.default_rng(0)
    for i in range(4):
        engine.sched.submit(Request(
            i, rng.integers(0, 64, size=3).astype(np.int32),
            max_new_tokens=3))
    out = engine.run(max_steps=64)
    assert len(out["completed"]) == 4
    for r in out["completed"]:
        assert len(r.output) == 3
        assert all(0 <= t < cfg.padded_vocab for t in r.output)
    # open-stream queueing stats in decode-step units: every completion is
    # recorded, service = placement->completion (3 new tokens + prefill
    # steps), and the queue wait counts steps spent waiting for a slot
    s = out["stats"]
    assert s["n_records"] == 4
    assert s["queue"]["n_completed"] == 4
    assert s["service"]["mean"] >= 3            # at least the decode budget
    assert s["queue_wait"]["mean"] >= 0
    # 4 requests into 2 slots: the second pair waited for a free slot
    assert s["sojourn"]["p99"] >= s["service"]["p50"]
    assert 0 < s["queue"]["utilization"] <= 1.0
