"""Serving: scheduler brokers, continuous batching engine."""
import numpy as np
import pytest

import jax

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serve.scheduler import Request, Scheduler, ServeEngine


def test_matchmaking_prefers_smallest_adequate_bucket():
    s = Scheduler(n_slots=4, max_len=64, policy="matchmaking",
                  bucket_lens=[16, 16, 64, 64])
    s.submit(Request(0, np.zeros(4, np.int32), max_new_tokens=4))
    placed = s.schedule()
    assert placed and placed[0].slot in (0, 1)   # fits a small bucket
    s.submit(Request(1, np.zeros(40, np.int32), max_new_tokens=8))
    placed = s.schedule()
    assert placed and placed[0].slot in (2, 3)   # needs a large bucket


def test_matchmaking_fairness_round_robins_ties():
    s = Scheduler(n_slots=4, max_len=64, policy="matchmaking",
                  bucket_lens=[64, 64, 64, 64])
    slots = []
    for i in range(4):
        s.submit(Request(i, np.zeros(2, np.int32), max_new_tokens=2))
        slots.append(s.schedule()[0].slot)
    assert len(set(slots)) == 4      # no slot monopolized


def test_round_robin_cycles():
    s = Scheduler(n_slots=3, max_len=32, policy="round_robin",
                  bucket_lens=[32, 32, 32])
    slots = []
    for i in range(3):
        s.submit(Request(i, np.zeros(2, np.int32), max_new_tokens=2))
        slots.append(s.schedule()[0].slot)
    assert slots == [0, 1, 2]


def test_oversize_requests_dropped_waiting_queue_drains():
    s = Scheduler(n_slots=1, max_len=16, policy="matchmaking",
                  bucket_lens=[16])
    s.submit(Request(0, np.zeros(30, np.int32), max_new_tokens=4))  # too big
    s.submit(Request(1, np.zeros(4, np.int32), max_new_tokens=2))
    s.submit(Request(2, np.zeros(4, np.int32), max_new_tokens=2))
    placed = s.schedule()
    assert s.dropped == 1 and len(placed) == 1 and len(s.queue) == 1
    # the drop is STRUCTURED, not a bare counter: reason + req_id, attached
    # to both the scheduler record and the request itself
    rej = s.rejected[0]
    assert rej["reason"] == "over_max_len" and rej["req_id"] == 0
    assert rej["need"] == 34 and rej["max_len"] == 16


def _drive_broker(policy, seed, n_requests=30, n_slots=4, max_len=32,
                  max_rounds=4000):
    """Continuous-load broker simulation without a model: every round admits
    fresh requests while serving slots one decode step; returns (scheduler,
    placement round per req_id, feasibility per req_id)."""
    rng = np.random.default_rng(seed)
    s = Scheduler(n_slots=n_slots, max_len=max_len, policy=policy)
    placed_at, feasible, submitted = {}, {}, 0
    for rnd in range(max_rounds):
        while submitted < n_requests and len(s.queue) < 2 * n_slots:
            plen = int(rng.integers(1, max_len + 8))
            ntok = int(rng.integers(1, 4))
            feasible[submitted] = plen + ntok <= max_len
            s.submit(Request(submitted, np.zeros(plen, np.int32),
                             max_new_tokens=ntok))
            submitted += 1
        for req in s.schedule():
            placed_at[req.req_id] = rnd
        for i in s.active_slots():
            st = s.slots[i]
            st.budget -= 1
            if st.budget <= 0:
                s.release(i)
        if submitted == n_requests and not s.queue and not s.active_slots():
            break
    return s, placed_at, feasible


def _broker_fairness_case(policy, seed):
    s, placed_at, feasible = _drive_broker(policy, seed)
    for rid, ok in feasible.items():
        if ok:
            # no starvation: every admitted, feasible request was placed
            assert rid in placed_at, (policy, seed, rid)
        else:
            assert rid not in placed_at
            assert any(r["req_id"] == rid and r["reason"] == "over_max_len"
                       for r in s.rejected), (policy, seed, rid)


@pytest.mark.parametrize("policy", ["round_robin", "matchmaking"])
def test_broker_fairness_no_starvation_under_continuous_load(policy):
    """Every admitted, feasible request is eventually placed under
    continuous load; infeasible ones surface as structured rejections.
    Hypothesis-driven when available, a seeded sweep otherwise."""
    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        for seed in range(20):
            _broker_fairness_case(policy, seed)
        return

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10 ** 6))
    def run(seed):
        _broker_fairness_case(policy, seed)

    run()


@pytest.mark.parametrize("policy", ["round_robin", "matchmaking"])
def test_engine_completes_requests(policy):
    cfg = reduced(get_config("smollm-360m"), n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=64)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, n_slots=2, max_len=24, policy=policy)
    rng = np.random.default_rng(0)
    for i in range(4):
        engine.sched.submit(Request(
            i, rng.integers(0, 64, size=3).astype(np.int32),
            max_new_tokens=3))
    out = engine.run(max_steps=64)
    assert len(out["completed"]) == 4
    for r in out["completed"]:
        assert len(r.output) == 3
        assert all(0 <= t < cfg.padded_vocab for t in r.output)
    # open-stream queueing stats in decode-step units: every completion is
    # recorded, service = placement->completion (3 new tokens + prefill
    # steps), and the queue wait counts steps spent waiting for a slot
    s = out["stats"]
    assert s["n_records"] == 4
    assert s["queue"]["n_completed"] == 4
    assert s["service"]["mean"] >= 3            # at least the decode budget
    assert s["queue_wait"]["mean"] >= 0
    # 4 requests into 2 slots: the second pair waited for a free slot
    assert s["sojourn"]["p99"] >= s["service"]["p50"]
    assert 0 < s["queue"]["utilization"] <= 1.0


def _tiny_engine(policy="matchmaking", n_slots=2, max_len=24):
    cfg = reduced(get_config("smollm-360m"), n_layers=2, d_model=32,
                  n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                  vocab_size=64)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, n_slots=n_slots, max_len=max_len,
                       policy=policy)


def test_rejected_requests_surfaced_in_serve_stats():
    """Regression: an over-max_len drop must be visible in the run result
    AND the SLO stats — never a silent counter bump."""
    engine = _tiny_engine()
    engine.sched.submit(Request(0, np.zeros(40, np.int32),
                                max_new_tokens=4))          # infeasible
    engine.sched.submit(Request(1, np.zeros(3, np.int32), max_new_tokens=2))
    out = engine.run(max_steps=32)
    assert len(out["completed"]) == 1
    assert out["dropped"] == 1
    assert out["rejected"] == [{"req_id": 0, "reason": "over_max_len",
                                "need": 44, "max_len": 24}]
    assert out["stats"]["rejections"] == {"over_max_len": 1.0}
    assert out["stats"]["n_rejected"] == 1.0


def test_empty_prompt_does_not_crash_prefill():
    """Regression: an empty prompt used to leave ``nxt`` unbound in
    ``_prefill_one`` (NameError); it now decodes from a zero token."""
    engine = _tiny_engine()
    engine.sched.submit(Request(0, np.zeros(0, np.int32), max_new_tokens=3))
    out = engine.run(max_steps=32)
    assert len(out["completed"]) == 1
    assert len(out["completed"][0].output) == 3
