"""Model substrate: every arch trains/prefills/decodes; decode == full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models.model import build_model


def make_batch(cfg, B=2, S=16):
    batch = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % 13,
             "labels": jnp.ones((B, S), jnp.int32),
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend == "vision_stub":
        batch["tokens"] = batch["tokens"][:, :S - cfg.frontend_tokens]
        batch["patches"] = jnp.ones((B, cfg.frontend_tokens, cfg.frontend_dim),
                                    jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.ones((B, S, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_and_serve(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg, remat=False, xent_chunk=8)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), arch
    assert metrics["tokens"] > 0

    caches = model.make_caches(B, max_len=S + 4,
                               cross_len=S if cfg.is_encdec else 0)
    logits, caches = jax.jit(model.prefill)(params, batch, caches)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, caches = jax.jit(model.decode)(params, tok, caches, jnp.int32(S))
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma3-4b", "mamba2-370m",
                                  "jamba-v0.1-52b"])
def test_decode_matches_prefill(arch):
    """Incremental decode must reproduce the full-sequence forward —
    validates KV caches, RoPE offsets, SSM state carry, window masks.
    MoE archs need ample router capacity: capacity-dropping is a function of
    the batch's token count, so prefill(T) and decode(1) legitimately differ
    when tokens overflow expert slots (documented MoE serving semantics)."""
    cfg = reduced(get_config(arch))
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = build_model(cfg, remat=False, compute_dtype=jnp.float32,
                        xent_chunk=8)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 1, 12
    toks = (jnp.arange(S, dtype=jnp.int32)[None] * 7) % cfg.vocab_size

    # full prefill of S tokens
    caches_full = model.make_caches(B, max_len=S + 2)
    batch = {"tokens": toks}
    logits_full, _ = model.prefill(params, batch, caches_full)

    # prefill S-1 then decode the last token
    caches = model.make_caches(B, max_len=S + 2)
    _, caches = model.prefill(params, {"tokens": toks[:, :S - 1]}, caches)
    logits_inc, _ = model.decode(params, toks[:, S - 1:S], caches,
                                 jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(logits_inc[:, -1]),
                               np.asarray(logits_full[:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_moe_sliced_matches_dense_oracle():
    from repro.models import moe as moe_mod
    from repro.models.param import init_params
    cfg = reduced(get_config("olmoe-1b-7b"))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    params = init_params(moe_mod.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    ref = moe_mod.moe_block(params, x, cfg, compute_dtype=jnp.float32,
                            moe_impl="dense")
    out = moe_mod.moe_block(params, x, cfg, compute_dtype=jnp.float32,
                            moe_impl="sliced")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-3)


def test_sliding_window_limits_context():
    """A token beyond the window must not influence local attention."""
    from repro.models.attention import _chunked_attn
    B, S, H, hd, w = 1, 32, 2, 8, 4
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    out1 = _chunked_attn(q, k, v, causal=True, window=w, q_offset=0,
                         kv_len=None, q_chunk=8)
    k2 = k.at[:, 0].set(99.0)   # outside every later token's window
    v2 = v.at[:, 0].set(99.0)
    out2 = _chunked_attn(q, k2, v2, causal=True, window=w, q_offset=0,
                         kv_len=None, q_chunk=8)
    np.testing.assert_allclose(out1[:, w:], out2[:, w:], atol=1e-5)


def test_chunked_xent_matches_dense():
    from repro.models.layers import chunked_xent
    B, S, D, V = 2, 16, 8, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    unemb = jax.random.normal(jax.random.PRNGKey(1), (D, V))
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    mask = jnp.ones((B, S))
    loss, cnt = chunked_xent(x, unemb, labels, mask, chunk=4)
    logits = x @ unemb
    ref = -jax.nn.log_softmax(logits)[
        jnp.arange(B)[:, None], jnp.arange(S)[None], labels].sum()
    np.testing.assert_allclose(float(loss), float(ref), rtol=1e-5)
    assert int(cnt) == B * S


def test_model_with_pallas_attention_impl():
    """impl="pallas" routes train-time attention through the flash kernel
    (interpret mode on CPU) and matches the XLA path."""
    cfg = reduced(get_config("llama3-8b"), n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256)
    batch = make_batch(cfg, B=1, S=32)
    params = build_model(cfg, remat=False, xent_chunk=8).init(
        jax.random.PRNGKey(0))
    loss_xla, _ = build_model(cfg, impl="xla", remat=False,
                              compute_dtype=jnp.float32,
                              xent_chunk=8).loss_fn(params, batch)
    loss_pal, _ = build_model(cfg, impl="pallas", remat=False,
                              compute_dtype=jnp.float32,
                              xent_chunk=8).loss_fn(params, batch)
    np.testing.assert_allclose(float(loss_pal), float(loss_xla),
                               rtol=1e-4, atol=1e-4)
