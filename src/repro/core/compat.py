"""Version-compat shims for jax APIs that moved between releases.

The repo targets the jax 0.4.x series shipped in the image but is written
against the newer spellings; this module papers over both directions:

  * ``shard_map``      — top-level ``jax.shard_map`` only exists from
                         jax >= 0.6; before that it lives in
                         ``jax.experimental.shard_map``.  The replication
                         check kwarg was also renamed
                         (``check_rep`` -> ``check_vma``); the wrapper
                         accepts either and translates.
  * ``make_mesh``      — the ``axis_types`` kwarg (and
                         ``jax.sharding.AxisType``) only exist on newer jax;
                         the wrapper drops the kwarg where unsupported
                         (``Auto`` is the default there anyway).
  * ``CompilerParams`` — pallas-TPU renamed ``TPUCompilerParams`` to
                         ``CompilerParams``; this resolves whichever the
                         installed jax ships.
"""
from __future__ import annotations

import inspect

import jax

try:                                     # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
except ImportError:                      # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *args, **kwargs):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename hidden."""
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, *args, **kwargs)


_MAKE_MESH_PARAMS = frozenset(inspect.signature(jax.make_mesh).parameters)

# jax.sharding.AxisType.Auto where it exists, else None (the kwarg is dropped).
AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` tolerating the ``axis_types`` kwarg's absence."""
    if "axis_types" not in _MAKE_MESH_PARAMS:
        kwargs.pop("axis_types", None)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


from jax.experimental.pallas import tpu as _pltpu  # noqa: E402

CompilerParams = (getattr(_pltpu, "CompilerParams", None)
                  or _pltpu.TPUCompilerParams)
