"""Version-compat shims for jax APIs that moved between releases.

The repo targets the jax 0.4.x series shipped in the image but is written
against the newer spellings; this module papers over both directions:

  * ``shard_map``      — top-level ``jax.shard_map`` only exists from
                         jax >= 0.6; before that it lives in
                         ``jax.experimental.shard_map``.  The replication
                         check kwarg was also renamed
                         (``check_rep`` -> ``check_vma``); the wrapper
                         accepts either and translates.
  * ``make_mesh``      — the ``axis_types`` kwarg (and
                         ``jax.sharding.AxisType``) only exist on newer jax;
                         the wrapper drops the kwarg where unsupported
                         (``Auto`` is the default there anyway).
  * ``CompilerParams`` — pallas-TPU renamed ``TPUCompilerParams`` to
                         ``CompilerParams``; this resolves whichever the
                         installed jax ships.

It also owns the ONE definition of the Pallas interpret-mode default
(``resolve_kernel_interpret``) that des_scan and the kernel wrappers used to
each spell out as ``jax.default_backend() != "tpu"``.
"""
from __future__ import annotations

import inspect
import warnings

import jax

try:                                     # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
except ImportError:                      # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *args, **kwargs):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename hidden."""
    if "check_vma" in kwargs and "check_vma" not in _SHARD_MAP_PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _SHARD_MAP_PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, *args, **kwargs)


_MAKE_MESH_PARAMS = frozenset(inspect.signature(jax.make_mesh).parameters)

# jax.sharding.AxisType.Auto where it exists, else None (the kwarg is dropped).
AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` tolerating the ``axis_types`` kwarg's absence."""
    if "axis_types" not in _MAKE_MESH_PARAMS:
        kwargs.pop("axis_types", None)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


from jax.experimental.pallas import tpu as _pltpu  # noqa: E402

CompilerParams = (getattr(_pltpu, "CompilerParams", None)
                  or _pltpu.TPUCompilerParams)


# --------------------------------------------- Pallas interpret-mode default

class KernelInterpretFallbackWarning(UserWarning):
    """``use_kernel=True`` off-TPU runs the kernel's interpret/emulation
    fallback, not a compiled accelerator kernel — kernel timings measured in
    this mode are NOT hardware kernel performance."""


def pallas_interpret_default() -> bool:
    """The repo-wide Pallas interpret default: compiled on TPU, interpret
    (or bit-exact jnp emulation, for kernels that provide one) elsewhere."""
    return jax.default_backend() != "tpu"


_warned_interpret_fallback = False


def resolve_kernel_interpret(interpret, *, warn: bool = True,
                             context: str = "seg_scan") -> bool:
    """Resolve an ``interpret=None`` kernel flag to the backend default.

    The previously thrice-duplicated ``jax.default_backend() != "tpu"``
    default lives HERE.  When the default silently lands on the fallback
    (``use_kernel=True`` on a non-TPU backend), a one-time
    ``KernelInterpretFallbackWarning`` is emitted so CPU "kernel" runs can't
    masquerade as compiled-kernel measurements; an EXPLICIT
    ``interpret=True`` is a deliberate choice and never warns."""
    global _warned_interpret_fallback
    if interpret is not None:
        return bool(interpret)
    interpret = pallas_interpret_default()
    if interpret and warn and not _warned_interpret_fallback:
        _warned_interpret_fallback = True
        warnings.warn(
            f"use_kernel=True on backend {jax.default_backend()!r}: the "
            f"{context} kernel falls back to interpret/emulation mode "
            f"(kernel_path='interpret'); timings do not reflect compiled "
            f"accelerator kernels", KernelInterpretFallbackWarning,
            stacklevel=3)
    return interpret


def kernel_path(use_kernel: bool, interpret=None):
    """The kernel path a scan configuration will actually execute:
    ``None`` (lax path), ``"compiled"``, or ``"interpret"`` — recorded in
    ``DispatchReport.kernel_path`` for honest benchmark provenance."""
    if not use_kernel:
        return None
    return "interpret" if resolve_kernel_interpret(
        interpret, warn=False) else "compiled"
