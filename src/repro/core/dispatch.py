"""ElasticDispatcher — the unified, remesh-aware, chunk-streaming job layer.

The thesis closes by claiming Cloud²Sim's "distributed execution model and
adaptive scaling solution could be leveraged as a general purpose auto scaler
middleware".  This module IS that middleware for the repo: one dispatch layer
that the scenario grids, the MapReduce engine, and the elastic simulation
cluster all sit on, instead of each carrying its own ad-hoc mesh/shard/cache
logic.  Concept map to the thesis's middleware vocabulary:

  IExecutorService / executeOnKeyOwner   ``DispatchJob.member_fn`` — logic
                                         ships to each member's local chunk
                                         partition via ``DistributedExecutor``
  distributed task queue                 the chunk stream of ``submit``: a job
                                         larger than one dispatch (or than
                                         device memory) is cut into fixed-
                                         shape chunks and executed in order,
                                         each chunk a task taken off the queue
  Hazelcast partition table (§4.1.3)     the 271-virtual-shard
                                         ``PartitionTable`` owned here; its
                                         VM→member map is a RUNTIME operand of
                                         the distributed cores, so rebalances
                                         never recompile
  adaptive scaler (Algorithms 4–6, §5)   ``ElasticController`` → IAS; when it
                                         fires BETWEEN chunks the dispatcher
                                         rebalances the table, retires exactly
                                         the outgoing geometry's executables,
                                         rebuilds the mesh, re-homes the
                                         ``DataGrid``, and the stream resumes
                                         on the new member set
  compiled-task near-cache               ``CompileCache`` — one executable per
                                         (geometry, job-signature), LRU, with
                                         hit/miss/build counters, absorbing
                                         and generalizing the scan core's
                                         ``_DIST_CORE_CACHE``/
                                         ``_AUTO_BLOCK_CACHE``

Jobs are declared as ``DispatchJob`` descriptors — ``(member_fn | global_fn,
reduce)``.  ``member_fn(local_items, local_valid, *replicated)`` runs on each
member's shard of the chunk (the Hazelcast-style explicit path);
``global_fn(items, valid, *replicated)`` expresses the same job as one global
computation whose schedule the partitioner chooses (the Infinispan-style
auto-SPMD path).  ``reduce`` combines chunks: "concat" streams row results,
"sum"/"max" accumulate associative partials, so integer reductions (e.g. word
count) are BIT-identical for any member count, chunking, or mid-stream scale
event — the thesis's accuracy-under-elasticity claim at the job layer.
``deterministic=True`` extends that guarantee to FLOAT sums: the job emits
per-row contributions and the dispatcher reduces them with position-aligned
pairwise trees (rows) plus a fixed-arity tree keyed on chunk index (chunks).

The streaming path is an ASYNC, DOUBLE-BUFFERED pipeline (``dispatch_ahead``
launched-but-unretired chunks, default 2): chunk k+1 is staged on the host —
or cut on DEVICE via ``executor.slice_chunk`` when the item set is already
device-resident — while chunk k computes, and the host blocks only to bound
the queue, to take the wall-time samples the IAS needs (an EMA of
retirement-to-retirement step times over a per-job-class calibrated
``target_step_time``), and at reduce/remesh boundaries.  A scale event is a
pipeline BARRIER: drain in-flight chunks, rebalance, rebuild, resume — chunk
boundaries and reduce order never change, so results stay bit-identical no
matter how many chunks were in flight.

FAILURE is a recoverable event at this layer, not a dead job (Hazelcast's
defining property beyond elasticity is surviving member departure; see
``core/faults.py`` and docs/robustness.md).  ``submit`` takes a
``RetryPolicy`` (attempt budget, chunk deadline, backoff, quarantine) and an
optional ``FaultInjector``; the previously-unused ``HealthMonitor`` is the
detector (non-finite chunk outputs are its documented "member crash" signal,
per-member launch walls feed ``straggler_skew``).  A detected member failure
becomes a FORCED failure remesh — drain survivors' in-flight chunks, retire
the dead device from the pool, rebalance the table and remesh grid onto the
survivors — and the failed plus lost chunks are REPLAYED there.  Chunks are
pure functions of (item slice, replicated operands) and the combine order is
fixed by chunk INDEX, so a recovered stream is bit-identical to a fault-free
run.  Unrecoverable jobs raise ``JobFailedError`` carrying the structured
``DispatchReport`` (failures / retries / recovery_events); the dispatcher is
left drained and reusable either way.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import math
import signal as _signal
import threading
import time
import warnings
from typing import Callable, Deque, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.executor import DistributedExecutor
from repro.core.faults import (CompileFailedError, FaultInjector,
                               JobFailedError, MemberFailedError, RetryPolicy)
from repro.core.grid import DataGrid
from repro.core.journal import (CheckpointPolicy, DrainInterrupted,
                                JobJournal, ResumeMismatchError, counter_push,
                                journal_dir, load_checkpoint, load_journal,
                                stable_signature, tree_digest)
from repro.core.partition import (DEFAULT_PARTITION_COUNT, PartitionTable,
                                  pad_to_shards, partition_weights_from_keys)
from repro.core.stats import DispatchStats, QueueSnapshot


class NonPow2ChunkWarning(UserWarning):
    """A ``deterministic=True`` float-sum stream was chunked at a
    non-power-of-two size: results are still deterministic FOR THIS chunking
    (replays included) but are not bit-identical to runs using a DIFFERENT
    chunk size — only equal power-of-two chunks form exact subtrees of the
    global row-aligned reduction tree (see ``_chunk_tree_reduce``)."""


# --------------------------------------------------------------- compile cache

_MISSING = object()


class CompileCache:
    """LRU cache of compiled executables keyed by (geometry, signature...).

    Insertion-ordered dict semantics with the FRONT as the eviction victim;
    ``get`` moves a hit to the back (so sweeps over many geometries never
    evict the hottest one) and counts hits/misses; ``put`` counts builds.
    Dict-style access (``len``/``in``/iteration/``[]``) peeks WITHOUT
    disturbing recency — the elastic invalidation path and tests use it to
    inspect entries.  The counters are the observable the dispatch acceptance
    tests pin: a chunk stream must build at most one executable per
    (geometry, job-signature) and hit the cache for every later chunk.
    """

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self._store: Dict[Hashable, object] = {}
        self.hits = 0
        self.misses = 0
        self.builds = 0

    # ------------------------------------------------------------ LRU access
    def get(self, key, default=None):
        val = self._store.pop(key, _MISSING)
        if val is _MISSING:
            self.misses += 1
            return default
        self._store[key] = val            # move to back: most recently used
        self.hits += 1
        return val

    def put(self, key, value, max_entries: Optional[int] = None,
            count_build: bool = True):
        """``count_build=False`` for metadata writes (cached ints, measured
        capacities) so ``builds`` keeps meaning COMPILED EXECUTABLES — the
        observable the dispatch acceptance tests pin."""
        cap = self.max_entries if max_entries is None else max_entries
        self._store.pop(key, None)
        while len(self._store) >= max(cap, 1):
            del self._store[next(iter(self._store))]   # evict the LRU front
        self._store[key] = value
        if count_build:
            self.builds += 1

    def get_or_build(self, key, builder: Callable[[], object],
                     max_entries: Optional[int] = None):
        val = self.get(key, _MISSING)
        if val is _MISSING:
            val = builder()
            self.put(key, val, max_entries)
        return val

    # ----------------------------------------------------------- maintenance
    def invalidate(self, match: Optional[Callable[[Hashable], bool]] = None
                   ) -> int:
        """Drop entries whose key satisfies ``match`` (all, when None).
        Returns the number of entries dropped — the scale-event path uses it
        to report exactly how many executables the outgoing geometry held."""
        keys = [k for k in self._store if match is None or match(k)]
        for k in keys:
            del self._store[k]
        return len(keys)

    def stats(self) -> Dict[str, int]:
        return {"size": len(self._store), "hits": self.hits,
                "misses": self.misses, "builds": self.builds}

    # ------------------------------------------------- dict-style inspection
    def __len__(self):
        return len(self._store)

    def __iter__(self):
        return iter(self._store)

    def __contains__(self, key):
        return key in self._store

    def __getitem__(self, key):          # peek: no recency update, no count
        return self._store[key]

    def __setitem__(self, key, value):   # metadata write: not an executable
        self.put(key, value, count_build=False)

    def __delitem__(self, key):
        del self._store[key]


# ----------------------------------------------------- geometry-cache registry
#
# Any module that keeps its own (mesh, axis, ...)-keyed executable cache
# registers it here at import time; a dispatcher scale event then retires the
# outgoing mesh's entries from EVERY registered cache without the middleware
# having to know client modules by name (des_scan registers its distributed
# scan cores and auto-sized exchange capacities this way).

_GEOMETRY_CACHES: List[Tuple[str, CompileCache, bool]] = []


def register_geometry_cache(name: str, cache: CompileCache,
                            counts_as_core: bool = True) -> None:
    """Register a cache whose keys lead with ``(mesh, axis, ...)`` for
    automatic retirement on scale events.  ``counts_as_core=False`` for
    metadata caches (e.g. measured exchange capacities) that should be
    dropped but not reported as retired executables."""
    _GEOMETRY_CACHES.append((name, cache, counts_as_core))


# ------------------------------------------------------- reduction primitives

def _row_tree_sum(rows, valid):
    """Position-aligned pairwise-tree sum over the leading (row) axis.

    Invalid rows are zeroed, the array is zero-padded to the next power of
    two, and adjacent pairs are combined level by level — the addition tree
    of row r is a function of r ALONE, never of the padded length.  Because
    an all-zero subtree contributes an exact ``+0.0`` (x + 0.0 == x), the
    result is BIT-identical for any pad length >= the live row count, i.e.
    for any member count's chunk padding.  This is the row-level half of the
    deterministic float reduction; ``_chunk_tree_reduce`` is the cross-chunk
    half.

    This function MUST be compiled in its own executable, never fused with
    the job's producer (see ``_build_member``): a member_fn ending in a bare
    multiply (``x * w``) otherwise compiles differently at M=1 — the whole
    chunk is one XLA fusion and the multiply contracts into the level-0
    adds as FMA (single rounding), while at M>1 the shard_map boundary
    blocks that contraction — losing member-count bit-identity for
    product-shaped jobs.  HLO-level guards (``optimization_barrier``,
    ``reduce_precision(8, 23)``, bitcast round-trips) are all folded away
    by the CPU pipeline before codegen; an executable boundary is the only
    fence LLVM's FMA contraction cannot cross."""
    mask_shape = (rows.shape[0],) + (1,) * (rows.ndim - 1)
    x = jnp.where(valid.reshape(mask_shape), rows, jnp.zeros((), rows.dtype))
    n = x.shape[0]
    p2 = 1 if n <= 1 else 1 << (n - 1).bit_length()
    if p2 != n:
        x = jnp.concatenate(
            [x, jnp.zeros((p2 - n,) + x.shape[1:], x.dtype)])
    while x.shape[0] > 1:
        x = x[0::2] + x[1::2]
    return x[0]


def _chunk_tree_reduce(parts, combine, pending=None):
    """Fixed-arity pairwise combine tree keyed on chunk index (a binary
    counter: partial subtrees of equal height merge as chunks arrive, the
    final drain folds survivors highest-level — i.e. earliest chunks —
    first).  The tree shape depends only on the number of chunks, so float
    ``reduce="sum"`` streams are deterministic for a given chunking, and —
    because equal power-of-two chunks form exact subtrees of the global
    row-aligned tree — bit-identical ACROSS power-of-two chunk sizes.  For
    int/max reductions the combine is associative and the tree is
    indistinguishable from the old left fold.

    ``pending`` seeds the counter with a RESTORED state: a checkpoint of the
    counter after k in-order chunks is exactly the pow2 subtrees of k's
    binary decomposition, so resuming pushes chunks k..n-1 through literally
    the same fold sequence the uninterrupted run would have — bit-identical
    bytes (the durable-dispatch resume guarantee)."""
    if pending is None:
        pending = {}
    for part in parts:
        counter_push(pending, part, combine)
    out = None
    for level in sorted(pending):        # ascending: latest chunks first,
        # so each fold keeps earlier chunks on the LEFT of the combine
        out = (pending[level] if out is None
               else jax.tree_util.tree_map(combine, pending[level], out))
    return out


# ------------------------------------------------------- failure detection

def _all_finite(tree) -> bool:
    """Cheap post-retirement health probe: True iff every float leaf of a
    chunk output is fully finite — the ``HealthMonitor`` docstring's "member
    crash" signal.  One device reduction + one scalar sync per float leaf on
    an ALREADY-RETIRED output (int leaves cannot encode NaN/Inf and are
    skipped); the fault-free overhead is benchmarked in BENCH_fault.json."""
    for leaf in jax.tree_util.tree_leaves(tree):
        if isinstance(leaf, np.ndarray):
            if leaf.dtype.kind == "f" and not np.isfinite(leaf).all():
                return False
        elif jnp.issubdtype(leaf.dtype, jnp.floating):
            if not bool(jnp.isfinite(leaf).all()):
                return False
    return True


@jax.jit
def _finite_probe(tree):
    """One fused all-float-leaves-finite reduction, ENQUEUED at launch so it
    overlaps the pipelined compute it guards — the validator only syncs the
    resulting scalar, which by retirement time has already been computed.
    Keeps the fault-free guarded overhead (BENCH_fault.json) to one device
    scalar sync per chunk instead of per-leaf blocking round-trips."""
    flags = [jnp.isfinite(leaf).all()
             for leaf in jax.tree_util.tree_leaves(tree)
             if jnp.issubdtype(leaf.dtype, jnp.floating)]
    if not flags:
        return jnp.asarray(True)
    return functools.reduce(jnp.logical_and, flags)


def _nonfinite_member(tree, n_rows: int, n_members: int) -> Optional[int]:
    """Attribute a non-finite chunk output to a mesh slot: leaves keeping the
    chunk's row-shaped leading dim map their first bad row to the member that
    computed it (rows are range-sharded over the executor axis).  ``None``
    when only row-free leaves (replicated aggregates) are corrupt — the
    corruption is real but unattributable, so nothing is quarantined.  Host
    work, on the failure path only."""
    shard = max(n_rows // max(n_members, 1), 1)
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(leaf)
        if arr.dtype.kind != "f" or arr.ndim < 1 or arr.shape[0] != n_rows:
            continue
        bad = ~np.isfinite(arr.reshape(n_rows, -1)).all(axis=1)
        idx = np.nonzero(bad)[0]
        if idx.size:
            return min(int(idx[0]) // shard, max(n_members, 1) - 1)
    return None


# ------------------------------------------------------------ job descriptors

@dataclasses.dataclass(frozen=True)
class DispatchJob:
    """One streaming job: how a chunk executes and how chunks combine.

    Exactly one of ``member_fn``/``global_fn`` must be set:

      member_fn(local_items, local_valid, *replicated)
          runs on each member's shard of the chunk (executeOnKeyOwner).  For
          ``reduce="concat"`` it returns per-row outputs (leading dim = the
          local shard) which the dispatcher reassembles in global row order;
          for "sum"/"max" it returns a partial aggregate which the dispatcher
          combines across members (psum/pmax) and then across chunks.
      global_fn(items, valid, *replicated)
          expresses the whole chunk as one global computation; the partitioner
          (auto-SPMD) chooses the schedule.  Cross-chunk combination still
          follows ``reduce``.

    ``local_valid``/``valid`` is a bool mask marking the chunk's live rows —
    the dispatcher pads every chunk to a fixed shard-divisible shape so the
    compile cache hits, and padded rows MUST NOT contribute to "sum"/"max"
    aggregates (mask them; for "concat" the dispatcher trims them off).

    ``signature`` is the job's static compile identity: it must determine the
    traced computation completely (the dispatcher may reuse an executable
    built from an earlier ``DispatchJob`` carrying an equal signature).

    ``deterministic`` (``reduce="sum"`` only) changes the fn contract: the
    job returns PER-ROW contributions (leading dim = rows, like "concat")
    WITHOUT masking or summing them, and the dispatcher reduces rows itself
    with a position-aligned pairwise tree (``_row_tree_sum``) and chunks
    with a fixed-arity tree keyed on chunk index — so FLOAT sums get the
    same bit-identity guarantee across member counts, mid-stream scale
    events, and (power-of-two) chunkings that int32 word count has.

    ``target_step_time`` is the job class's IAS calibration: under
    ``auto_scale`` the dispatcher feeds ``step_time_ema / target`` as the
    load sample.  ``None`` self-calibrates — the first steady-state sample
    of the job class is pinned to the neutral midpoint of the scaling
    thresholds, so only subsequent drift drives the scaler.
    """
    name: str
    signature: Hashable
    member_fn: Optional[Callable] = None
    global_fn: Optional[Callable] = None
    reduce: str = "concat"               # "concat" | "sum" | "max"
    deterministic: bool = False          # per-row tree-reduced float sum
    target_step_time: Optional[float] = None   # per-job-class IAS target
    # which seg-scan path the job's computation runs, for benchmark
    # provenance: None (lax), "compiled" (real Pallas kernel), or
    # "interpret" (off-TPU fallback) — see compat.kernel_path
    kernel_path: Optional[str] = None

    def __post_init__(self):
        if (self.member_fn is None) == (self.global_fn is None):
            raise ValueError("exactly one of member_fn/global_fn required")
        if self.reduce not in ("concat", "sum", "max"):
            raise ValueError(f"unknown reduce {self.reduce!r}")
        if self.deterministic and self.reduce != "sum":
            raise ValueError("deterministic=True requires reduce='sum'")


@dataclasses.dataclass
class DispatchReport:
    """What one ``submit`` stream did — the acceptance-test observable."""
    job: str
    n_items: int
    chunk: int
    n_chunks: int = 0
    compiles: int = 0                    # executables built this stream
    cache_hits: int = 0                  # chunks served by a cached executable
    members_per_chunk: List[int] = dataclasses.field(default_factory=list)
    scale_events: int = 0                # remesh events fired mid-stream
    wall_s: float = 0.0
    dispatch_ahead: int = 0              # pipeline depth this stream ran at
    max_in_flight: int = 0               # peak launched-but-unretired chunks
    staged_device: int = 0               # chunks cut on device (slice_chunk)
    staged_host: int = 0                 # chunks sliced/padded host-side
    # seg-scan kernel provenance (from DispatchJob.kernel_path): None for
    # the lax path, "compiled" for the real Pallas kernel, "interpret" for
    # the off-TPU fallback — so a CPU "kernel" benchmark can't silently
    # report interpreter timings as kernel timings
    kernel_path: Optional[str] = None
    ema_step_s: float = 0.0              # last step-time EMA (auto_scale)
    retries: int = 0                     # chunk replays this stream
    # structured failure record: one dict per DETECTED failure —
    # {chunk, kind, attempt, member, detail, wall_s, recovered_after_s}
    failures: List[dict] = dataclasses.field(default_factory=list)
    # one dict per forced failure remesh: the scale event's fields plus
    # {cause, dead_member, dead_device, failed_chunk, replayed_chunks,
    #  recovery_s} — recovery_s is detect-to-last-replayed-chunk-validated
    recovery_events: List[dict] = dataclasses.field(default_factory=list)
    # durable dispatch (``checkpoint=``/``resume``): where this stream's
    # journal lives, how many durable checkpoints it wrote (write latencies
    # on the background writer thread), and — on a resumed stream — the
    # journal it came from, the journaled chunks it skipped, and the lost
    # in-flight chunks it replayed
    journal_path: Optional[str] = None
    checkpoints: int = 0
    checkpoint_write_s: List[float] = dataclasses.field(default_factory=list)
    resumed_from: Optional[str] = None
    chunks_skipped: int = 0
    chunks_replayed: int = 0
    # multi-tenant serving: the tenant this stream belongs to (``submit``'s
    # ``tenant=`` — set by TenantFrontEnd so failures, journals, and stats
    # are attributable to the submitting tenant); None for direct callers
    tenant: Optional[str] = None
    # queueing-theoretic observability (``collect_stats`` / policy="mmn"):
    # per-stage latency decomposition (queue_wait / service / validate /
    # sojourn: windowed mean + percentiles, log-bucket histogram quantiles),
    # stall records, and the operational-law queue view (arrival rate,
    # throughput, utilization, mean queue length) — see repro/core/stats.py
    # and docs/observability.md.  None when instrumentation is off.
    stats: Optional[dict] = None

    def summary(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


# ------------------------------------------------------------- the dispatcher

class ElasticDispatcher:
    """Owns mesh, ownership table, compile cache, and the chunk stream.

    One instance per tenant/cluster.  ``submit`` streams a job chunk by
    chunk; between chunks the ``ElasticController`` may fire (driven by
    ``observe_load`` from an ``on_chunk`` callback, or automatically from
    measured chunk wall time when ``auto_scale=True``) and the stream
    resumes on the re-built mesh — compiled executables for the outgoing
    geometry are retired, every other geometry's stay warm.
    """

    def __init__(self, devices=None, axis: str = "data",
                 health_cfg=None, start_members: int = 1,
                 partition_count: Optional[int] = None,
                 chunk_size: Optional[int] = None,
                 cache_entries: int = 64, auto_scale: bool = False,
                 dispatch_ahead: int = 2,
                 retry_policy: Optional[RetryPolicy] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 collect_stats: bool = False,
                 checkpoint: Optional[CheckpointPolicy] = None):
        from repro.core.elastic import ElasticController, entity_pad_multiple
        from repro.core.health import HealthConfig, HealthMonitor

        self.devices = list(devices if devices is not None else jax.devices())
        self.axis = axis
        n0 = max(1, min(start_members, len(self.devices)))
        self.table = PartitionTable(
            partition_count=partition_count or DEFAULT_PARTITION_COUNT,
            n_instances=n0)
        hc = health_cfg or HealthConfig()
        hc = dataclasses.replace(
            hc, max_instances=min(hc.max_instances, len(self.devices)))
        if hc.policy not in ("ema", "mmn"):
            raise ValueError(f"unknown HealthConfig.policy {hc.policy!r}; "
                             "expected 'ema' or 'mmn'")
        self.health_cfg = hc
        # queueing observability: stamp every chunk's pipeline stages and
        # expose DispatchReport.stats.  The mmn scaling policy NEEDS the
        # measured service decomposition, so it forces collection on.
        self.collect_stats = collect_stats or hc.policy == "mmn"
        # ENTITY sizes pad to this multiple so shapes are identical at every
        # member count the IAS can reach (bit-stable scale events for the
        # elastic cluster).  Chunk streams don't need it: each geometry pads
        # chunks to its own shard multiple, and chunk rows are independent.
        self.entity_pad = entity_pad_multiple(hc, n0)
        self.controller = ElasticController(hc, n0, remesh_fn=self._remesh)
        self.cache = CompileCache(cache_entries)
        self.chunk_size = chunk_size
        self.auto_scale = auto_scale
        # pipeline depth: how many chunks may be launched ahead of the oldest
        # unretired one (0 = fully synchronous, the pre-async baseline)
        self.dispatch_ahead = max(int(dispatch_ahead), 0)
        # device-resident item sets at least this big are chunked on device
        # (``executor.slice_chunk``) instead of round-tripping through host
        # numpy; below it the extra per-chunk jit dispatch costs more than
        # the copies it saves (tests pin 0 to force the device path)
        self.device_slice_min_bytes = 1 << 20
        self.grid: Optional[DataGrid] = None
        self.scale_events: List[dict] = []
        self._key_weights: Optional[np.ndarray] = None
        # fault tolerance: default per-stream policy/injector (submit can
        # override per call), devices retired by member failure, and a
        # DEDICATED HealthMonitor fed one sample per validated chunk — kept
        # separate from the controller's monitor so failure-path walls never
        # pollute the voluntary scaler's load window
        self.retry_policy = retry_policy
        self.fault_injector = fault_injector
        # durability: default CheckpointPolicy for every stream (submit can
        # override per call) and the graceful-preemption flag — settable
        # from a signal handler / another thread, honored at the next chunk
        # boundary of the active journaled stream (see request_drain)
        self.checkpoint_policy = checkpoint
        self._drain_requested = threading.Event()
        self.dead_devices: List = []
        self.fault_monitor = HealthMonitor(hc)
        # per-job-class calibrated IAS step-time targets (auto_scale);
        # signatures pinned EXPLICITLY via calibrate_target survive the
        # failure-path calibration reset, self-calibrated ones do not
        self.job_targets: Dict[Hashable, float] = {}
        self._explicit_targets: set = set()
        # launched-but-unretired chunk outputs of the ACTIVE stream; the
        # remesh barrier drains it, exception cleanup clears it
        self._in_flight: Deque[Tuple] = collections.deque()
        self._valid_masks: Dict[Tuple[int, int], jnp.ndarray] = {}
        self._epoch = 0                  # bumped per remesh (geometry epoch)
        self._build(n0)

    @classmethod
    def for_mesh(cls, mesh, axis: Optional[str] = None) -> "ElasticDispatcher":
        """A FROZEN dispatcher bound to an existing 1-D mesh: same devices,
        same axis name, min_instances == max_instances so the IAS can never
        fire.  Lets mesh-first callers (the legacy MapReduce constructor)
        run on the unified job layer without opting into elasticity."""
        from repro.core.health import HealthConfig

        if mesh.devices.ndim != 1:
            raise ValueError("for_mesh requires a 1-D mesh, got shape "
                             f"{mesh.devices.shape}")
        axis = axis or mesh.axis_names[0]
        n = int(mesh.devices.size)
        hc = HealthConfig(min_instances=n, max_instances=n)
        return cls(devices=list(mesh.devices.ravel()), axis=axis,
                   health_cfg=hc, start_members=n)

    # --------------------------------------------------------------- topology
    def _build(self, n: int) -> None:
        self.executor = DistributedExecutor.for_devices(self.devices[:n],
                                                        self.axis)
        self.mesh = self.executor.mesh

    @property
    def n_members(self) -> int:
        return self.controller.n_instances

    def ensure_grid(self) -> DataGrid:
        """The dispatcher-owned DataGrid, created lazily on the current mesh
        and re-homed automatically on every scale event."""
        if self.grid is None:
            self.grid = DataGrid(self.mesh, axis=self.axis)
        return self.grid

    def vm_owner(self, n_keys: int) -> jnp.ndarray:
        """Current key→member ownership (the distributed cores' runtime
        operand) for int keys 0..n_keys-1."""
        return jnp.asarray(self.table.owners_of_range(n_keys))

    # ---------------------------------------------------------------- scaling
    def observe_load(self, load: float):
        """Feed one normalized load sample (observed/target) to the
        monitor→probe→IAS chain; a threshold crossing triggers ``_remesh``
        at this chunk/step boundary."""
        return self.controller.tick(load)

    def observe_key_weights(self, weights) -> None:
        """Record observed per-key load (e.g. the scan core's
        ``exchange_load`` summed per VM).  The NEXT rebalance becomes
        locality-aware: virtual partitions level by weighted load, so a hot
        key's partition stops dragging a full share of cold partitions onto
        its member (ROADMAP exchange follow-on c).  One-shot: the sample is
        CONSUMED by that rebalance — later scale events fall back to count
        leveling unless a fresh observation is fed, so a long-stale load
        profile never keeps steering placement."""
        self._key_weights = None if weights is None else np.asarray(
            weights, np.float64)

    def _partition_weights(self) -> Optional[np.ndarray]:
        if self._key_weights is None:
            return None
        return partition_weights_from_keys(self._key_weights,
                                           self.table.partition_count)

    def _remesh(self, n: int, reason: str = "scale") -> None:
        """The scale-event callback — a PIPELINE BARRIER: drain every
        in-flight chunk of the active stream, then rebalance table → retire
        exactly the outgoing geometry's executables (every registered
        geometry cache + this dispatcher's job cache) → rebuild mesh →
        re-home DataGrid → resume.  Draining first keeps the event clean
        (no old-geometry compute overlapping the new geometry's compiles)
        and is the only mid-stream synchronization the async pipeline does;
        chunk boundaries and reduce order are unaffected by how many chunks
        were in flight, so results stay bit-identical.  ``reason`` is
        "scale" for voluntary IAS events, "member_failure" for the forced
        remesh of the involuntary-departure path."""
        drained = self._drain_in_flight()
        old_mesh, axis = self.mesh, self.axis
        moved = self.table.rebalance(n, weights=self._partition_weights())
        self._key_weights = None        # one-shot: consumed by this event
        match = lambda k: k[0] == old_mesh and k[1] == axis
        retired = 0
        for _, cache, counted in _GEOMETRY_CACHES:
            dropped = cache.invalidate(match)
            if counted:
                retired += dropped
        retired_jobs = self.cache.invalidate(match)
        self._build(n)
        self._epoch += 1                # wall-clock samples spanning the
        # barrier are meaningless: the stream loop resets its timer on epoch
        if self.grid is not None:
            self.grid.remesh(self.mesh)
        self.scale_events.append(
            {"n_members": n, "moved_partitions": moved,
             "retired_cores": retired, "retired_jobs": retired_jobs,
             "drained_in_flight": drained, "reason": reason})

    def _member_failure_remesh(self, device, slot: int, report) -> dict:
        """The involuntary-departure path: retire ``device`` from the pool,
        restore any backed-up grid entries from their neighbor replicas,
        clamp the IAS ceiling to the survivors, and force a FAILURE REMESH
        (same barrier as a voluntary scale event: rebalance table → retire
        dead geometry's executables → rebuild mesh → re-home grid) onto
        ``min(n_members, survivors)`` members.  Spare pool devices beyond
        the mesh keep the member COUNT intact when possible — the Hazelcast
        model, where a standby absorbs a departed member's partitions.
        Returns the recorded scale event (reason "member_failure") for the
        caller to extend with recovery details.  Raises ``JobFailedError``
        when the survivors cannot carry the job (fewer than
        ``min_instances``) — after first shrinking the dispatcher onto
        whatever survived, so the MIDDLEWARE stays usable even when the JOB
        is lost."""
        if device in self.devices:
            self.devices.remove(device)
            self.dead_devices.append(device)
        survivors = len(self.devices)
        if survivors == 0:
            raise JobFailedError(
                "every member failed: no surviving devices", report)
        restored = (self.grid.fail_over(slot)
                    if self.grid is not None and self.grid.backup_count
                    else [])
        recoverable = survivors >= self.health_cfg.min_instances
        if not recoverable:
            # degrade the floor so the dispatcher itself stays remeshable;
            # the job still fails loudly below
            self.health_cfg.min_instances = survivors
        self.health_cfg.max_instances = min(self.health_cfg.max_instances,
                                            survivors)
        n_new = min(self.n_members, survivors)
        self.controller.force_instances(n_new)
        self._remesh(n_new, reason="member_failure")
        event = self.scale_events[-1]
        if restored:
            event["grid_restored"] = restored
        if not recoverable:
            raise JobFailedError(
                f"member at slot {slot} (device {device}) failed; "
                f"{survivors} survivor(s) < min_instances — job "
                "unrecoverable", report)
        return event

    @property
    def in_flight(self) -> int:
        """Launched-but-unretired chunks of the active stream (0 between
        streams — the exception-safety observable: a failed ``submit`` must
        never leak launched buffers)."""
        return len(self._in_flight)

    def _drain_in_flight(self) -> int:
        """Block until every launched chunk has retired.  Returns how many
        were in flight — the remesh barrier records it per scale event.
        Exception-safe: if a chunk's computation itself raises at the
        blocking point, the rest of the queue is still dropped — a stale
        chunk must never leak into (and re-raise inside) the next stream."""
        n = len(self._in_flight)
        try:
            while self._in_flight:
                _, out, _, _ = self._in_flight.popleft()
                jax.block_until_ready(out)
        finally:
            self._in_flight.clear()
        return n

    def calibrate_target(self, job: DispatchJob, target_step_time: float
                         ) -> None:
        """Pin a job class's IAS step-time target explicitly (overrides the
        first-sample self-calibration; ``job.target_step_time`` still wins).
        Explicit pins survive the failure-path calibration reset — the
        operator asserted the number, a dying stream can't falsify it."""
        self.job_targets[job.signature] = float(target_step_time)
        self._explicit_targets.add(job.signature)

    def _job_target(self, job: DispatchJob, first_sample: float) -> float:
        """Resolve the job class's step-time target: the job's own >
        previously calibrated > self-calibrate NOW so ``first_sample`` sits
        at the neutral midpoint of the scaling thresholds (load there
        triggers nothing; later drift does)."""
        if job.target_step_time is not None:
            return job.target_step_time
        target = self.job_targets.get(job.signature)
        if target is None:
            mid = 0.5 * (self.health_cfg.max_threshold
                         + self.health_cfg.min_threshold)
            target = first_sample / max(mid, 1e-9)
            self.job_targets[job.signature] = target
        return target

    # ---------------------------------------------------- durable dispatch
    def request_drain(self) -> None:
        """Ask the active JOURNALED stream to preempt gracefully: at the
        next chunk boundary it stops launching, retires + validates every
        in-flight chunk, checkpoints the validated prefix, journals a drain
        record, and raises ``DrainInterrupted`` (carrying the partial report
        and journal path) — ``resume`` picks the stream back up later.
        Thread- and signal-safe; a stream running without a
        ``CheckpointPolicy`` ignores it (nothing durable to drain to)."""
        self._drain_requested.set()

    def install_drain_signal(self, signum: int = _signal.SIGTERM) -> None:
        """Route a process signal (default SIGTERM — the preemption notice
        cluster schedulers send before SIGKILL) to ``request_drain``.  Call
        from the main thread (CPython restricts ``signal.signal``)."""
        _signal.signal(signum, lambda _s, _f: self.request_drain())

    def _env_signature(self, job: DispatchJob, B: int, chunk: int,
                       n_chunks: int, items, replicated) -> dict:
        """The JSON-able environment identity a journal header pins and
        ``resume`` re-verifies: geometry (backend/devices/axis/partition
        layout), job identity (name + process-stable signature + reduce
        semantics), and the chunk plan + dtype/shape structs.  Any
        difference makes the journaled bytes unreproducible, so resume
        refuses loudly (``ResumeMismatchError``) instead of diverging."""
        struct = [[list(a.shape[1:]), np.dtype(a.dtype).str]
                  for a in jax.tree_util.tree_leaves(items)]
        rep_struct = [[list(np.shape(a)), np.dtype(np.asarray(a).dtype).str]
                      for a in jax.tree_util.tree_leaves(replicated)]
        return {"platform": self.devices[0].platform,
                "n_devices": len(self.devices),
                "axis": self.axis,
                "partition_count": int(self.table.partition_count),
                "job": job.name,
                "signature": stable_signature(job.signature),
                "reduce": job.reduce,
                "deterministic": bool(job.deterministic),
                "n_items": int(B), "chunk": int(chunk),
                "n_chunks": int(n_chunks),
                "item_struct": struct, "rep_struct": rep_struct}

    def _restore_topology(self, snap: dict) -> None:
        """Rebuild mesh + ``PartitionTable`` from a journaled snapshot: force
        the member count (clamped to the surviving pool / IAS bounds), run
        the normal remesh barrier, then overwrite the freshly-rebalanced
        owners with the journaled map.  Restoring owners is FIDELITY (the
        locality-aware placement the dead coordinator had learned), not
        correctness — results are owner-map-invariant — so a clamped member
        count skips the owner overwrite rather than failing the resume."""
        n = max(min(int(snap["n_members"]), len(self.devices),
                    self.health_cfg.max_instances),
                self.health_cfg.min_instances)
        if n != self.n_members:
            self.controller.force_instances(n)
            self._remesh(n, reason="resume")
        if n == int(snap["n_members"]) and "owner" in snap:
            try:
                self.table.restore(
                    {"partition_count": self.table.partition_count,
                     "n_instances": n, "owner": snap["owner"]})
            except ValueError as e:
                raise ResumeMismatchError(
                    f"journaled partition snapshot does not fit this "
                    f"dispatcher: {e}") from e

    def resume(self, path, job: DispatchJob, items, *, replicated=(),
               chunk: Optional[int] = None,
               on_chunk: Optional[Callable] = None,
               dispatch_ahead: Optional[int] = None,
               retry_policy: Optional[RetryPolicy] = None,
               fault_injector: Optional[FaultInjector] = None,
               collect_stats: Optional[bool] = None,
               checkpoint: Optional[CheckpointPolicy] = None
               ) -> Tuple[object, DispatchReport]:
        """Continue a journaled stream after the coordinator died (or was
        drained).  ``path`` is the journal directory a previous ``submit``
        wrote under a ``CheckpointPolicy``; ``job``/``items``/``replicated``
        must be the same job — resume VERIFIES the environment signature
        (geometry, backend, job identity, chunk plan, dtype/shape structs)
        against the journal header and raises ``ResumeMismatchError`` on any
        difference, never silently diverging.

        A COMPLETE journal short-circuits: the final checkpoint is loaded
        (integrity-digested) and returned with ZERO chunk executions —
        ``resume`` of a finished stream is idempotent.  Otherwise the mesh
        and ``PartitionTable`` are rebuilt from the last journaled snapshot,
        the latest checkpoint's partial reduce state is restored (an exact
        pow2-subtree state of the deterministic chunk tree), journaled
        chunks before it are SKIPPED, and only the lost in-flight suffix is
        replayed — each replayed chunk digest-checked against its journal
        record.  The combined output is bit-identical to the uninterrupted
        run and is delivered on HOST (the restored base lives in host
        memory).  Returns ``(outputs, DispatchReport)`` with
        ``resumed_from`` / ``chunks_skipped`` / ``chunks_replayed`` set."""
        path = journal_dir(path)
        state = load_journal(path)
        if state.header is None:
            raise ResumeMismatchError(f"no journal header at {path!r} — "
                                      "nothing to resume")
        leaves = jax.tree_util.tree_leaves(items)
        if not leaves:
            raise ValueError("resume needs the original item arrays")
        B = int(leaves[0].shape[0])
        chunk_ = chunk if chunk is not None else (self.chunk_size or B)
        chunk_ = max(1, min(int(chunk_), max(B, 1)))
        n_chunks = max(-(-B // chunk_), 1)
        mine = self._env_signature(job, B, chunk_, n_chunks, items,
                                   replicated)
        theirs = state.header.get("env", {})
        diffs = [f"{k}: journal={theirs.get(k)!r} vs here={mine[k]!r}"
                 for k in mine if theirs.get(k) != mine[k]]
        if diffs:
            raise ResumeMismatchError(
                "journal environment signature mismatch — resuming would "
                "not reproduce the journaled bytes:\n  " + "\n  ".join(diffs))
        policy = checkpoint
        if policy is None:
            policy = CheckpointPolicy(
                path=path,
                every_n_chunks=int(state.header.get("every_n_chunks", 4)))
        elif policy.path != path:
            raise ValueError("checkpoint.path must equal the resume path")

        if state.complete is not None:
            rec = state.usable_checkpoint(final=True)
            if rec is None:
                raise ResumeMismatchError(
                    f"journal at {path!r} is complete but its final "
                    "checkpoint directory is missing")
            outputs, _ = load_checkpoint(path, rec)
            report = DispatchReport(
                job=job.name, n_items=B, chunk=chunk_, n_chunks=n_chunks,
                journal_path=path, resumed_from=path,
                chunks_skipped=n_chunks, chunks_replayed=0,
                kernel_path=job.kernel_path)
            return outputs, report

        snap = state.last_snapshot
        if snap is not None:
            self._restore_topology(snap)
        base_k, base_state = 0, None
        rec = state.usable_checkpoint()
        if rec is not None:
            base_state, manifest = load_checkpoint(path, rec)
            base_k = int(manifest["k"])
        digests = {ci: r["digest"] for ci, r in state.chunks.items()
                   if ci >= base_k and r.get("digest")}
        journal = JobJournal.reopen(policy)
        journal.append({"type": "resume", "k": base_k,
                        "replayed_from": base_k}, fsync=True)
        return self.submit(
            job, items, replicated=replicated, chunk=chunk_,
            on_chunk=on_chunk, dispatch_ahead=dispatch_ahead,
            deliver="host", retry_policy=retry_policy,
            fault_injector=fault_injector, collect_stats=collect_stats,
            checkpoint=policy,
            _resume={"journal": journal, "path": path, "base_k": base_k,
                     "base_state": base_state, "digests": digests})

    # ------------------------------------------------------------- submission
    def submit(self, job: DispatchJob, items, *, replicated=(),
               chunk: Optional[int] = None,
               on_chunk: Optional[Callable] = None,
               dispatch_ahead: Optional[int] = None,
               deliver: str = "device",
               retry_policy: Optional[RetryPolicy] = None,
               fault_injector: Optional[FaultInjector] = None,
               collect_stats: Optional[bool] = None,
               checkpoint: Optional[CheckpointPolicy] = None,
               tenant: Optional[str] = None,
               _resume: Optional[dict] = None
               ) -> Tuple[object, DispatchReport]:
        """Stream ``items`` (a pytree of arrays sharing leading dim B)
        through ``job`` in fixed-shape chunks, as an ASYNC double-buffered
        pipeline.

        Every chunk is padded to ``pad_to_shards(chunk, n_members)`` rows
        (live rows flagged by the valid mask), so all chunks of a geometry
        share ONE executable — grids larger than device memory stream with
        at most one compile per (geometry, job-signature).

        Pipelining: chunk k+1 is staged (sliced + padded) and dispatched
        while chunk k still runs on device — JAX dispatch is asynchronous,
        so the host never blocks mid-stream except to (1) bound the queue at
        ``dispatch_ahead`` launched-but-unretired chunks (memory bound;
        0 = fully synchronous baseline) and (2) take the wall-time samples
        the IAS needs.  The only other synchronization points are the
        REMESH BARRIER (``_remesh`` drains the queue before rebuilding) and
        the final reduce.  Chunk boundaries and reduce order never depend on
        how many chunks were in flight, so results are bit-identical to the
        synchronous path for every scale sequence.

        Staging: a DEVICE-resident item set (every leaf a ``jax.Array``) of
        at least ``device_slice_min_bytes`` never round-trips to host — the
        source is padded once on device and chunks are cut with
        ``executor.slice_chunk`` (``lax.dynamic_slice`` + valid masking);
        host-resident (or tiny, where an extra per-chunk jit dispatch costs
        more than the copies it saves) items use numpy slicing as before.
        When no scale event fired mid-stream, outputs stay on device and are
        exposed LAZILY (callers chain them into the next job or block at
        their own reduce boundary); a remesh mixes geometries, so the final
        combine falls back to host.

        After each chunk ``on_chunk(dispatcher, chunk_index, n_chunks)``
        runs (feed ``observe_load`` there to drive the IAS
        deterministically).  With ``auto_scale=True`` the dispatcher instead
        feeds an EMA of measured retirement-to-retirement step times over
        the job class's ``target_step_time`` (see ``_job_target``) — one
        ``block_until_ready`` per sample, exactly where the IAS needs a
        wall-time reading, never a per-chunk stop-the-world.

        ``deliver`` places the final reduce: "device" (default) keeps it
        lazy on device — the right choice when the output chains into
        another job; "host" materializes it at the reduce boundary — the
        right choice when the caller converts to numpy immediately (one
        gather instead of a sharded device concat PLUS a gather; the values
        are bitwise identical either way).

        Fault tolerance: ``retry_policy`` / ``fault_injector`` (falling back
        to the dispatcher-level defaults) arm the GUARDED retirement path —
        every chunk is validated on retirement (deadline, optional finite
        check), detected failures are retried under the policy's budget,
        repeat-offender members are quarantined via a forced failure remesh,
        and the failed plus lost in-flight chunks are REPLAYED; because the
        combine below walks chunk INDEX order, a recovered stream is
        bit-identical to a fault-free run.  Without either, the fault-free
        fast path is byte-for-byte the unguarded pipeline.  Unrecoverable
        streams raise ``JobFailedError`` carrying the report.  Returns
        ``(outputs, DispatchReport)``.

        Durability: ``checkpoint`` (a ``CheckpointPolicy``, falling back to
        the dispatcher default) journals the stream — header with the
        environment signature and chunk plan, a digest record per validated
        chunk, fault and scale records (with partition snapshots) — and
        atomically persists the partial reduce state every
        ``every_n_chunks`` validated chunks (pow2-aligned boundaries of the
        deterministic chunk tree; writes overlap on a background thread).
        Kill the coordinator at ANY point and ``resume(path, ...)``
        reproduces the uninterrupted bytes; ``request_drain`` /
        ``install_drain_signal`` turn preemption notices into a graceful
        checkpoint + ``DrainInterrupted``.  A ``JobFailedError``'s report
        is journaled before raising, so post-mortems survive process death.
        ``_resume`` is the private handoff from ``resume`` (restored base
        state, chunks to skip, digests to re-verify).
        """
        if deliver not in ("device", "host"):
            raise ValueError(f"unknown deliver {deliver!r}")
        if tenant is not None:
            # tenant-scoped stream: bind the fault injector so tenant-
            # addressed specs fire only inside THIS stream (replays
            # included), and tag the report — JobFailedError reports too,
            # so a failed tenant's post-mortem names its owner
            inj = (fault_injector if fault_injector is not None
                   else self.fault_injector)
            ctx = (inj.bind_tenant(tenant) if inj is not None
                   else contextlib.nullcontext())
            try:
                with ctx:
                    out, rep = self.submit(
                        job, items, replicated=replicated, chunk=chunk,
                        on_chunk=on_chunk, dispatch_ahead=dispatch_ahead,
                        deliver=deliver, retry_policy=retry_policy,
                        fault_injector=fault_injector,
                        collect_stats=collect_stats, checkpoint=checkpoint,
                        _resume=_resume)
            except JobFailedError as e:
                e.report.tenant = tenant
                raise
            rep.tenant = tenant
            return out, rep
        leaves = jax.tree_util.tree_leaves(items)
        if not leaves:
            raise ValueError("submit needs at least one item array")
        B = int(leaves[0].shape[0])
        if any(int(l.shape[0]) != B for l in leaves):
            raise ValueError("item arrays must share their leading dim")
        chunk = chunk if chunk is not None else (self.chunk_size or B)
        chunk = max(1, min(int(chunk), max(B, 1)))
        # B == 0 still runs ONE fully-padded chunk (valid all-False): concat
        # outputs trim to correct empty arrays, sum/max partials reduce over
        # masked-out rows only — parity with the non-dispatcher vmap path
        n_chunks = max(-(-B // chunk), 1)
        depth = (self.dispatch_ahead if dispatch_ahead is None
                 else max(int(dispatch_ahead), 0))
        # device-side chunk slicing pays one extra jit dispatch per chunk to
        # save the host round-trip — worth it exactly when the item set is
        # big enough for the copies to matter.  Tiny item sets (a grid's
        # per-variant scalars) stage faster through numpy.  depth 0
        # reproduces the legacy synchronous path end to end: items round-
        # trip through host numpy exactly as the pre-async dispatcher staged
        # them.
        n_bytes = sum(l.size * l.dtype.itemsize for l in leaves)
        on_device = (depth > 0 and B > 0
                     and n_bytes >= self.device_slice_min_bytes
                     and all(isinstance(l, jax.Array) for l in leaves))
        if on_device:
            src = self._pad_device_source(items, chunk, n_chunks, B)
        else:
            items_np = jax.tree_util.tree_map(np.asarray, items)

        policy = (retry_policy if retry_policy is not None
                  else self.retry_policy)
        injector = (fault_injector if fault_injector is not None
                    else self.fault_injector)
        if policy is None:
            # an injector without an explicit policy still needs a detector:
            # default attempt budget with the finiteness probe armed
            policy = RetryPolicy(check_finite=injector is not None)
        guarded = injector is not None or policy.active
        # per-stage queueing stats: enqueue → dispatch → retire → validate
        # stamps per chunk.  Collection never touches chunk payloads or
        # reduce order (results stay bit-identical); the mmn policy depends
        # on the measured service decomposition, so it forces a collector.
        collect = (self.collect_stats if collect_stats is None
                   else collect_stats)
        mmn = self.health_cfg.policy == "mmn"
        collector = (DispatchStats(warmup=self.health_cfg.stats_warmup,
                                   cooldown=self.health_cfg.stats_cooldown)
                     if (collect or mmn) else None)
        launch_epoch: Dict[int, int] = {}  # chunk -> epoch at its launch
        if job.deterministic and n_chunks > 1 and chunk & (chunk - 1) != 0:
            warnings.warn(
                f"deterministic float sum chunked at {chunk} (not a power of"
                " two): results are deterministic and replay-stable for THIS"
                " chunking but not bit-identical across chunk sizes — use a"
                " power-of-two chunk for the cross-chunking guarantee",
                NonPow2ChunkWarning, stacklevel=2)

        report = DispatchReport(job=job.name, n_items=B, chunk=chunk,
                                n_chunks=n_chunks, dispatch_ahead=depth,
                                kernel_path=job.kernel_path)
        hits0, builds0 = self.cache.hits, self.cache.builds
        events0 = len(self.scale_events)
        # durability: open (or adopt, on resume) the stream's journal and
        # track the checkpointable validated prefix.  ``ck`` holds the
        # durable reduce state: k = folded prefix length, state = the
        # binary-counter pending dict (sum/max) or concatenated prefix
        # (concat), done = journaled chunk indices, host = validated host
        # copies awaiting the next fold, digests = journaled digests a
        # resumed run re-verifies its replays against.
        ckpolicy = (checkpoint if checkpoint is not None
                    else self.checkpoint_policy)
        journal: Optional[JobJournal] = None
        ck: Optional[dict] = None
        base_k = 0
        if ckpolicy is not None:
            if _resume is not None:
                journal = _resume["journal"]
                base_k = int(_resume["base_k"])
                report.resumed_from = _resume["path"]
                report.chunks_skipped = base_k
                report.chunks_replayed = n_chunks - base_k
                base_state = _resume["base_state"]
            else:
                env = self._env_signature(job, B, chunk, n_chunks, items,
                                          replicated)
                journal = JobJournal.create(ckpolicy, {
                    "env": env, "n_members": self.n_members,
                    "owner": self.table.owner.tolist(),
                    "every_n_chunks": ckpolicy.every_n_chunks})
                base_state = None
            ck = {"k": base_k, "state": base_state, "done": set(),
                  "host": {}, "digests": dict(_resume["digests"])
                  if _resume is not None else {},
                  "stride": ckpolicy.every_n_chunks, "n_scale": 0}
            report.journal_path = journal.path
        # per-chunk results indexed by chunk: trimmed row outputs (concat) or
        # partial aggregates (sum/max/deterministic).  A REPLAY overwrites
        # its chunk's slot; the combine walks slots in chunk-index order, so
        # retries and recoveries never perturb the reduce tree.  A resumed
        # stream fills only slots >= base_k — the skipped prefix lives in
        # the restored checkpoint state.
        parts: List[Optional[Tuple[int, object]]] = [None] * n_chunks
        part_epochs = set()  # geometries the parts live on
        alpha = getattr(self.health_cfg, "ema_alpha", 0.4)
        stream = {"t_mark": None, "ema": None, "epoch": self._epoch}
        queue: Deque[int] = collections.deque(range(base_k, n_chunks))
        if collector is not None:
            # a submit stream is a CLOSED arrival process: every chunk is
            # ready at stream start, so they share one enqueue stamp and
            # queue_wait measures time spent behind the pipeline bound
            t0_enq = collector.clock()
            for _ci in range(base_k, n_chunks):
                collector.enqueue(_ci, t0_enq)
        fired_cb: set = set()             # chunks whose on_chunk has run
        attempts: Dict[int, int] = collections.Counter()
        strikes: Dict = collections.Counter()  # retryable failures / device
        # retired-but-unvalidated chunks (guarded path): mirrors _in_flight
        # plus whatever a barrier drained before validation could run
        pending_val: Deque[Tuple] = collections.deque()
        open_recoveries: List[dict] = []  # member recoveries awaiting replays
        fail_t: Dict[int, float] = {}     # chunk -> last failure detect time
        val_step = [0]
        # unguarded journaled streams: launched chunks not yet journaled —
        # a remesh barrier retires in-flight chunks without passing through
        # retire_oldest, so journal_settled sweeps them up afterwards
        unjournaled: set = set()

        def journal_scales():
            """Journal scale events fired since the last call, each with the
            post-event member count and partition-owner snapshot — what
            ``resume`` rebuilds the topology from."""
            while journal is not None and \
                    ck["n_scale"] < len(self.scale_events) - events0:
                ev = self.scale_events[events0 + ck["n_scale"]]
                journal.append({"type": "scale", "event": ev,
                                "n_members": self.n_members,
                                "owner": self.table.owner.tolist()})
                ck["n_scale"] += 1

        def advance_checkpoint(force: bool = False):
            """Fold newly-contiguous validated chunks into the durable
            reduce state and persist it (atomic dir) when a stride boundary
            is crossed — or at the exact watermark when a drain forces it.
            The binary-counter state after ANY validated prefix k is exactly
            the pow2 subtrees of k's binary decomposition, so every
            checkpoint is an exact subtree state of the deterministic chunk
            tree and resume is bit-identical.  Runs on the journal WRITER
            thread (the tail of each ``finish_chunk``); the drain path is
            the one dispatch-thread caller, and only after ``journal.wait``
            has idled the queue."""
            w = ck["k"]
            while w in ck["host"]:
                w += 1
            boundary = w if force else (w // ck["stride"]) * ck["stride"]
            if boundary <= ck["k"] or (not force and boundary >= n_chunks):
                return                   # completion writes the final state
            if job.reduce == "concat":
                pieces = ([] if ck["state"] is None else [ck["state"]])
                pieces += [ck["host"].pop(ci)
                           for ci in range(ck["k"], boundary)]
                ck["state"] = jax.tree_util.tree_map(
                    lambda *xs: np.concatenate(xs, axis=0), *pieces)
                kind = "prefix"
            else:
                combine = np.add if job.reduce == "sum" else np.maximum
                # shallow-copy so a resume's restored base dict is never
                # mutated — the final combine still needs it untouched
                pending = dict(ck["state"] or {})
                for ci in range(ck["k"], boundary):
                    counter_push(pending, ck["host"].pop(ci), combine)
                ck["state"] = pending
                kind = "pending"
            ck["k"] = boundary
            journal.checkpoint_now(boundary, kind, ck["state"],
                                   {"n_members": self.n_members})

        def finish_chunk(ci: int, out, n_live: int, record: dict):
            """Writer-thread tail of ``journal_chunk``: gather the validated
            partial to host (trimmed for concat), digest it, write the chunk
            record, stage the host copy for the fold, and advance the
            checkpoint watermark.  Everything here walks output bytes —
            keeping it off the dispatch thread is what makes fault-free
            journaling overhead a queue put per chunk."""
            host = jax.tree_util.tree_map(np.asarray, out)
            if job.reduce == "concat":
                host = jax.tree_util.tree_map(lambda a: a[:n_live], host)
            if "digest" not in record:
                record["digest"] = tree_digest(host)
            journal.sync_append(record)
            ck["host"][ci] = host
            advance_checkpoint()

        def journal_chunk(ci: int):
            """Close the durable books on one FINAL chunk (validated on the
            guarded path, retired on the unguarded one).  The heavy tail —
            host gather, digest, fold, checkpoint — rides the journal
            writer thread via ``defer``; only a resumed replay digests HERE,
            inline, because a divergent replay must stop the stream
            immediately, not surface after more chunks launched."""
            if journal is None or ci in ck["done"] or ci < base_k:
                return
            n_live, out = parts[ci]
            record = {"type": "chunk", "chunk": int(ci),
                      "attempt": int(attempts[ci]), "n_live": int(n_live)}
            expect = ck["digests"].get(ci)
            if expect is not None:
                host = jax.tree_util.tree_map(np.asarray, out)
                if job.reduce == "concat":
                    host = jax.tree_util.tree_map(lambda a: a[:n_live],
                                                  host)
                digest = tree_digest(host)
                if digest != expect:
                    raise ResumeMismatchError(
                        f"replayed chunk {ci} digest {digest[:12]}… does "
                        f"not match the journaled {expect[:12]}… — the "
                        "items or job differ from the journaled stream")
                record["digest"] = digest
                out = host               # gathered once; the fold reuses it
            elif not ckpolicy.digest_chunks:
                record["digest"] = None
            journal.defer(lambda c=ci, o=out, nl=n_live, r=record:
                          finish_chunk(c, o, nl, r))
            ck["done"].add(ci)
            unjournaled.discard(ci)
            journal_scales()

        def journal_settled():
            """Unguarded path only: journal launched chunks that have left
            the flight queue without passing through ``retire_oldest`` — a
            remesh barrier's ``_drain_in_flight`` blocks until they are
            ready, so anything launched and no longer in flight is FINAL."""
            if journal is None or guarded or not unjournaled:
                return
            flying = {entry[0] for entry in self._in_flight}
            for ci in sorted(unjournaled - flying):
                journal_chunk(ci)

        def drain_now():
            """Graceful preemption (``request_drain``/SIGTERM): stop
            launching, retire + validate everything in flight, checkpoint
            the exact validated watermark, journal the drain, and raise
            ``DrainInterrupted`` — ``resume`` continues the stream later."""
            self._drain_requested.clear()
            while self._in_flight:
                retire_oldest()
            if guarded:
                sync_validation()
            journal_settled()
            journal_scales()
            journal.wait()               # settle the deferred fold tails so
            # ck["k"]/["state"] are this thread's to touch
            advance_checkpoint(force=True)
            journal.append({"type": "drain", "k": int(ck["k"]),
                            "remaining": sorted(queue)}, fsync=True)
            journal.wait()
            report.checkpoints = journal.n_checkpoints
            report.checkpoint_write_s = list(journal.write_s)
            if collector is not None:
                for w_s in journal.write_s:
                    collector.record_checkpoint(w_s)
                report.stats = collector.summary(n_servers=1)
            report.wall_s = time.perf_counter() - t_start
            journal.close()
            raise DrainInterrupted(
                f"stream of job {job.name!r} drained at validated prefix "
                f"{ck['k']}/{n_chunks} on request", report, journal.path)

        def mark(compiled: bool, t_launch: float):
            """Sample one per-chunk step time — the retirement-to-retirement
            wall delta in pipelined steady state, or launch-to-completion
            when nothing retired before this chunk (short streams) — and,
            under auto_scale, feed EMA/target to the IAS.  Compile chunks
            and remesh barriers reset the timer instead of polluting the
            EMA — their wall is trace/compile or rebuild noise, often
            10-100x the steady state, and would ratchet the scaler to
            max_instances."""
            now = time.perf_counter()
            if compiled or stream["epoch"] != self._epoch:
                stream["epoch"] = self._epoch
                stream["t_mark"] = now
                return
            since = (t_launch if stream["t_mark"] is None
                     else max(stream["t_mark"], t_launch))
            dt, stream["t_mark"] = now - since, now
            stream["ema"] = (dt if stream["ema"] is None
                             else alpha * dt + (1.0 - alpha) * stream["ema"])
            report.ema_step_s = stream["ema"]
            if self.auto_scale and on_chunk is None:
                if mmn and collector is not None:
                    # queue-aware feed: measured per-member service rate vs
                    # the demand anchor 1/target.  Closed streams have no
                    # meaningful arrival process, so queue_length stays 0 —
                    # backlog is pipeline structure, not unmet demand (open
                    # callers like serve/ pass a measured Lq themselves).
                    s = collector.mean_service()
                    if math.isfinite(s) and s > 0:
                        target = self._job_target(job, s)
                        self.controller.tick_queue(QueueSnapshot(
                            arrival_rate=1.0 / target,
                            service_rate=1.0 / (s * self.n_members),
                            n_members=self.n_members,
                            queue_length=0.0))
                else:
                    self.observe_load(stream["ema"]
                                      / self._job_target(job, stream["ema"]))

        def retire_oldest():
            """Block on the oldest launched chunk, then sample; the guarded
            path validates every chunk that has left the flight queue."""
            ci, out, compiled, t_launch = self._in_flight.popleft()
            jax.block_until_ready(out)
            if collector is not None:
                # stamp BEFORE mark() so the mmn feed sees a fresh mean
                tainted = compiled or launch_epoch.get(ci) != self._epoch
                collector.retire(ci, tainted=tainted)
                if not guarded:
                    collector.validate(ci, tainted=tainted)
            mark(compiled, t_launch)
            if guarded:
                sync_validation()
            elif journal is not None:
                journal_chunk(ci)        # unguarded: retirement is final

        def note_validated(ci: int, now: float):
            """Close the books on a validated chunk: stamp the recovery
            latency on its latest failure record and on any open
            member-failure recovery awaiting its replay."""
            t0 = fail_t.pop(ci, None)
            if t0 is not None:
                for rec in reversed(report.failures):
                    if rec["chunk"] == ci and "recovered_after_s" not in rec:
                        rec["recovered_after_s"] = now - t0
                        break
            for open_rec in open_recoveries[:]:
                open_rec["outstanding"].discard(ci)
                if not open_rec["outstanding"]:
                    open_rec["event"]["recovery_s"] = now - open_rec["t0"]
                    open_recoveries.remove(open_rec)
            journal_chunk(ci)            # guarded: validation is final

        def recover_member(device, slot: int, failed_ci: int, cause: str):
            """Member-failure recovery: the replay set is the failed chunk
            plus every launched-but-unvalidated chunk (their buffers may
            live on the dead member); drain the survivors, force the
            failure remesh, and requeue the replays in ascending order."""
            t0 = time.perf_counter()
            lost = sorted({failed_ci}
                          | {entry[0] for entry in pending_val}
                          | {entry[0] for entry in self._in_flight})
            self._drain_in_flight()
            pending_val.clear()
            strikes.pop(device, None)
            event = self._member_failure_remesh(device, slot, report)
            event.update({"cause": cause, "dead_member": slot,
                          "dead_device": str(device),
                          "failed_chunk": failed_ci,
                          "replayed_chunks": lost})
            report.recovery_events.append(event)
            report.retries += len(lost)
            open_recoveries.append(
                {"event": event, "t0": t0, "outstanding": set(lost)})
            for ci in reversed(lost):
                queue.appendleft(ci)
                if collector is not None:
                    collector.enqueue(ci)

        def fail_chunk(ci: int, kind: str, member=None, detail: str = "",
                       wall=None):
            """Record one retryable chunk failure, enforce the attempt
            budget, quarantine a repeat-offender member, back off, and
            requeue the chunk for replay."""
            attempts[ci] += 1
            fail_t[ci] = time.perf_counter()
            report.failures.append(
                {"chunk": ci, "kind": kind, "attempt": attempts[ci],
                 "member": member, "detail": detail, "wall_s": wall})
            if journal is not None:      # retry/fault events are durable too
                journal.append({"type": "fault", "chunk": int(ci),
                                "kind": kind, "attempt": int(attempts[ci]),
                                "member": member, "detail": detail})
            if attempts[ci] >= policy.max_attempts:
                raise JobFailedError(
                    f"chunk {ci} of job {job.name!r} failed {attempts[ci]}x"
                    f" (last: {kind}); attempts exhausted (max_attempts="
                    f"{policy.max_attempts})", report)
            if member is not None and policy.quarantine_after > 0:
                mesh_devices = self.executor.device_list
                dev = mesh_devices[member % len(mesh_devices)]
                strikes[dev] += 1
                # quarantine only when the pool can afford to lose the
                # member; otherwise keep retrying under the attempt budget
                can_drop = (len(self.devices) - 1
                            >= max(1, self.health_cfg.min_instances))
                if strikes[dev] >= policy.quarantine_after and can_drop:
                    recover_member(
                        dev, member, ci,
                        cause=(f"quarantined: {strikes[dev]} retryable "
                               f"failures attributed to one member "
                               f"(last: {kind})"))
                    return
            report.retries += 1
            backoff = policy.backoff_for(attempts[ci])
            if backoff > 0:
                time.sleep(backoff)
            queue.appendleft(ci)
            if collector is not None:
                collector.enqueue(ci)

        def validate(ci, out, t_launch, M, L, fin=None, compiled=False):
            """Guarded retirement: fire any scheduled stall, take the
            chunk's wall, sync the finiteness probe (``fin``, enqueued at
            launch — falls back to a blocking ``_all_finite`` when no probe
            was dispatched), feed the detector monitor, and route detected
            failures to ``fail_chunk``."""
            delay, stall_slot = (injector.stall_for(ci) if injector
                                 else (0.0, None))
            if delay > 0:
                time.sleep(delay)         # the hung launch: retirement late
            now = time.perf_counter()
            wall = now - t_launch
            tainted = compiled or launch_epoch.get(ci) != self._epoch
            finite = True
            if policy.check_finite or injector is not None:
                finite = bool(fin) if fin is not None else _all_finite(out)
            member_times = None
            if stall_slot is not None:
                member_times = [max(wall - delay, 0.0)] * M
                member_times[stall_slot % M] = wall
            val_step[0] += 1
            self.fault_monitor.observe_chunk(
                step=val_step[0], wall_s=wall, finite=finite,
                member_times=member_times, tainted=tainted)
            if collector is not None and delay > 0:
                collector.record_stall(delay)
            if not finite:
                if collector is not None:
                    # a failed attempt's wall is fault noise: keep the
                    # record's time integrals, drop it from the windows
                    collector.validate(ci, t=now, tainted=True)
                fail_chunk(ci, "nan_poison",
                           member=_nonfinite_member(out, L, M),
                           detail="non-finite chunk output", wall=wall)
                return
            if (policy.chunk_timeout_s is not None
                    and wall > policy.chunk_timeout_s):
                if collector is not None:
                    collector.validate(ci, t=now, tainted=True)
                fail_chunk(
                    ci, "stall", member=stall_slot,
                    detail=(f"wall {wall:.3f}s exceeded deadline "
                            f"{policy.chunk_timeout_s}s (straggler skew "
                            f"{self.fault_monitor.straggler_skew():.2f})"),
                    wall=wall)
                return
            if collector is not None:
                collector.validate(ci, t=now, tainted=tainted)
            note_validated(ci, now)

        def sync_validation():
            """Validate every chunk that has left the flight queue —
            normal retirements AND remesh-barrier drains."""
            while len(pending_val) > len(self._in_flight):
                ci, out, t_launch, M, L, fin, compiled = pending_val.popleft()
                validate(ci, out, t_launch, M, L, fin, compiled)

        def launch(ci: int) -> bool:
            """Stage + compile + dispatch chunk ``ci``.  Returns False when
            a fault hook failed the launch (the chunk was requeued, or a
            member recovery already re-queued the replay set)."""
            lo, hi = ci * chunk, min((ci + 1) * chunk, B)
            n_live = hi - lo
            M = self.executor.n_members
            L = pad_to_shards(chunk, M)
            if injector is not None:
                try:
                    injector.on_launch(ci, self.executor.device_list)
                except MemberFailedError as e:
                    # the MEMBER failed, not the chunk: no attempt consumed
                    report.failures.append(
                        {"chunk": ci, "kind": "member_crash",
                         "attempt": attempts[ci], "member": e.member,
                         "detail": str(e), "wall_s": None})
                    if journal is not None:
                        journal.append(
                            {"type": "fault", "chunk": int(ci),
                             "kind": "member_crash", "member": e.member,
                             "detail": str(e)})
                    recover_member(e.device, e.member, ci,
                                   cause="member crash detected at launch")
                    return False
            if on_device:
                sl, valid = self.executor.slice_chunk(src, lo, L, n_live)
                report.staged_device += 1
            else:
                sl, valid = self._stage_host(items_np, lo, n_live, L)
                report.staged_host += 1
            builds_before = self.cache.builds
            try:
                if injector is not None:
                    injector.on_compile(ci)
                fn = self._executable(job, sl, replicated, L)
            except CompileFailedError as e:
                fail_chunk(ci, "compile_fail", detail=str(e))
                return False
            compiled_now = self.cache.builds != builds_before
            t_launch = time.perf_counter()
            launch_epoch[ci] = self._epoch
            if collector is not None:
                collector.dispatch(ci, t_launch, tainted=compiled_now)
            out = fn(sl, valid, *replicated)         # async dispatch
            # (deterministic jobs: the executable itself tree-reduced
            # the rows, so `out` is already the chunk partial)
            if injector is not None:
                out = injector.maybe_poison(ci, out, L, M)
            if depth == 0:
                # synchronous baseline (``streamed_sync``): materialize
                # the chunk on host NOW — one blocking D2H per chunk,
                # exactly the pre-async behavior this pipeline replaces
                out = jax.tree_util.tree_map(np.asarray, out)
                if collector is not None:
                    collector.retire(ci, tainted=compiled_now)
                    if not guarded:
                        collector.validate(ci, tainted=compiled_now)
                mark(compiled_now, t_launch)
            else:
                self._in_flight.append((ci, out, compiled_now, t_launch))
                report.max_in_flight = max(report.max_in_flight,
                                           len(self._in_flight))
            # combine lazily, in chunk order — retirement (blocking) is
            # decoupled from reduction, so order never depends on how
            # many chunks are in flight.  concat rows are trimmed at the
            # reduce boundary, not here: an eager mid-stream slice of an
            # unevenly-sharded chunk would cost a per-chunk reshard
            parts[ci] = (n_live, out)
            if journal is not None and not guarded:
                unjournaled.add(ci)
            part_epochs.add(self._epoch)
            report.members_per_chunk.append(M)
            if guarded:
                if depth == 0:
                    # sync baseline: out is already host numpy — the cheap
                    # np fallback inside validate covers it
                    validate(ci, out, t_launch, M, L, compiled=compiled_now)
                else:
                    fin = (_finite_probe(out)
                           if policy.check_finite or injector is not None
                           else None)
                    pending_val.append(
                        (ci, out, t_launch, M, L, fin, compiled_now))
            if depth == 0 and not guarded:
                journal_chunk(ci)        # sync baseline: launch is final
            return True

        t_start = time.perf_counter()
        try:
            while queue:
                journal_settled()        # barrier-drained chunks are final
                if journal is not None and self._drain_requested.is_set():
                    drain_now()          # raises DrainInterrupted
                ci = queue.popleft()
                if not launch(ci):
                    continue
                if on_chunk is not None and ci not in fired_cb:
                    # scale schedules stay deterministic under faults: the
                    # callback fires once per chunk INDEX, on its first
                    # launch, never again on replays
                    fired_cb.add(ci)
                    on_chunk(self, ci, n_chunks)
                    if guarded:
                        sync_validation()   # an on_chunk remesh drained
                while len(self._in_flight) > depth:
                    retire_oldest()
                if queue:
                    continue
                # tail of the stream (validation failures may refill queue)
                # (a collector must also block-retire the tail: lazy drop
                # would leave its last chunks' retire/validate un-stamped;
                # a journaled stream must retire every chunk through
                # journal_chunk, so it never lazy-drops either)
                if (guarded or collector is not None or journal is not None
                        or (self.auto_scale and on_chunk is None)):
                    # the IAS needs samples even from streams shorter than
                    # the pipeline depth, and the guarded path must block
                    # to validate: drain the tail WITH sampling (short
                    # streams fall back to launch-to-completion walls)
                    while self._in_flight and not queue:
                        retire_oldest()
                    if guarded and not queue:
                        sync_validation()
                else:
                    # lazy delivery: drop the queue without blocking —
                    # `parts` keeps the arrays alive, the in-flight bound
                    # was enforced chunk by chunk, and the caller blocks at
                    # its own reduce boundary (host delivery materializes
                    # right below anyway)
                    self._in_flight.clear()
        except DrainInterrupted:
            raise                        # graceful preemption, not a dying
            # stream: the journal is closed, calibration stays valid
        except Exception as exc:
            # durable post-mortem: a JobFailedError's structured report is
            # journaled BEFORE raising (it would otherwise die with the
            # coordinator); other exceptions leave an aborted marker.  Best
            # effort — a failing journal must not mask the real error.
            if journal is not None:
                try:
                    if isinstance(exc, JobFailedError):
                        journal.append(
                            {"type": "job_failed", "message": str(exc),
                             "report": exc.report.summary()}, fsync=True)
                    else:
                        journal.append({"type": "aborted",
                                        "error": repr(exc)}, fsync=True)
                    journal.close()
                except Exception:
                    pass
            # a dying stream must not poison the job class's IAS
            # calibration: its compile/retry-inflated first sample would
            # steer the NEXT stream's scaler (explicit calibrate_target
            # pins survive — the operator asserted those)
            if job.signature not in self._explicit_targets:
                self.job_targets.pop(job.signature, None)
            raise
        finally:
            # exception mid-stream (a failing on_chunk, a bad replicated
            # operand, an unrecoverable fault): quiesce and forget every
            # launched chunk so the dispatcher is reusable and no buffer
            # outlives the stream
            self._drain_in_flight()

        # one geometry throughout, an async stream, and device delivery:
        # combine on device and expose the result lazily; host delivery, a
        # mid-stream remesh (parts on different device sets), a resumed
        # stream (the restored base lives in host memory) or the
        # synchronous baseline (parts already np, legacy host-output
        # semantics) combine on host
        combine_on_device = (deliver == "device" and depth > 0
                             and len(part_epochs) <= 1 and _resume is None)
        resume_base = None if _resume is None else _resume["base_state"]
        outputs = self._combine(job, parts[base_k:], combine_on_device,
                                base=resume_base)
        if journal is not None:
            # completion is durable too: journal any straggler chunks and
            # tail scale events, persist the combined output as the FINAL
            # checkpoint, mark the stream complete (fsync'd) — resuming a
            # complete journal then returns this state with zero executions
            journal_settled()
            journal_scales()
            host_out = jax.tree_util.tree_map(np.asarray, outputs)
            journal.write_checkpoint(n_chunks, "final", host_out,
                                     {"n_members": self.n_members})
            journal.append({"type": "complete", "n_chunks": n_chunks},
                           fsync=True)
            journal.wait()
            report.checkpoints = journal.n_checkpoints
            report.checkpoint_write_s = list(journal.write_s)
            if collector is not None:
                for w_s in journal.write_s:
                    collector.record_checkpoint(w_s)
            journal.close()
            self._drain_requested.clear()  # a drain that lost the race to
            # completion must not preempt the NEXT stream
        report.compiles = self.cache.builds - builds0
        report.cache_hits = self.cache.hits - hits0
        report.scale_events = len(self.scale_events) - events0
        report.wall_s = time.perf_counter() - t_start
        if collector is not None:
            report.stats = collector.summary(n_servers=1)
        return outputs, report

    # ---------------------------------------------------- staging + combine
    def _pad_device_source(self, items, chunk: int, n_chunks: int, B: int):
        """Pad a device-resident item source ONCE (repeating the last row —
        the same well-defined dead-row fill the host path uses) so every
        fixed-shape ``slice_chunk`` window stays in bounds at ANY member
        count the IAS can reach.  ``pad_to_shards(chunk, m)`` is NOT
        monotone in m (pad_to_shards(4, 3) = 6 > pad_to_shards(4, 4) = 4),
        so the bound is the max over every possible member count — an
        undersized pad would let ``dynamic_slice`` clamp the window and
        silently compute on the wrong rows.  One eager device op per
        stream; no host round-trip."""
        L_max = max(pad_to_shards(chunk, m)
                    for m in range(1, len(self.devices) + 1))
        need = (n_chunks - 1) * chunk + L_max
        if need <= B:
            return items
        return jax.tree_util.tree_map(
            lambda a: jnp.concatenate(
                [a, jnp.repeat(a[-1:], need - B, axis=0)]), items)

    def _stage_host(self, items_np, lo: int, n_live: int, L: int):
        """Host-side staging: numpy slice + pad-by-repeating-the-last-row
        (zeros when the slice is empty: nothing to repeat).  Padded rows are
        marked dead by the valid mask — which depends only on (L, n_live),
        so the device mask is memoized: full chunks of a stream reuse ONE
        array instead of paying a device_put per chunk."""
        sl = jax.tree_util.tree_map(lambda a: a[lo:lo + n_live], items_np)
        if L != n_live:
            sl = jax.tree_util.tree_map(
                lambda a: np.concatenate(
                    [a, np.repeat(a[-1:], L - n_live, axis=0)])
                if n_live else np.zeros((L,) + a.shape[1:], a.dtype), sl)
        valid = self._valid_masks.get((L, n_live))
        if valid is None:
            valid = jnp.asarray(np.arange(L) < n_live)
            self._valid_masks[(L, n_live)] = valid
        return sl, valid

    @staticmethod
    def _combine(job: DispatchJob, parts, combine_on_device: bool,
                 base=None):
        """Cross-chunk reduction at the stream's reduce boundary.  Each part
        is ``(n_live, chunk_output)``; padded rows of concat outputs are
        trimmed HERE, off the hot loop.  On ONE geometry (no mid-stream
        remesh) an async stream stays on device and the result is exposed
        lazily; across geometries the parts live on different device sets
        (eager device ops would not colocate) and the synchronous baseline
        already materialized per chunk, so those combine on host — the
        IEEE-754 f32 ops are bitwise identical either way.

        ``base`` is a resumed stream's restored checkpoint state (chunks
        before the checkpoint never re-ran): the concatenated row prefix
        for "concat", or the binary-counter pending dict for "sum"/"max" —
        seeding ``_chunk_tree_reduce`` so the replayed suffix folds through
        the identical tree the uninterrupted run used."""
        if combine_on_device:
            asarray = lambda a: a
            cat = lambda *p: jnp.concatenate(p, axis=0)
            add, mx = jnp.add, jnp.maximum
        else:
            asarray = np.asarray
            cat = lambda *p: np.concatenate(p, axis=0)
            add, mx = np.add, np.maximum
        if job.reduce == "concat":
            trimmed = [jax.tree_util.tree_map(
                lambda a: asarray(a)[:n_live], out) for n_live, out in parts]
            if base is not None:
                trimmed.insert(0, jax.tree_util.tree_map(asarray, base))
            return jax.tree_util.tree_map(cat, *trimmed)
        aggs = [jax.tree_util.tree_map(asarray, out) for _, out in parts]
        pending = (None if base is None
                   else {int(lvl): jax.tree_util.tree_map(asarray, t)
                         for lvl, t in base.items()})
        return _chunk_tree_reduce(aggs, add if job.reduce == "sum" else mx,
                                  pending=pending)

    # ------------------------------------------------------------ executables
    def _executable(self, job: DispatchJob, chunk_tree, replicated, L: int):
        """One compiled callable per (mesh, axis, signature, reduce, shapes).
        The mesh in the key is the ONLY geometry binding: a scale event
        retires exactly the outgoing mesh's entries (``_remesh``), every
        other geometry's executables stay warm for when the IAS returns."""
        struct = tuple(
            (tuple(a.shape[1:]), np.dtype(a.dtype).str)
            for a in jax.tree_util.tree_leaves(chunk_tree))
        rep_struct = tuple(
            (tuple(np.shape(a)), np.dtype(np.asarray(a).dtype).str)
            for a in jax.tree_util.tree_leaves(replicated))
        mode = "member" if job.member_fn is not None else "global"
        key = (self.mesh, self.axis, job.signature, job.reduce,
               job.deterministic, mode, L, struct, rep_struct)
        fn = self.cache.get(key)
        if fn is None:
            builder = (self._build_member if mode == "member"
                       else self._build_global)
            fn = builder(job)
            self.cache.put(key, fn)
        return fn

    @property
    def _chunk_donate(self):
        """donate_argnums for the chunk buffer (argnum 0, the chunk tree):
        it is used exactly once, so XLA can recycle its memory for outputs —
        steady-state streaming then allocates nothing.  The valid mask is
        NOT donated: it is memoized across chunks (``_stage_host``) and
        donation would delete it under the later chunks.  Decided per
        dispatcher from its OWN devices (never ``jax.default_backend``,
        which would pin the process backend at import and misjudge
        mixed-backend use); CPU has no donation support and would only warn
        per compile."""
        return () if self.devices[0].platform == "cpu" else (0,)

    def _build_member(self, job: DispatchJob):
        executor = self.executor          # bound to the key's mesh
        axis = self.axis
        # a deterministic job's fn returns PER-ROW contributions which the
        # executable itself tree-reduces (position-aligned row tree) AFTER
        # the gather — no member-count-shaped psum grouping ever touches
        # the float values, and the donated chunk buffers are never touched
        # again after the call returns
        row_out = job.reduce == "concat" or job.deterministic

        def body(data, *rep):
            local, lval = data
            out = job.member_fn(local, lval, *rep)
            if not row_out and job.reduce == "sum":
                return jax.tree_util.tree_map(executor.psum, out)
            if not row_out and job.reduce == "max":
                return jax.tree_util.tree_map(executor.pmax, out)
            return out

        out_specs = P(axis) if row_out else P()

        def call(chunk_tree, valid, *rep):
            out = executor.execute_on_key_owners(
                body, (chunk_tree, valid), replicated_args=rep,
                out_specs=out_specs)
            if job.deterministic:
                out = jax.tree_util.tree_map(
                    lambda a: _row_tree_sum(a, valid), out)
            return out

        if not job.deterministic:
            return jax.jit(call, donate_argnums=self._chunk_donate)

        # deterministic: the row tree compiles as its OWN executable so the
        # member_fn's producer can never FMA-contract into the level-0 adds
        # at M=1 (the executable boundary is the only fence the CPU backend
        # respects — see _row_tree_sum).  The rows stage keeps the chunk
        # donation; both stages enqueue async, so pipelining is unchanged.
        def rows_call(chunk_tree, valid, *rep):
            return executor.execute_on_key_owners(
                body, (chunk_tree, valid), replicated_args=rep,
                out_specs=out_specs)

        rows_fn = jax.jit(rows_call, donate_argnums=self._chunk_donate)
        tree_fn = jax.jit(lambda out, valid: jax.tree_util.tree_map(
            lambda a: _row_tree_sum(a, valid), out))

        def split_call(chunk_tree, valid, *rep):
            return tree_fn(rows_fn(chunk_tree, valid, *rep), valid)

        return split_call

    def _build_global(self, job: DispatchJob):
        executor = self.executor
        axis = self.axis

        def run(chunk_tree, valid, *rep):
            return job.global_fn(chunk_tree, valid, *rep)

        jitted = jax.jit(run, donate_argnums=self._chunk_donate)
        # deterministic: the row tree compiles as its OWN executable (a
        # nested jit would inline into the outer trace) so the global_fn's
        # producer can never FMA-contract into the level-0 adds — the same
        # fence as _build_member (see _row_tree_sum)
        tree_fn = jax.jit(lambda out, valid: jax.tree_util.tree_map(
            lambda a: _row_tree_sum(a, valid), out))

        def call(chunk_tree, valid, *rep):
            # auto-SPMD: place the chunk partitioned, the rest replicated,
            # and let the partitioner choose the schedule (Infinispan flavor)
            sharded = jax.tree_util.tree_map(
                lambda a: executor.put(jnp.asarray(a), P(axis)), chunk_tree)
            valid = executor.put(jnp.asarray(valid), P(axis))
            rep = tuple(jax.tree_util.tree_map(
                lambda a: executor.put(jnp.asarray(a), P()), r)
                for r in rep)
            out = jitted(sharded, valid, *rep)
            if job.deterministic:
                out = tree_fn(out, valid)
            return out

        return call
