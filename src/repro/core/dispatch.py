"""ElasticDispatcher — the unified, remesh-aware, chunk-streaming job layer.

The thesis closes by claiming Cloud²Sim's "distributed execution model and
adaptive scaling solution could be leveraged as a general purpose auto scaler
middleware".  This module IS that middleware for the repo: one dispatch layer
that the scenario grids, the MapReduce engine, and the elastic simulation
cluster all sit on, instead of each carrying its own ad-hoc mesh/shard/cache
logic.  Concept map to the thesis's middleware vocabulary:

  IExecutorService / executeOnKeyOwner   ``DispatchJob.member_fn`` — logic
                                         ships to each member's local chunk
                                         partition via ``DistributedExecutor``
  distributed task queue                 the chunk stream of ``submit``: a job
                                         larger than one dispatch (or than
                                         device memory) is cut into fixed-
                                         shape chunks and executed in order,
                                         each chunk a task taken off the queue
  Hazelcast partition table (§4.1.3)     the 271-virtual-shard
                                         ``PartitionTable`` owned here; its
                                         VM→member map is a RUNTIME operand of
                                         the distributed cores, so rebalances
                                         never recompile
  adaptive scaler (Algorithms 4–6, §5)   ``ElasticController`` → IAS; when it
                                         fires BETWEEN chunks the dispatcher
                                         rebalances the table, retires exactly
                                         the outgoing geometry's executables,
                                         rebuilds the mesh, re-homes the
                                         ``DataGrid``, and the stream resumes
                                         on the new member set
  compiled-task near-cache               ``CompileCache`` — one executable per
                                         (geometry, job-signature), LRU, with
                                         hit/miss/build counters, absorbing
                                         and generalizing the scan core's
                                         ``_DIST_CORE_CACHE``/
                                         ``_AUTO_BLOCK_CACHE``

Jobs are declared as ``DispatchJob`` descriptors — ``(member_fn | global_fn,
reduce)``.  ``member_fn(local_items, local_valid, *replicated)`` runs on each
member's shard of the chunk (the Hazelcast-style explicit path);
``global_fn(items, valid, *replicated)`` expresses the same job as one global
computation whose schedule the partitioner chooses (the Infinispan-style
auto-SPMD path).  ``reduce`` combines chunks: "concat" streams row results,
"sum"/"max" accumulate associative partials, so integer reductions (e.g. word
count) are BIT-identical for any member count, chunking, or mid-stream scale
event — the thesis's accuracy-under-elasticity claim at the job layer.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.executor import DistributedExecutor
from repro.core.grid import DataGrid
from repro.core.partition import (DEFAULT_PARTITION_COUNT, PartitionTable,
                                  pad_to_shards, partition_weights_from_keys)


# --------------------------------------------------------------- compile cache

_MISSING = object()


class CompileCache:
    """LRU cache of compiled executables keyed by (geometry, signature...).

    Insertion-ordered dict semantics with the FRONT as the eviction victim;
    ``get`` moves a hit to the back (so sweeps over many geometries never
    evict the hottest one) and counts hits/misses; ``put`` counts builds.
    Dict-style access (``len``/``in``/iteration/``[]``) peeks WITHOUT
    disturbing recency — the elastic invalidation path and tests use it to
    inspect entries.  The counters are the observable the dispatch acceptance
    tests pin: a chunk stream must build at most one executable per
    (geometry, job-signature) and hit the cache for every later chunk.
    """

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self._store: Dict[Hashable, object] = {}
        self.hits = 0
        self.misses = 0
        self.builds = 0

    # ------------------------------------------------------------ LRU access
    def get(self, key, default=None):
        val = self._store.pop(key, _MISSING)
        if val is _MISSING:
            self.misses += 1
            return default
        self._store[key] = val            # move to back: most recently used
        self.hits += 1
        return val

    def put(self, key, value, max_entries: Optional[int] = None,
            count_build: bool = True):
        """``count_build=False`` for metadata writes (cached ints, measured
        capacities) so ``builds`` keeps meaning COMPILED EXECUTABLES — the
        observable the dispatch acceptance tests pin."""
        cap = self.max_entries if max_entries is None else max_entries
        self._store.pop(key, None)
        while len(self._store) >= max(cap, 1):
            del self._store[next(iter(self._store))]   # evict the LRU front
        self._store[key] = value
        if count_build:
            self.builds += 1

    def get_or_build(self, key, builder: Callable[[], object],
                     max_entries: Optional[int] = None):
        val = self.get(key, _MISSING)
        if val is _MISSING:
            val = builder()
            self.put(key, val, max_entries)
        return val

    # ----------------------------------------------------------- maintenance
    def invalidate(self, match: Optional[Callable[[Hashable], bool]] = None
                   ) -> int:
        """Drop entries whose key satisfies ``match`` (all, when None).
        Returns the number of entries dropped — the scale-event path uses it
        to report exactly how many executables the outgoing geometry held."""
        keys = [k for k in self._store if match is None or match(k)]
        for k in keys:
            del self._store[k]
        return len(keys)

    def stats(self) -> Dict[str, int]:
        return {"size": len(self._store), "hits": self.hits,
                "misses": self.misses, "builds": self.builds}

    # ------------------------------------------------- dict-style inspection
    def __len__(self):
        return len(self._store)

    def __iter__(self):
        return iter(self._store)

    def __contains__(self, key):
        return key in self._store

    def __getitem__(self, key):          # peek: no recency update, no count
        return self._store[key]

    def __setitem__(self, key, value):   # metadata write: not an executable
        self.put(key, value, count_build=False)

    def __delitem__(self, key):
        del self._store[key]


# ----------------------------------------------------- geometry-cache registry
#
# Any module that keeps its own (mesh, axis, ...)-keyed executable cache
# registers it here at import time; a dispatcher scale event then retires the
# outgoing mesh's entries from EVERY registered cache without the middleware
# having to know client modules by name (des_scan registers its distributed
# scan cores and auto-sized exchange capacities this way).

_GEOMETRY_CACHES: List[Tuple[str, CompileCache, bool]] = []


def register_geometry_cache(name: str, cache: CompileCache,
                            counts_as_core: bool = True) -> None:
    """Register a cache whose keys lead with ``(mesh, axis, ...)`` for
    automatic retirement on scale events.  ``counts_as_core=False`` for
    metadata caches (e.g. measured exchange capacities) that should be
    dropped but not reported as retired executables."""
    _GEOMETRY_CACHES.append((name, cache, counts_as_core))


# ------------------------------------------------------------ job descriptors

@dataclasses.dataclass(frozen=True)
class DispatchJob:
    """One streaming job: how a chunk executes and how chunks combine.

    Exactly one of ``member_fn``/``global_fn`` must be set:

      member_fn(local_items, local_valid, *replicated)
          runs on each member's shard of the chunk (executeOnKeyOwner).  For
          ``reduce="concat"`` it returns per-row outputs (leading dim = the
          local shard) which the dispatcher reassembles in global row order;
          for "sum"/"max" it returns a partial aggregate which the dispatcher
          combines across members (psum/pmax) and then across chunks.
      global_fn(items, valid, *replicated)
          expresses the whole chunk as one global computation; the partitioner
          (auto-SPMD) chooses the schedule.  Cross-chunk combination still
          follows ``reduce``.

    ``local_valid``/``valid`` is a bool mask marking the chunk's live rows —
    the dispatcher pads every chunk to a fixed shard-divisible shape so the
    compile cache hits, and padded rows MUST NOT contribute to "sum"/"max"
    aggregates (mask them; for "concat" the dispatcher trims them off).

    ``signature`` is the job's static compile identity: it must determine the
    traced computation completely (the dispatcher may reuse an executable
    built from an earlier ``DispatchJob`` carrying an equal signature).
    """
    name: str
    signature: Hashable
    member_fn: Optional[Callable] = None
    global_fn: Optional[Callable] = None
    reduce: str = "concat"               # "concat" | "sum" | "max"

    def __post_init__(self):
        if (self.member_fn is None) == (self.global_fn is None):
            raise ValueError("exactly one of member_fn/global_fn required")
        if self.reduce not in ("concat", "sum", "max"):
            raise ValueError(f"unknown reduce {self.reduce!r}")


@dataclasses.dataclass
class DispatchReport:
    """What one ``submit`` stream did — the acceptance-test observable."""
    job: str
    n_items: int
    chunk: int
    n_chunks: int = 0
    compiles: int = 0                    # executables built this stream
    cache_hits: int = 0                  # chunks served by a cached executable
    members_per_chunk: List[int] = dataclasses.field(default_factory=list)
    scale_events: int = 0                # remesh events fired mid-stream
    wall_s: float = 0.0

    def summary(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


# ------------------------------------------------------------- the dispatcher

class ElasticDispatcher:
    """Owns mesh, ownership table, compile cache, and the chunk stream.

    One instance per tenant/cluster.  ``submit`` streams a job chunk by
    chunk; between chunks the ``ElasticController`` may fire (driven by
    ``observe_load`` from an ``on_chunk`` callback, or automatically from
    measured chunk wall time when ``auto_scale=True``) and the stream
    resumes on the re-built mesh — compiled executables for the outgoing
    geometry are retired, every other geometry's stay warm.
    """

    def __init__(self, devices=None, axis: str = "data",
                 health_cfg=None, start_members: int = 1,
                 partition_count: Optional[int] = None,
                 chunk_size: Optional[int] = None,
                 cache_entries: int = 64, auto_scale: bool = False):
        from repro.core.elastic import ElasticController, entity_pad_multiple
        from repro.core.health import HealthConfig

        self.devices = list(devices if devices is not None else jax.devices())
        self.axis = axis
        n0 = max(1, min(start_members, len(self.devices)))
        self.table = PartitionTable(
            partition_count=partition_count or DEFAULT_PARTITION_COUNT,
            n_instances=n0)
        hc = health_cfg or HealthConfig()
        hc = dataclasses.replace(
            hc, max_instances=min(hc.max_instances, len(self.devices)))
        self.health_cfg = hc
        # ENTITY sizes pad to this multiple so shapes are identical at every
        # member count the IAS can reach (bit-stable scale events for the
        # elastic cluster).  Chunk streams don't need it: each geometry pads
        # chunks to its own shard multiple, and chunk rows are independent.
        self.entity_pad = entity_pad_multiple(hc, n0)
        self.controller = ElasticController(hc, n0, remesh_fn=self._remesh)
        self.cache = CompileCache(cache_entries)
        self.chunk_size = chunk_size
        self.auto_scale = auto_scale
        self.grid: Optional[DataGrid] = None
        self.scale_events: List[dict] = []
        self._key_weights: Optional[np.ndarray] = None
        self._build(n0)

    @classmethod
    def for_mesh(cls, mesh, axis: Optional[str] = None) -> "ElasticDispatcher":
        """A FROZEN dispatcher bound to an existing 1-D mesh: same devices,
        same axis name, min_instances == max_instances so the IAS can never
        fire.  Lets mesh-first callers (the legacy MapReduce constructor)
        run on the unified job layer without opting into elasticity."""
        from repro.core.health import HealthConfig

        if mesh.devices.ndim != 1:
            raise ValueError("for_mesh requires a 1-D mesh, got shape "
                             f"{mesh.devices.shape}")
        axis = axis or mesh.axis_names[0]
        n = int(mesh.devices.size)
        hc = HealthConfig(min_instances=n, max_instances=n)
        return cls(devices=list(mesh.devices.ravel()), axis=axis,
                   health_cfg=hc, start_members=n)

    # --------------------------------------------------------------- topology
    def _build(self, n: int) -> None:
        self.executor = DistributedExecutor.for_devices(self.devices[:n],
                                                        self.axis)
        self.mesh = self.executor.mesh

    @property
    def n_members(self) -> int:
        return self.controller.n_instances

    def ensure_grid(self) -> DataGrid:
        """The dispatcher-owned DataGrid, created lazily on the current mesh
        and re-homed automatically on every scale event."""
        if self.grid is None:
            self.grid = DataGrid(self.mesh, axis=self.axis)
        return self.grid

    def vm_owner(self, n_keys: int) -> jnp.ndarray:
        """Current key→member ownership (the distributed cores' runtime
        operand) for int keys 0..n_keys-1."""
        return jnp.asarray(self.table.owners_of_range(n_keys))

    # ---------------------------------------------------------------- scaling
    def observe_load(self, load: float):
        """Feed one normalized load sample (observed/target) to the
        monitor→probe→IAS chain; a threshold crossing triggers ``_remesh``
        at this chunk/step boundary."""
        return self.controller.tick(load)

    def observe_key_weights(self, weights) -> None:
        """Record observed per-key load (e.g. the scan core's
        ``exchange_load`` summed per VM).  The NEXT rebalance becomes
        locality-aware: virtual partitions level by weighted load, so a hot
        key's partition stops dragging a full share of cold partitions onto
        its member (ROADMAP exchange follow-on c).  One-shot: the sample is
        CONSUMED by that rebalance — later scale events fall back to count
        leveling unless a fresh observation is fed, so a long-stale load
        profile never keeps steering placement."""
        self._key_weights = None if weights is None else np.asarray(
            weights, np.float64)

    def _partition_weights(self) -> Optional[np.ndarray]:
        if self._key_weights is None:
            return None
        return partition_weights_from_keys(self._key_weights,
                                           self.table.partition_count)

    def _remesh(self, n: int) -> None:
        """The scale-event callback: rebalance table → retire exactly the
        outgoing geometry's executables (every registered geometry cache +
        this dispatcher's job cache) → rebuild mesh → re-home DataGrid."""
        old_mesh, axis = self.mesh, self.axis
        moved = self.table.rebalance(n, weights=self._partition_weights())
        self._key_weights = None        # one-shot: consumed by this event
        match = lambda k: k[0] == old_mesh and k[1] == axis
        retired = 0
        for _, cache, counted in _GEOMETRY_CACHES:
            dropped = cache.invalidate(match)
            if counted:
                retired += dropped
        retired_jobs = self.cache.invalidate(match)
        self._build(n)
        if self.grid is not None:
            self.grid.remesh(self.mesh)
        self.scale_events.append(
            {"n_members": n, "moved_partitions": moved,
             "retired_cores": retired, "retired_jobs": retired_jobs})

    # ------------------------------------------------------------- submission
    def submit(self, job: DispatchJob, items, *, replicated=(),
               chunk: Optional[int] = None,
               on_chunk: Optional[Callable] = None) -> Tuple[object,
                                                             DispatchReport]:
        """Stream ``items`` (a pytree of arrays sharing leading dim B)
        through ``job`` in fixed-shape chunks.

        Every chunk is padded to ``pad_to_shards(chunk, n_members)`` rows
        (live rows flagged by the valid mask), so all chunks of a geometry
        share ONE executable — grids larger than device memory stream with
        at most one compile per (geometry, job-signature).  After each chunk
        ``on_chunk(dispatcher, chunk_index, n_chunks)`` runs (feed
        ``observe_load`` there to drive the IAS deterministically), or, with
        ``auto_scale=True``, the measured chunk wall time is fed as the load
        sample; if the IAS fires, the remaining chunks re-home onto the new
        member set.  Returns ``(outputs, DispatchReport)``.
        """
        leaves = jax.tree_util.tree_leaves(items)
        if not leaves:
            raise ValueError("submit needs at least one item array")
        B = int(leaves[0].shape[0])
        if any(int(l.shape[0]) != B for l in leaves):
            raise ValueError("item arrays must share their leading dim")
        chunk = chunk if chunk is not None else (self.chunk_size or B)
        chunk = max(1, min(int(chunk), max(B, 1)))
        # B == 0 still runs ONE fully-padded chunk (valid all-False): concat
        # outputs trim to correct empty arrays, sum/max partials reduce over
        # masked-out rows only — parity with the non-dispatcher vmap path
        n_chunks = max(-(-B // chunk), 1)
        items_np = jax.tree_util.tree_map(np.asarray, items)

        report = DispatchReport(job=job.name, n_items=B, chunk=chunk,
                                n_chunks=n_chunks)
        hits0, builds0 = self.cache.hits, self.cache.builds
        events0 = len(self.scale_events)
        collected = []                    # concat: per-chunk trimmed outputs
        acc = None                        # sum/max accumulator
        t_start = time.perf_counter()
        for ci in range(n_chunks):
            lo, hi = ci * chunk, min((ci + 1) * chunk, B)
            n_live = hi - lo
            M = self.executor.n_members
            L = pad_to_shards(chunk, M)
            sl = jax.tree_util.tree_map(lambda a: a[lo:hi], items_np)
            if L != n_live:               # pad by repeating the last row —
                # a well-defined duplicate the valid mask marks dead
                # (zeros when the slice is empty: nothing to repeat)
                sl = jax.tree_util.tree_map(
                    lambda a: np.concatenate(
                        [a, np.repeat(a[-1:], L - n_live, axis=0)])
                    if n_live else np.zeros((L,) + a.shape[1:], a.dtype), sl)
            valid = np.arange(L) < n_live
            builds_before = self.cache.builds
            fn = self._executable(job, sl, replicated, L)
            compiled_now = self.cache.builds != builds_before
            t0 = time.perf_counter()
            out = fn(sl, jnp.asarray(valid), *replicated)
            out = jax.tree_util.tree_map(np.asarray, out)
            wall = time.perf_counter() - t0
            if job.reduce == "concat":
                collected.append(jax.tree_util.tree_map(
                    lambda a: a[:n_live], out))
            elif acc is None:
                acc = out
            else:
                comb = np.add if job.reduce == "sum" else np.maximum
                acc = jax.tree_util.tree_map(comb, acc, out)
            report.members_per_chunk.append(M)
            if on_chunk is not None:
                on_chunk(self, ci, n_chunks)
            elif self.auto_scale and not compiled_now:
                # a cache-miss chunk's wall is dominated by trace+compile
                # time (often 10-100x steady state) — feeding it would
                # ratchet the IAS to max_instances on pure compile noise
                self.observe_load(wall / self.health_cfg.target_step_time)
        report.compiles = self.cache.builds - builds0
        report.cache_hits = self.cache.hits - hits0
        report.scale_events = len(self.scale_events) - events0
        report.wall_s = time.perf_counter() - t_start
        if job.reduce == "concat":
            outputs = jax.tree_util.tree_map(
                lambda *parts: np.concatenate(parts, axis=0), *collected)
        else:
            outputs = acc
        return outputs, report

    # ------------------------------------------------------------ executables
    def _executable(self, job: DispatchJob, chunk_tree, replicated, L: int):
        """One compiled callable per (mesh, axis, signature, reduce, shapes).
        The mesh in the key is the ONLY geometry binding: a scale event
        retires exactly the outgoing mesh's entries (``_remesh``), every
        other geometry's executables stay warm for when the IAS returns."""
        struct = tuple(
            (tuple(a.shape[1:]), np.dtype(a.dtype).str)
            for a in jax.tree_util.tree_leaves(chunk_tree))
        rep_struct = tuple(
            (tuple(np.shape(a)), np.dtype(np.asarray(a).dtype).str)
            for a in jax.tree_util.tree_leaves(replicated))
        mode = "member" if job.member_fn is not None else "global"
        key = (self.mesh, self.axis, job.signature, job.reduce, mode, L,
               struct, rep_struct)
        fn = self.cache.get(key)
        if fn is None:
            builder = (self._build_member if mode == "member"
                       else self._build_global)
            fn = builder(job)
            self.cache.put(key, fn)
        return fn

    def _build_member(self, job: DispatchJob):
        executor = self.executor          # bound to the key's mesh
        axis = self.axis

        def body(data, *rep):
            local, lval = data
            out = job.member_fn(local, lval, *rep)
            if job.reduce == "sum":
                return jax.tree_util.tree_map(executor.psum, out)
            if job.reduce == "max":
                return jax.tree_util.tree_map(executor.pmax, out)
            return out

        out_specs = P(axis) if job.reduce == "concat" else P()

        def call(chunk_tree, valid, *rep):
            return executor.execute_on_key_owners(
                body, (chunk_tree, valid), replicated_args=rep,
                out_specs=out_specs)

        return jax.jit(call)

    def _build_global(self, job: DispatchJob):
        executor = self.executor
        axis = self.axis
        jitted = jax.jit(lambda chunk_tree, valid, *rep:
                         job.global_fn(chunk_tree, valid, *rep))

        def call(chunk_tree, valid, *rep):
            # auto-SPMD: place the chunk partitioned, the rest replicated,
            # and let the partitioner choose the schedule (Infinispan flavor)
            sharded = jax.tree_util.tree_map(
                lambda a: executor.put(jnp.asarray(a), P(axis)), chunk_tree)
            valid = executor.put(jnp.asarray(valid), P(axis))
            rep = tuple(jax.tree_util.tree_map(
                lambda a: executor.put(jnp.asarray(a), P()), r)
                for r in rep)
            return jitted(sharded, valid, *rep)

        return call
