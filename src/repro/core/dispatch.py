"""ElasticDispatcher — the unified, remesh-aware, chunk-streaming job layer.

The thesis closes by claiming Cloud²Sim's "distributed execution model and
adaptive scaling solution could be leveraged as a general purpose auto scaler
middleware".  This module IS that middleware for the repo: one dispatch layer
that the scenario grids, the MapReduce engine, and the elastic simulation
cluster all sit on, instead of each carrying its own ad-hoc mesh/shard/cache
logic.  Concept map to the thesis's middleware vocabulary:

  IExecutorService / executeOnKeyOwner   ``DispatchJob.member_fn`` — logic
                                         ships to each member's local chunk
                                         partition via ``DistributedExecutor``
  distributed task queue                 the chunk stream of ``submit``: a job
                                         larger than one dispatch (or than
                                         device memory) is cut into fixed-
                                         shape chunks and executed in order,
                                         each chunk a task taken off the queue
  Hazelcast partition table (§4.1.3)     the 271-virtual-shard
                                         ``PartitionTable`` owned here; its
                                         VM→member map is a RUNTIME operand of
                                         the distributed cores, so rebalances
                                         never recompile
  adaptive scaler (Algorithms 4–6, §5)   ``ElasticController`` → IAS; when it
                                         fires BETWEEN chunks the dispatcher
                                         rebalances the table, retires exactly
                                         the outgoing geometry's executables,
                                         rebuilds the mesh, re-homes the
                                         ``DataGrid``, and the stream resumes
                                         on the new member set
  compiled-task near-cache               ``CompileCache`` — one executable per
                                         (geometry, job-signature), LRU, with
                                         hit/miss/build counters, absorbing
                                         and generalizing the scan core's
                                         ``_DIST_CORE_CACHE``/
                                         ``_AUTO_BLOCK_CACHE``

Jobs are declared as ``DispatchJob`` descriptors — ``(member_fn | global_fn,
reduce)``.  ``member_fn(local_items, local_valid, *replicated)`` runs on each
member's shard of the chunk (the Hazelcast-style explicit path);
``global_fn(items, valid, *replicated)`` expresses the same job as one global
computation whose schedule the partitioner chooses (the Infinispan-style
auto-SPMD path).  ``reduce`` combines chunks: "concat" streams row results,
"sum"/"max" accumulate associative partials, so integer reductions (e.g. word
count) are BIT-identical for any member count, chunking, or mid-stream scale
event — the thesis's accuracy-under-elasticity claim at the job layer.
``deterministic=True`` extends that guarantee to FLOAT sums: the job emits
per-row contributions and the dispatcher reduces them with position-aligned
pairwise trees (rows) plus a fixed-arity tree keyed on chunk index (chunks).

The streaming path is an ASYNC, DOUBLE-BUFFERED pipeline (``dispatch_ahead``
launched-but-unretired chunks, default 2): chunk k+1 is staged on the host —
or cut on DEVICE via ``executor.slice_chunk`` when the item set is already
device-resident — while chunk k computes, and the host blocks only to bound
the queue, to take the wall-time samples the IAS needs (an EMA of
retirement-to-retirement step times over a per-job-class calibrated
``target_step_time``), and at reduce/remesh boundaries.  A scale event is a
pipeline BARRIER: drain in-flight chunks, rebalance, rebuild, resume — chunk
boundaries and reduce order never change, so results stay bit-identical no
matter how many chunks were in flight.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.executor import DistributedExecutor
from repro.core.grid import DataGrid
from repro.core.partition import (DEFAULT_PARTITION_COUNT, PartitionTable,
                                  pad_to_shards, partition_weights_from_keys)


# --------------------------------------------------------------- compile cache

_MISSING = object()


class CompileCache:
    """LRU cache of compiled executables keyed by (geometry, signature...).

    Insertion-ordered dict semantics with the FRONT as the eviction victim;
    ``get`` moves a hit to the back (so sweeps over many geometries never
    evict the hottest one) and counts hits/misses; ``put`` counts builds.
    Dict-style access (``len``/``in``/iteration/``[]``) peeks WITHOUT
    disturbing recency — the elastic invalidation path and tests use it to
    inspect entries.  The counters are the observable the dispatch acceptance
    tests pin: a chunk stream must build at most one executable per
    (geometry, job-signature) and hit the cache for every later chunk.
    """

    def __init__(self, max_entries: int = 64):
        self.max_entries = max_entries
        self._store: Dict[Hashable, object] = {}
        self.hits = 0
        self.misses = 0
        self.builds = 0

    # ------------------------------------------------------------ LRU access
    def get(self, key, default=None):
        val = self._store.pop(key, _MISSING)
        if val is _MISSING:
            self.misses += 1
            return default
        self._store[key] = val            # move to back: most recently used
        self.hits += 1
        return val

    def put(self, key, value, max_entries: Optional[int] = None,
            count_build: bool = True):
        """``count_build=False`` for metadata writes (cached ints, measured
        capacities) so ``builds`` keeps meaning COMPILED EXECUTABLES — the
        observable the dispatch acceptance tests pin."""
        cap = self.max_entries if max_entries is None else max_entries
        self._store.pop(key, None)
        while len(self._store) >= max(cap, 1):
            del self._store[next(iter(self._store))]   # evict the LRU front
        self._store[key] = value
        if count_build:
            self.builds += 1

    def get_or_build(self, key, builder: Callable[[], object],
                     max_entries: Optional[int] = None):
        val = self.get(key, _MISSING)
        if val is _MISSING:
            val = builder()
            self.put(key, val, max_entries)
        return val

    # ----------------------------------------------------------- maintenance
    def invalidate(self, match: Optional[Callable[[Hashable], bool]] = None
                   ) -> int:
        """Drop entries whose key satisfies ``match`` (all, when None).
        Returns the number of entries dropped — the scale-event path uses it
        to report exactly how many executables the outgoing geometry held."""
        keys = [k for k in self._store if match is None or match(k)]
        for k in keys:
            del self._store[k]
        return len(keys)

    def stats(self) -> Dict[str, int]:
        return {"size": len(self._store), "hits": self.hits,
                "misses": self.misses, "builds": self.builds}

    # ------------------------------------------------- dict-style inspection
    def __len__(self):
        return len(self._store)

    def __iter__(self):
        return iter(self._store)

    def __contains__(self, key):
        return key in self._store

    def __getitem__(self, key):          # peek: no recency update, no count
        return self._store[key]

    def __setitem__(self, key, value):   # metadata write: not an executable
        self.put(key, value, count_build=False)

    def __delitem__(self, key):
        del self._store[key]


# ----------------------------------------------------- geometry-cache registry
#
# Any module that keeps its own (mesh, axis, ...)-keyed executable cache
# registers it here at import time; a dispatcher scale event then retires the
# outgoing mesh's entries from EVERY registered cache without the middleware
# having to know client modules by name (des_scan registers its distributed
# scan cores and auto-sized exchange capacities this way).

_GEOMETRY_CACHES: List[Tuple[str, CompileCache, bool]] = []


def register_geometry_cache(name: str, cache: CompileCache,
                            counts_as_core: bool = True) -> None:
    """Register a cache whose keys lead with ``(mesh, axis, ...)`` for
    automatic retirement on scale events.  ``counts_as_core=False`` for
    metadata caches (e.g. measured exchange capacities) that should be
    dropped but not reported as retired executables."""
    _GEOMETRY_CACHES.append((name, cache, counts_as_core))


# ------------------------------------------------------- reduction primitives

def _row_tree_sum(rows, valid):
    """Position-aligned pairwise-tree sum over the leading (row) axis.

    Invalid rows are zeroed, the array is zero-padded to the next power of
    two, and adjacent pairs are combined level by level — the addition tree
    of row r is a function of r ALONE, never of the padded length.  Because
    an all-zero subtree contributes an exact ``+0.0`` (x + 0.0 == x), the
    result is BIT-identical for any pad length >= the live row count, i.e.
    for any member count's chunk padding.  This is the row-level half of the
    deterministic float reduction; ``_chunk_tree_reduce`` is the cross-chunk
    half."""
    mask_shape = (rows.shape[0],) + (1,) * (rows.ndim - 1)
    x = jnp.where(valid.reshape(mask_shape), rows, jnp.zeros((), rows.dtype))
    n = x.shape[0]
    p2 = 1 if n <= 1 else 1 << (n - 1).bit_length()
    if p2 != n:
        x = jnp.concatenate(
            [x, jnp.zeros((p2 - n,) + x.shape[1:], x.dtype)])
    while x.shape[0] > 1:
        x = x[0::2] + x[1::2]
    return x[0]


def _chunk_tree_reduce(parts, combine):
    """Fixed-arity pairwise combine tree keyed on chunk index (a binary
    counter: partial subtrees of equal height merge as chunks arrive, the
    final drain folds survivors highest-level — i.e. earliest chunks —
    first).  The tree shape depends only on the number of chunks, so float
    ``reduce="sum"`` streams are deterministic for a given chunking, and —
    because equal power-of-two chunks form exact subtrees of the global
    row-aligned tree — bit-identical ACROSS power-of-two chunk sizes.  For
    int/max reductions the combine is associative and the tree is
    indistinguishable from the old left fold."""
    pending: Dict[int, object] = {}
    for part in parts:
        level = 0
        while level in pending:
            part = jax.tree_util.tree_map(combine, pending.pop(level), part)
            level += 1
        pending[level] = part
    out = None
    for level in sorted(pending):        # ascending: latest chunks first,
        # so each fold keeps earlier chunks on the LEFT of the combine
        out = (pending[level] if out is None
               else jax.tree_util.tree_map(combine, pending[level], out))
    return out


# ------------------------------------------------------------ job descriptors

@dataclasses.dataclass(frozen=True)
class DispatchJob:
    """One streaming job: how a chunk executes and how chunks combine.

    Exactly one of ``member_fn``/``global_fn`` must be set:

      member_fn(local_items, local_valid, *replicated)
          runs on each member's shard of the chunk (executeOnKeyOwner).  For
          ``reduce="concat"`` it returns per-row outputs (leading dim = the
          local shard) which the dispatcher reassembles in global row order;
          for "sum"/"max" it returns a partial aggregate which the dispatcher
          combines across members (psum/pmax) and then across chunks.
      global_fn(items, valid, *replicated)
          expresses the whole chunk as one global computation; the partitioner
          (auto-SPMD) chooses the schedule.  Cross-chunk combination still
          follows ``reduce``.

    ``local_valid``/``valid`` is a bool mask marking the chunk's live rows —
    the dispatcher pads every chunk to a fixed shard-divisible shape so the
    compile cache hits, and padded rows MUST NOT contribute to "sum"/"max"
    aggregates (mask them; for "concat" the dispatcher trims them off).

    ``signature`` is the job's static compile identity: it must determine the
    traced computation completely (the dispatcher may reuse an executable
    built from an earlier ``DispatchJob`` carrying an equal signature).

    ``deterministic`` (``reduce="sum"`` only) changes the fn contract: the
    job returns PER-ROW contributions (leading dim = rows, like "concat")
    WITHOUT masking or summing them, and the dispatcher reduces rows itself
    with a position-aligned pairwise tree (``_row_tree_sum``) and chunks
    with a fixed-arity tree keyed on chunk index — so FLOAT sums get the
    same bit-identity guarantee across member counts, mid-stream scale
    events, and (power-of-two) chunkings that int32 word count has.

    ``target_step_time`` is the job class's IAS calibration: under
    ``auto_scale`` the dispatcher feeds ``step_time_ema / target`` as the
    load sample.  ``None`` self-calibrates — the first steady-state sample
    of the job class is pinned to the neutral midpoint of the scaling
    thresholds, so only subsequent drift drives the scaler.
    """
    name: str
    signature: Hashable
    member_fn: Optional[Callable] = None
    global_fn: Optional[Callable] = None
    reduce: str = "concat"               # "concat" | "sum" | "max"
    deterministic: bool = False          # per-row tree-reduced float sum
    target_step_time: Optional[float] = None   # per-job-class IAS target

    def __post_init__(self):
        if (self.member_fn is None) == (self.global_fn is None):
            raise ValueError("exactly one of member_fn/global_fn required")
        if self.reduce not in ("concat", "sum", "max"):
            raise ValueError(f"unknown reduce {self.reduce!r}")
        if self.deterministic and self.reduce != "sum":
            raise ValueError("deterministic=True requires reduce='sum'")


@dataclasses.dataclass
class DispatchReport:
    """What one ``submit`` stream did — the acceptance-test observable."""
    job: str
    n_items: int
    chunk: int
    n_chunks: int = 0
    compiles: int = 0                    # executables built this stream
    cache_hits: int = 0                  # chunks served by a cached executable
    members_per_chunk: List[int] = dataclasses.field(default_factory=list)
    scale_events: int = 0                # remesh events fired mid-stream
    wall_s: float = 0.0
    dispatch_ahead: int = 0              # pipeline depth this stream ran at
    max_in_flight: int = 0               # peak launched-but-unretired chunks
    staged_device: int = 0               # chunks cut on device (slice_chunk)
    staged_host: int = 0                 # chunks sliced/padded host-side
    ema_step_s: float = 0.0              # last step-time EMA (auto_scale)

    def summary(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


# ------------------------------------------------------------- the dispatcher

class ElasticDispatcher:
    """Owns mesh, ownership table, compile cache, and the chunk stream.

    One instance per tenant/cluster.  ``submit`` streams a job chunk by
    chunk; between chunks the ``ElasticController`` may fire (driven by
    ``observe_load`` from an ``on_chunk`` callback, or automatically from
    measured chunk wall time when ``auto_scale=True``) and the stream
    resumes on the re-built mesh — compiled executables for the outgoing
    geometry are retired, every other geometry's stay warm.
    """

    def __init__(self, devices=None, axis: str = "data",
                 health_cfg=None, start_members: int = 1,
                 partition_count: Optional[int] = None,
                 chunk_size: Optional[int] = None,
                 cache_entries: int = 64, auto_scale: bool = False,
                 dispatch_ahead: int = 2):
        from repro.core.elastic import ElasticController, entity_pad_multiple
        from repro.core.health import HealthConfig

        self.devices = list(devices if devices is not None else jax.devices())
        self.axis = axis
        n0 = max(1, min(start_members, len(self.devices)))
        self.table = PartitionTable(
            partition_count=partition_count or DEFAULT_PARTITION_COUNT,
            n_instances=n0)
        hc = health_cfg or HealthConfig()
        hc = dataclasses.replace(
            hc, max_instances=min(hc.max_instances, len(self.devices)))
        self.health_cfg = hc
        # ENTITY sizes pad to this multiple so shapes are identical at every
        # member count the IAS can reach (bit-stable scale events for the
        # elastic cluster).  Chunk streams don't need it: each geometry pads
        # chunks to its own shard multiple, and chunk rows are independent.
        self.entity_pad = entity_pad_multiple(hc, n0)
        self.controller = ElasticController(hc, n0, remesh_fn=self._remesh)
        self.cache = CompileCache(cache_entries)
        self.chunk_size = chunk_size
        self.auto_scale = auto_scale
        # pipeline depth: how many chunks may be launched ahead of the oldest
        # unretired one (0 = fully synchronous, the pre-async baseline)
        self.dispatch_ahead = max(int(dispatch_ahead), 0)
        # device-resident item sets at least this big are chunked on device
        # (``executor.slice_chunk``) instead of round-tripping through host
        # numpy; below it the extra per-chunk jit dispatch costs more than
        # the copies it saves (tests pin 0 to force the device path)
        self.device_slice_min_bytes = 1 << 20
        self.grid: Optional[DataGrid] = None
        self.scale_events: List[dict] = []
        self._key_weights: Optional[np.ndarray] = None
        # per-job-class calibrated IAS step-time targets (auto_scale)
        self.job_targets: Dict[Hashable, float] = {}
        # launched-but-unretired chunk outputs of the ACTIVE stream; the
        # remesh barrier drains it, exception cleanup clears it
        self._in_flight: Deque[Tuple] = collections.deque()
        self._valid_masks: Dict[Tuple[int, int], jnp.ndarray] = {}
        self._epoch = 0                  # bumped per remesh (geometry epoch)
        self._build(n0)

    @classmethod
    def for_mesh(cls, mesh, axis: Optional[str] = None) -> "ElasticDispatcher":
        """A FROZEN dispatcher bound to an existing 1-D mesh: same devices,
        same axis name, min_instances == max_instances so the IAS can never
        fire.  Lets mesh-first callers (the legacy MapReduce constructor)
        run on the unified job layer without opting into elasticity."""
        from repro.core.health import HealthConfig

        if mesh.devices.ndim != 1:
            raise ValueError("for_mesh requires a 1-D mesh, got shape "
                             f"{mesh.devices.shape}")
        axis = axis or mesh.axis_names[0]
        n = int(mesh.devices.size)
        hc = HealthConfig(min_instances=n, max_instances=n)
        return cls(devices=list(mesh.devices.ravel()), axis=axis,
                   health_cfg=hc, start_members=n)

    # --------------------------------------------------------------- topology
    def _build(self, n: int) -> None:
        self.executor = DistributedExecutor.for_devices(self.devices[:n],
                                                        self.axis)
        self.mesh = self.executor.mesh

    @property
    def n_members(self) -> int:
        return self.controller.n_instances

    def ensure_grid(self) -> DataGrid:
        """The dispatcher-owned DataGrid, created lazily on the current mesh
        and re-homed automatically on every scale event."""
        if self.grid is None:
            self.grid = DataGrid(self.mesh, axis=self.axis)
        return self.grid

    def vm_owner(self, n_keys: int) -> jnp.ndarray:
        """Current key→member ownership (the distributed cores' runtime
        operand) for int keys 0..n_keys-1."""
        return jnp.asarray(self.table.owners_of_range(n_keys))

    # ---------------------------------------------------------------- scaling
    def observe_load(self, load: float):
        """Feed one normalized load sample (observed/target) to the
        monitor→probe→IAS chain; a threshold crossing triggers ``_remesh``
        at this chunk/step boundary."""
        return self.controller.tick(load)

    def observe_key_weights(self, weights) -> None:
        """Record observed per-key load (e.g. the scan core's
        ``exchange_load`` summed per VM).  The NEXT rebalance becomes
        locality-aware: virtual partitions level by weighted load, so a hot
        key's partition stops dragging a full share of cold partitions onto
        its member (ROADMAP exchange follow-on c).  One-shot: the sample is
        CONSUMED by that rebalance — later scale events fall back to count
        leveling unless a fresh observation is fed, so a long-stale load
        profile never keeps steering placement."""
        self._key_weights = None if weights is None else np.asarray(
            weights, np.float64)

    def _partition_weights(self) -> Optional[np.ndarray]:
        if self._key_weights is None:
            return None
        return partition_weights_from_keys(self._key_weights,
                                           self.table.partition_count)

    def _remesh(self, n: int) -> None:
        """The scale-event callback — a PIPELINE BARRIER: drain every
        in-flight chunk of the active stream, then rebalance table → retire
        exactly the outgoing geometry's executables (every registered
        geometry cache + this dispatcher's job cache) → rebuild mesh →
        re-home DataGrid → resume.  Draining first keeps the event clean
        (no old-geometry compute overlapping the new geometry's compiles)
        and is the only mid-stream synchronization the async pipeline does;
        chunk boundaries and reduce order are unaffected by how many chunks
        were in flight, so results stay bit-identical."""
        drained = self._drain_in_flight()
        old_mesh, axis = self.mesh, self.axis
        moved = self.table.rebalance(n, weights=self._partition_weights())
        self._key_weights = None        # one-shot: consumed by this event
        match = lambda k: k[0] == old_mesh and k[1] == axis
        retired = 0
        for _, cache, counted in _GEOMETRY_CACHES:
            dropped = cache.invalidate(match)
            if counted:
                retired += dropped
        retired_jobs = self.cache.invalidate(match)
        self._build(n)
        self._epoch += 1                # wall-clock samples spanning the
        # barrier are meaningless: the stream loop resets its timer on epoch
        if self.grid is not None:
            self.grid.remesh(self.mesh)
        self.scale_events.append(
            {"n_members": n, "moved_partitions": moved,
             "retired_cores": retired, "retired_jobs": retired_jobs,
             "drained_in_flight": drained})

    @property
    def in_flight(self) -> int:
        """Launched-but-unretired chunks of the active stream (0 between
        streams — the exception-safety observable: a failed ``submit`` must
        never leak launched buffers)."""
        return len(self._in_flight)

    def _drain_in_flight(self) -> int:
        """Block until every launched chunk has retired.  Returns how many
        were in flight — the remesh barrier records it per scale event.
        Exception-safe: if a chunk's computation itself raises at the
        blocking point, the rest of the queue is still dropped — a stale
        chunk must never leak into (and re-raise inside) the next stream."""
        n = len(self._in_flight)
        try:
            while self._in_flight:
                _, out, _, _ = self._in_flight.popleft()
                jax.block_until_ready(out)
        finally:
            self._in_flight.clear()
        return n

    def calibrate_target(self, job: DispatchJob, target_step_time: float
                         ) -> None:
        """Pin a job class's IAS step-time target explicitly (overrides the
        first-sample self-calibration; ``job.target_step_time`` still wins)."""
        self.job_targets[job.signature] = float(target_step_time)

    def _job_target(self, job: DispatchJob, first_sample: float) -> float:
        """Resolve the job class's step-time target: the job's own >
        previously calibrated > self-calibrate NOW so ``first_sample`` sits
        at the neutral midpoint of the scaling thresholds (load there
        triggers nothing; later drift does)."""
        if job.target_step_time is not None:
            return job.target_step_time
        target = self.job_targets.get(job.signature)
        if target is None:
            mid = 0.5 * (self.health_cfg.max_threshold
                         + self.health_cfg.min_threshold)
            target = first_sample / max(mid, 1e-9)
            self.job_targets[job.signature] = target
        return target

    # ------------------------------------------------------------- submission
    def submit(self, job: DispatchJob, items, *, replicated=(),
               chunk: Optional[int] = None,
               on_chunk: Optional[Callable] = None,
               dispatch_ahead: Optional[int] = None,
               deliver: str = "device") -> Tuple[object, DispatchReport]:
        """Stream ``items`` (a pytree of arrays sharing leading dim B)
        through ``job`` in fixed-shape chunks, as an ASYNC double-buffered
        pipeline.

        Every chunk is padded to ``pad_to_shards(chunk, n_members)`` rows
        (live rows flagged by the valid mask), so all chunks of a geometry
        share ONE executable — grids larger than device memory stream with
        at most one compile per (geometry, job-signature).

        Pipelining: chunk k+1 is staged (sliced + padded) and dispatched
        while chunk k still runs on device — JAX dispatch is asynchronous,
        so the host never blocks mid-stream except to (1) bound the queue at
        ``dispatch_ahead`` launched-but-unretired chunks (memory bound;
        0 = fully synchronous baseline) and (2) take the wall-time samples
        the IAS needs.  The only other synchronization points are the
        REMESH BARRIER (``_remesh`` drains the queue before rebuilding) and
        the final reduce.  Chunk boundaries and reduce order never depend on
        how many chunks were in flight, so results are bit-identical to the
        synchronous path for every scale sequence.

        Staging: a DEVICE-resident item set (every leaf a ``jax.Array``) of
        at least ``device_slice_min_bytes`` never round-trips to host — the
        source is padded once on device and chunks are cut with
        ``executor.slice_chunk`` (``lax.dynamic_slice`` + valid masking);
        host-resident (or tiny, where an extra per-chunk jit dispatch costs
        more than the copies it saves) items use numpy slicing as before.
        When no scale event fired mid-stream, outputs stay on device and are
        exposed LAZILY (callers chain them into the next job or block at
        their own reduce boundary); a remesh mixes geometries, so the final
        combine falls back to host.

        After each chunk ``on_chunk(dispatcher, chunk_index, n_chunks)``
        runs (feed ``observe_load`` there to drive the IAS
        deterministically).  With ``auto_scale=True`` the dispatcher instead
        feeds an EMA of measured retirement-to-retirement step times over
        the job class's ``target_step_time`` (see ``_job_target``) — one
        ``block_until_ready`` per sample, exactly where the IAS needs a
        wall-time reading, never a per-chunk stop-the-world.

        ``deliver`` places the final reduce: "device" (default) keeps it
        lazy on device — the right choice when the output chains into
        another job; "host" materializes it at the reduce boundary — the
        right choice when the caller converts to numpy immediately (one
        gather instead of a sharded device concat PLUS a gather; the values
        are bitwise identical either way).  Returns
        ``(outputs, DispatchReport)``.
        """
        if deliver not in ("device", "host"):
            raise ValueError(f"unknown deliver {deliver!r}")
        leaves = jax.tree_util.tree_leaves(items)
        if not leaves:
            raise ValueError("submit needs at least one item array")
        B = int(leaves[0].shape[0])
        if any(int(l.shape[0]) != B for l in leaves):
            raise ValueError("item arrays must share their leading dim")
        chunk = chunk if chunk is not None else (self.chunk_size or B)
        chunk = max(1, min(int(chunk), max(B, 1)))
        # B == 0 still runs ONE fully-padded chunk (valid all-False): concat
        # outputs trim to correct empty arrays, sum/max partials reduce over
        # masked-out rows only — parity with the non-dispatcher vmap path
        n_chunks = max(-(-B // chunk), 1)
        depth = (self.dispatch_ahead if dispatch_ahead is None
                 else max(int(dispatch_ahead), 0))
        # device-side chunk slicing pays one extra jit dispatch per chunk to
        # save the host round-trip — worth it exactly when the item set is
        # big enough for the copies to matter.  Tiny item sets (a grid's
        # per-variant scalars) stage faster through numpy.  depth 0
        # reproduces the legacy synchronous path end to end: items round-
        # trip through host numpy exactly as the pre-async dispatcher staged
        # them.
        n_bytes = sum(l.size * l.dtype.itemsize for l in leaves)
        on_device = (depth > 0 and B > 0
                     and n_bytes >= self.device_slice_min_bytes
                     and all(isinstance(l, jax.Array) for l in leaves))
        if on_device:
            src = self._pad_device_source(items, chunk, n_chunks, B)
        else:
            items_np = jax.tree_util.tree_map(np.asarray, items)

        report = DispatchReport(job=job.name, n_items=B, chunk=chunk,
                                n_chunks=n_chunks, dispatch_ahead=depth)
        hits0, builds0 = self.cache.hits, self.cache.builds
        events0 = len(self.scale_events)
        parts = []           # per-chunk results, in chunk order: trimmed row
        # outputs (concat) or partial aggregates (sum/max/deterministic)
        part_epochs = set()  # geometries the parts live on
        alpha = getattr(self.health_cfg, "ema_alpha", 0.4)
        stream = {"t_mark": None, "ema": None, "epoch": self._epoch}

        def mark(compiled: bool, t_launch: float):
            """Sample one per-chunk step time — the retirement-to-retirement
            wall delta in pipelined steady state, or launch-to-completion
            when nothing retired before this chunk (short streams) — and,
            under auto_scale, feed EMA/target to the IAS.  Compile chunks
            and remesh barriers reset the timer instead of polluting the
            EMA — their wall is trace/compile or rebuild noise, often
            10-100x the steady state, and would ratchet the scaler to
            max_instances."""
            now = time.perf_counter()
            if compiled or stream["epoch"] != self._epoch:
                stream["epoch"] = self._epoch
                stream["t_mark"] = now
                return
            since = (t_launch if stream["t_mark"] is None
                     else max(stream["t_mark"], t_launch))
            dt, stream["t_mark"] = now - since, now
            stream["ema"] = (dt if stream["ema"] is None
                             else alpha * dt + (1.0 - alpha) * stream["ema"])
            report.ema_step_s = stream["ema"]
            if self.auto_scale and on_chunk is None:
                self.observe_load(stream["ema"]
                                  / self._job_target(job, stream["ema"]))

        def retire_oldest():
            """Block on the oldest launched chunk, then sample."""
            _, out, compiled, t_launch = self._in_flight.popleft()
            jax.block_until_ready(out)
            mark(compiled, t_launch)

        t_start = time.perf_counter()
        try:
            for ci in range(n_chunks):
                lo, hi = ci * chunk, min((ci + 1) * chunk, B)
                n_live = hi - lo
                M = self.executor.n_members
                L = pad_to_shards(chunk, M)
                if on_device:
                    sl, valid = self.executor.slice_chunk(src, lo, L, n_live)
                    report.staged_device += 1
                else:
                    sl, valid = self._stage_host(items_np, lo, n_live, L)
                    report.staged_host += 1
                builds_before = self.cache.builds
                fn = self._executable(job, sl, replicated, L)
                compiled_now = self.cache.builds != builds_before
                t_launch = time.perf_counter()
                out = fn(sl, valid, *replicated)         # async dispatch
                # (deterministic jobs: the executable itself tree-reduced
                # the rows, so `out` is already the chunk partial)
                if depth == 0:
                    # synchronous baseline (``streamed_sync``): materialize
                    # the chunk on host NOW — one blocking D2H per chunk,
                    # exactly the pre-async behavior this pipeline replaces
                    out = jax.tree_util.tree_map(np.asarray, out)
                    mark(compiled_now, t_launch)
                else:
                    self._in_flight.append((ci, out, compiled_now, t_launch))
                    report.max_in_flight = max(report.max_in_flight,
                                               len(self._in_flight))
                # combine lazily, in chunk order — retirement (blocking) is
                # decoupled from reduction, so order never depends on how
                # many chunks are in flight.  concat rows are trimmed at the
                # reduce boundary, not here: an eager mid-stream slice of an
                # unevenly-sharded chunk would cost a per-chunk reshard
                parts.append((n_live, out))
                part_epochs.add(self._epoch)
                report.members_per_chunk.append(M)
                if on_chunk is not None:
                    on_chunk(self, ci, n_chunks)
                while len(self._in_flight) > depth:
                    retire_oldest()
            if self.auto_scale and on_chunk is None:
                # the IAS needs samples even from streams shorter than the
                # pipeline depth: drain the tail WITH sampling (short
                # streams fall back to launch-to-completion walls in mark)
                while self._in_flight:
                    retire_oldest()
            else:
                # lazy delivery: drop the queue without blocking — `parts`
                # keeps the arrays alive, the in-flight bound was enforced
                # chunk by chunk, and the caller blocks at its own reduce
                # boundary (host delivery materializes right below anyway)
                self._in_flight.clear()
        finally:
            # exception mid-stream (a failing on_chunk, a bad replicated
            # operand): quiesce and forget every launched chunk so the
            # dispatcher is reusable and no buffer outlives the stream
            self._drain_in_flight()

        # one geometry throughout, an async stream, and device delivery:
        # combine on device and expose the result lazily; host delivery, a
        # mid-stream remesh (parts on different device sets) or the
        # synchronous baseline (parts already np, legacy host-output
        # semantics) combine on host
        combine_on_device = (deliver == "device" and depth > 0
                             and len(part_epochs) <= 1)
        outputs = self._combine(job, parts, combine_on_device)
        report.compiles = self.cache.builds - builds0
        report.cache_hits = self.cache.hits - hits0
        report.scale_events = len(self.scale_events) - events0
        report.wall_s = time.perf_counter() - t_start
        return outputs, report

    # ---------------------------------------------------- staging + combine
    def _pad_device_source(self, items, chunk: int, n_chunks: int, B: int):
        """Pad a device-resident item source ONCE (repeating the last row —
        the same well-defined dead-row fill the host path uses) so every
        fixed-shape ``slice_chunk`` window stays in bounds at ANY member
        count the IAS can reach.  ``pad_to_shards(chunk, m)`` is NOT
        monotone in m (pad_to_shards(4, 3) = 6 > pad_to_shards(4, 4) = 4),
        so the bound is the max over every possible member count — an
        undersized pad would let ``dynamic_slice`` clamp the window and
        silently compute on the wrong rows.  One eager device op per
        stream; no host round-trip."""
        L_max = max(pad_to_shards(chunk, m)
                    for m in range(1, len(self.devices) + 1))
        need = (n_chunks - 1) * chunk + L_max
        if need <= B:
            return items
        return jax.tree_util.tree_map(
            lambda a: jnp.concatenate(
                [a, jnp.repeat(a[-1:], need - B, axis=0)]), items)

    def _stage_host(self, items_np, lo: int, n_live: int, L: int):
        """Host-side staging: numpy slice + pad-by-repeating-the-last-row
        (zeros when the slice is empty: nothing to repeat).  Padded rows are
        marked dead by the valid mask — which depends only on (L, n_live),
        so the device mask is memoized: full chunks of a stream reuse ONE
        array instead of paying a device_put per chunk."""
        sl = jax.tree_util.tree_map(lambda a: a[lo:lo + n_live], items_np)
        if L != n_live:
            sl = jax.tree_util.tree_map(
                lambda a: np.concatenate(
                    [a, np.repeat(a[-1:], L - n_live, axis=0)])
                if n_live else np.zeros((L,) + a.shape[1:], a.dtype), sl)
        valid = self._valid_masks.get((L, n_live))
        if valid is None:
            valid = jnp.asarray(np.arange(L) < n_live)
            self._valid_masks[(L, n_live)] = valid
        return sl, valid

    @staticmethod
    def _combine(job: DispatchJob, parts, combine_on_device: bool):
        """Cross-chunk reduction at the stream's reduce boundary.  Each part
        is ``(n_live, chunk_output)``; padded rows of concat outputs are
        trimmed HERE, off the hot loop.  On ONE geometry (no mid-stream
        remesh) an async stream stays on device and the result is exposed
        lazily; across geometries the parts live on different device sets
        (eager device ops would not colocate) and the synchronous baseline
        already materialized per chunk, so those combine on host — the
        IEEE-754 f32 ops are bitwise identical either way."""
        if combine_on_device:
            asarray = lambda a: a
            cat = lambda *p: jnp.concatenate(p, axis=0)
            add, mx = jnp.add, jnp.maximum
        else:
            asarray = np.asarray
            cat = lambda *p: np.concatenate(p, axis=0)
            add, mx = np.add, np.maximum
        if job.reduce == "concat":
            trimmed = [jax.tree_util.tree_map(
                lambda a: asarray(a)[:n_live], out) for n_live, out in parts]
            return jax.tree_util.tree_map(cat, *trimmed)
        aggs = [jax.tree_util.tree_map(asarray, out) for _, out in parts]
        return _chunk_tree_reduce(aggs, add if job.reduce == "sum" else mx)

    # ------------------------------------------------------------ executables
    def _executable(self, job: DispatchJob, chunk_tree, replicated, L: int):
        """One compiled callable per (mesh, axis, signature, reduce, shapes).
        The mesh in the key is the ONLY geometry binding: a scale event
        retires exactly the outgoing mesh's entries (``_remesh``), every
        other geometry's executables stay warm for when the IAS returns."""
        struct = tuple(
            (tuple(a.shape[1:]), np.dtype(a.dtype).str)
            for a in jax.tree_util.tree_leaves(chunk_tree))
        rep_struct = tuple(
            (tuple(np.shape(a)), np.dtype(np.asarray(a).dtype).str)
            for a in jax.tree_util.tree_leaves(replicated))
        mode = "member" if job.member_fn is not None else "global"
        key = (self.mesh, self.axis, job.signature, job.reduce,
               job.deterministic, mode, L, struct, rep_struct)
        fn = self.cache.get(key)
        if fn is None:
            builder = (self._build_member if mode == "member"
                       else self._build_global)
            fn = builder(job)
            self.cache.put(key, fn)
        return fn

    @property
    def _chunk_donate(self):
        """donate_argnums for the chunk buffer (argnum 0, the chunk tree):
        it is used exactly once, so XLA can recycle its memory for outputs —
        steady-state streaming then allocates nothing.  The valid mask is
        NOT donated: it is memoized across chunks (``_stage_host``) and
        donation would delete it under the later chunks.  Decided per
        dispatcher from its OWN devices (never ``jax.default_backend``,
        which would pin the process backend at import and misjudge
        mixed-backend use); CPU has no donation support and would only warn
        per compile."""
        return () if self.devices[0].platform == "cpu" else (0,)

    def _build_member(self, job: DispatchJob):
        executor = self.executor          # bound to the key's mesh
        axis = self.axis
        # a deterministic job's fn returns PER-ROW contributions which the
        # executable itself tree-reduces (position-aligned row tree) AFTER
        # the gather — no member-count-shaped psum grouping ever touches
        # the float values, and the donated chunk buffers are never touched
        # again after the call returns
        row_out = job.reduce == "concat" or job.deterministic

        def body(data, *rep):
            local, lval = data
            out = job.member_fn(local, lval, *rep)
            if not row_out and job.reduce == "sum":
                return jax.tree_util.tree_map(executor.psum, out)
            if not row_out and job.reduce == "max":
                return jax.tree_util.tree_map(executor.pmax, out)
            return out

        out_specs = P(axis) if row_out else P()

        def call(chunk_tree, valid, *rep):
            out = executor.execute_on_key_owners(
                body, (chunk_tree, valid), replicated_args=rep,
                out_specs=out_specs)
            if job.deterministic:
                out = jax.tree_util.tree_map(
                    lambda a: _row_tree_sum(a, valid), out)
            return out

        return jax.jit(call, donate_argnums=self._chunk_donate)

    def _build_global(self, job: DispatchJob):
        executor = self.executor
        axis = self.axis

        def run(chunk_tree, valid, *rep):
            out = job.global_fn(chunk_tree, valid, *rep)
            if job.deterministic:
                out = jax.tree_util.tree_map(
                    lambda a: _row_tree_sum(a, valid), out)
            return out

        jitted = jax.jit(run, donate_argnums=self._chunk_donate)

        def call(chunk_tree, valid, *rep):
            # auto-SPMD: place the chunk partitioned, the rest replicated,
            # and let the partitioner choose the schedule (Infinispan flavor)
            sharded = jax.tree_util.tree_map(
                lambda a: executor.put(jnp.asarray(a), P(axis)), chunk_tree)
            valid = executor.put(jnp.asarray(valid), P(axis))
            rep = tuple(jax.tree_util.tree_map(
                lambda a: executor.put(jnp.asarray(a), P()), r)
                for r in rep)
            return jitted(sharded, valid, *rep)

        return call
