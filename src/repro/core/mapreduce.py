"""MapReduce engine — the paper's dual-backend MapReduce layer (§3.4.2, §4.2).

Cloud²Sim implements the SAME job API over Hazelcast and Infinispan and
benchmarks them against each other (Figs 5.9–5.11).  We keep that design:

  backend="hazelcast"   explicit shard_map: map() runs on each member's local
                        chunk, reduce() is an explicit collective (psum) —
                        the member-owned, logic-to-data execution model.
  backend="infinispan"  pjit/auto-SPMD: the same job expressed as a global
                        computation; the partitioner chooses the schedule
                        (Infinispan's "local-first cache" flavor).

Jobs follow the paper's default example: word count over a corpus of files.
``map_invocations`` = number of files (leading shard dim); ``reduce
invocations`` = number of distinct keys touched (vocab bins), matching how the
thesis scales its experiments (§4.2.3).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map


@dataclasses.dataclass(frozen=True)
class MapReduceJob:
    """map_fn: (file_chunk) -> partial aggregate; combine: pairwise reduce."""
    map_fn: Callable
    n_keys: int                     # size of the reduced key space
    name: str = "job"


def word_count_job(vocab: int, use_kernel: bool = False) -> MapReduceJob:
    """The paper's default word-count application: counts token occurrences.

    use_kernel: route the per-shard histogram through the Pallas histogram
    kernel (interpret mode on CPU) instead of the jnp one-hot path.
    """
    if use_kernel:
        from repro.kernels.histogram import ops as hist_ops
        fn = lambda chunk: hist_ops.histogram(chunk.reshape(-1), vocab)
    else:
        def fn(chunk):
            flat = chunk.reshape(-1)
            return jnp.zeros((vocab,), jnp.int32).at[flat].add(
                jnp.ones_like(flat), mode="drop")
    return MapReduceJob(map_fn=fn, n_keys=vocab, name="word_count")


class MapReduceEngine:
    def __init__(self, mesh: Mesh, backend: str = "hazelcast",
                 axis: str = "data", verbose: bool = False):
        assert backend in ("hazelcast", "infinispan")
        self.mesh = mesh
        self.backend = backend
        self.axis = axis
        self.verbose = verbose

    def run(self, job: MapReduceJob, files: jax.Array):
        """files: (n_files, file_len) int tokens; n_files % members == 0."""
        if self.backend == "hazelcast":
            out = self._run_hazelcast(job, files)
        else:
            out = self._run_infinispan(job, files)
        return out

    # -------- hazelcast backend: explicit member-local map + collective reduce
    def _run_hazelcast(self, job: MapReduceJob, files):
        axis = self.axis
        verbose = self.verbose

        def member(local_files):
            # map(): one invocation per local file
            partial = jax.vmap(job.map_fn)(local_files).sum(axis=0)
            if verbose:
                jax.debug.print(
                    "[member] mapped {} files locally", local_files.shape[0])
            # reduce(): collective combine of partial aggregates
            return jax.lax.psum(partial, axis)

        f = shard_map(member, mesh=self.mesh, in_specs=(P(axis),),
                      out_specs=P(), check_vma=False)
        return jax.jit(f)(files)

    # -------- infinispan backend: global expression, auto-SPMD partitioning
    def _run_infinispan(self, job: MapReduceJob, files):
        sharding = NamedSharding(self.mesh, P(self.axis))
        files = jax.device_put(files, sharding)

        def global_job(fs):
            return jax.vmap(job.map_fn)(fs).sum(axis=0)

        return jax.jit(global_job, in_shardings=(sharding,),
                       out_shardings=NamedSharding(self.mesh, P()))(files)

    def benchmark(self, job: MapReduceJob, files, repeats: int = 3):
        """Timed run (compile excluded) -> (result, seconds)."""
        out = self.run(job, files)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = self.run(job, files)
        jax.block_until_ready(out)
        return out, (time.perf_counter() - t0) / repeats


def make_corpus(n_files: int, file_len: int, vocab: int, seed: int = 0,
                zipf_a: float = 1.3) -> np.ndarray:
    """USENET-like corpus: zipf-distributed token ids (the thesis used large
    text files from the Westbury USENET corpus)."""
    rng = np.random.default_rng(seed)
    toks = rng.zipf(zipf_a, size=(n_files, file_len)).astype(np.int64)
    return (toks % vocab).astype(np.int32)
