"""MapReduce engine — the paper's dual-backend MapReduce layer (§3.4.2, §4.2).

Cloud²Sim implements the SAME job API over Hazelcast and Infinispan and
benchmarks them against each other (Figs 5.9–5.11).  We keep that design,
but both backends now execute as jobs on the unified ``ElasticDispatcher``
middleware (``core/dispatch.py``):

  backend="hazelcast"   a ``member_fn`` dispatch job: map() runs on each
                        member's local chunk, reduce() is an explicit
                        collective (psum) — the member-owned, logic-to-data
                        execution model.
  backend="infinispan"  a ``global_fn`` dispatch job: the same job expressed
                        as a global computation; the partitioner chooses the
                        schedule (Infinispan's "local-first cache" flavor).

Because the job layer is the dispatcher, MapReduce gains what the thesis's
§5 dynamic scaler promised: chunked streaming of corpora larger than one
dispatch, and ADAPTIVE SCALING — the IntelligentAdaptiveScaler can grow or
shrink the member set between chunks and the stream resumes on the new
mesh.  Word count reduces in int32, so results are BIT-identical for any
member count, chunking, or mid-stream scale event (both backends agree
exactly — the thesis's accuracy claim, now at the MapReduce layer too);
FLOAT jobs (``word_weight_job``) opt into the dispatcher's deterministic
tree reduction and get the same guarantee despite non-associative adds.
The old ``n_files % members == 0`` restriction is gone: the dispatcher pads
chunks to whole shards and masks the padding out of the reduction.

Jobs follow the paper's default example: word count over a corpus of files.
``map_invocations`` = number of files (leading shard dim); ``reduce
invocations`` = number of distinct keys touched (vocab bins), matching how the
thesis scales its experiments (§4.2.3).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.dispatch import DispatchJob, ElasticDispatcher


@dataclasses.dataclass(frozen=True)
class MapReduceJob:
    """map_fn: (file_chunk) -> partial aggregate; combine: pairwise reduce.

    ``deterministic`` routes the job through the dispatcher's deterministic
    float reduction: per-file map outputs are combined by position-aligned
    pairwise trees instead of shard-shaped sums, so FLOAT jobs get the same
    bit-identity guarantee across backends, member counts, scale events and
    (power-of-two) chunkings that int32 word count has for free."""
    map_fn: Callable
    n_keys: int                     # size of the reduced key space
    name: str = "job"
    deterministic: bool = False     # fixed-tree float reduction


def word_count_job(vocab: int, use_kernel: bool = False) -> MapReduceJob:
    """The paper's default word-count application: counts token occurrences.

    use_kernel: route the per-shard histogram through the Pallas histogram
    kernel (interpret mode on CPU) instead of the jnp one-hot path.
    """
    if use_kernel:
        from repro.kernels.histogram import ops as hist_ops
        fn = lambda chunk: hist_ops.histogram(chunk.reshape(-1), vocab)
    else:
        def fn(chunk):
            flat = chunk.reshape(-1)
            return jnp.zeros((vocab,), jnp.int32).at[flat].add(
                jnp.ones_like(flat), mode="drop")
    return MapReduceJob(map_fn=fn, n_keys=vocab, name="word_count")


def word_weight_job(vocab: int) -> MapReduceJob:
    """A FLOAT MapReduce job: each token contributes a rank-decaying f32
    weight ``1 / (1 + token)`` to its vocab bin (a tf-idf-flavoured twist on
    the thesis's word count).  Float adds are not associative, so this job
    opts into the dispatcher's deterministic tree reduction — results are
    bit-identical across backends, member counts, mid-stream scale events
    and power-of-two chunkings, exactly like the int32 word count."""
    def fn(chunk):
        flat = chunk.reshape(-1)
        w = 1.0 / (1.0 + flat.astype(jnp.float32))
        return jnp.zeros((vocab,), jnp.float32).at[flat].add(w, mode="drop")

    return MapReduceJob(map_fn=fn, n_keys=vocab, name="word_weight",
                        deterministic=True)


class MapReduceEngine:
    """Dual-backend MapReduce as dispatcher jobs.

    Construct either from a fixed 1-D ``mesh`` (legacy API — wraps a FROZEN
    dispatcher, no elasticity) or from an ``ElasticDispatcher`` (the
    middleware path: chunked streaming + IAS adaptive scaling between
    chunks).
    """

    def __init__(self, mesh: Optional[Mesh] = None, backend: str = "hazelcast",
                 axis: str = "data", verbose: bool = False,
                 dispatcher: Optional[ElasticDispatcher] = None):
        assert backend in ("hazelcast", "infinispan")
        if dispatcher is None:
            if mesh is None:
                raise ValueError("MapReduceEngine needs a mesh or a "
                                 "dispatcher")
            dispatcher = ElasticDispatcher.for_mesh(mesh, axis=axis)
        self.dispatcher = dispatcher
        self.backend = backend
        self.axis = dispatcher.axis
        self.verbose = verbose
        self.last_report = None          # DispatchReport of the latest run

    @property
    def mesh(self) -> Mesh:
        return self.dispatcher.mesh      # tracks scale events

    def run(self, job: MapReduceJob, files: jax.Array, *,
            chunk: Optional[int] = None, on_chunk: Optional[Callable] = None,
            checkpoint=None):
        """files: (n_files, file_len) int tokens.  ``chunk`` streams the
        corpus ``chunk`` files per dispatch (None = one dispatch); the IAS
        may re-home the stream between chunks (``on_chunk`` feeds load).
        ``files`` is left as-is: a large DEVICE-resident corpus (e.g. the
        output of a previous dispatcher job; see the dispatcher's
        ``device_slice_min_bytes``) is chunked on device by ``slice_chunk``
        and never round-trips to host; a host (or tiny) corpus is sliced
        host-side while the previous chunk computes (the async pipeline).
        ``checkpoint`` (a ``core.journal.CheckpointPolicy``) makes the
        stream DURABLE: journal + pow2-aligned reduce-state checkpoints;
        after a coordinator death, ``resume_run`` continues it."""
        out, report = self.dispatcher.submit(
            self._dispatch_job(job), files, chunk=chunk, on_chunk=on_chunk,
            checkpoint=checkpoint)
        self.last_report = report
        return jnp.asarray(out)

    def resume_run(self, path: str, job: MapReduceJob, files: jax.Array, *,
                   chunk: Optional[int] = None,
                   on_chunk: Optional[Callable] = None):
        """Continue a journaled ``run`` after a coordinator crash/drain —
        the MapReduce face of ``ElasticDispatcher.resume``: same job + same
        corpus (the environment signature is verified), journaled chunks
        are skipped, and the reduced result is bit-identical to the
        uninterrupted run."""
        out, report = self.dispatcher.resume(
            path, self._dispatch_job(job), files, chunk=chunk,
            on_chunk=on_chunk)
        self.last_report = report
        return jnp.asarray(out)

    def _dispatch_job(self, job: MapReduceJob) -> DispatchJob:
        return dispatch_job_for(job, self.backend, verbose=self.verbose)

    def benchmark(self, job: MapReduceJob, files, repeats: int = 3, *,
                  chunk: Optional[int] = None):
        """Timed run (compile excluded) -> (result, seconds)."""
        out = self.run(job, files, chunk=chunk)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = self.run(job, files, chunk=chunk)
        jax.block_until_ready(out)
        return out, (time.perf_counter() - t0) / repeats


def dispatch_job_for(job: MapReduceJob, backend: str = "hazelcast",
                     verbose: bool = False) -> DispatchJob:
    """The MapReduce job as a dispatch descriptor — module-level so engine-
    LESS callers (``serve.frontend.mapreduce_request``) can build dispatch
    jobs too.  ``map_fn`` itself is part of the signature: a fresh closure
    never reuses another job's executable, while repeated submissions of
    the SAME job object hit the compile cache (the multi-tenant
    amortization path: tenants sharing one job object share one
    executable)."""
    assert backend in ("hazelcast", "infinispan")
    sig = ("mapreduce", backend, job.name, job.n_keys, job.map_fn,
           job.deterministic)

    if job.deterministic:
        # per-FILE map outputs stream out unreduced; the dispatcher owns
        # the (position-aligned, member-count-invariant) tree reduction,
        # so the float result never sees a shard-shaped sum.  Both
        # backends emit identical per-row values — bit-parity for free.
        def per_row(files, valid, *_):
            del valid                # dispatcher masks the padded rows
            return jax.vmap(job.map_fn)(files)

        kw = ({"member_fn": per_row} if backend == "hazelcast"
              else {"global_fn": per_row})
        return DispatchJob(name=f"mapreduce/{job.name}", signature=sig,
                           reduce="sum", deterministic=True, **kw)

    if backend == "hazelcast":
        # explicit member-local map + collective reduce (psum)
        def member_fn(local_files, valid, *_):
            counts = jax.vmap(job.map_fn)(local_files)   # one per file
            if verbose:
                jax.debug.print("[member] mapped {} files locally",
                                local_files.shape[0])
            counts = jnp.where(valid[:, None], counts, 0)
            return counts.sum(axis=0)

        return DispatchJob(name=f"mapreduce/{job.name}", signature=sig,
                           member_fn=member_fn, reduce="sum")

    # infinispan: one global expression, auto-SPMD partitioning
    def global_fn(files, valid, *_):
        counts = jax.vmap(job.map_fn)(files)
        return jnp.where(valid[:, None], counts, 0).sum(axis=0)

    return DispatchJob(name=f"mapreduce/{job.name}", signature=sig,
                       global_fn=global_fn, reduce="sum")


def make_corpus(n_files: int, file_len: int, vocab: int, seed: int = 0,
                zipf_a: float = 1.3) -> np.ndarray:
    """USENET-like corpus: zipf-distributed token ids (the thesis used large
    text files from the Westbury USENET corpus)."""
    rng = np.random.default_rng(seed)
    toks = rng.zipf(zipf_a, size=(n_files, file_len)).astype(np.int64)
    return (toks % vocab).astype(np.int32)
