"""Adaptive scaling — Algorithms 4, 5, 6 of the thesis, ported structurally.

* ``DynamicScaler``      = Algorithm 4 (threshold loop + waiting buffers).
* ``AdaptiveScalerProbe``= Algorithm 5 (publishes scale-out/in flags into the
                           shared health map, one entry per tenant).
* ``IntelligentAdaptiveScaler`` (IAS) = Algorithm 6 (reads the flags, takes an
                           *atomic* decision — exactly one actor scales — with
                           a ``timeBetweenScalingDecisions`` buffer, 0-or-1
                           spawned instance per node).

The TPU adaptation (DESIGN.md §2): membership cannot change mid-``jit``, so a
scaling decision is *applied at a step boundary* by the ``ElasticController``:
checkpoint → rebuild mesh with the new data extent → re-shard → resume.  The
atomic IAtomicLong flag becomes a single-controller decision (process 0),
which is the sound SPMD equivalent (and immune to the split-brain failures
the thesis reports in §4.3.3).
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import math
from typing import Callable, Dict, List, Optional

from repro.core.health import HealthConfig, HealthMonitor, HealthSample

TERMINATE_ALL_FLAG = -999   # the thesis's shutdown sentinel


class Decision(enum.IntEnum):
    SCALE_IN = -1
    NONE = 0
    SCALE_OUT = 1


@dataclasses.dataclass
class ScalerState:
    n_instances: int
    last_scale_step: int = -10 ** 9
    key: int = 0                      # the IAtomicLong flag (0 = idle)
    history: List = dataclasses.field(default_factory=list)


class AdaptiveScalerProbe:
    """Algorithm 5: translate health threshold crossings into flags in the
    (per-tenant) node-health map."""

    def __init__(self, cfg: HealthConfig):
        self.cfg = cfg
        self.node_health: Dict[str, bool] = {"toScaleOut": False,
                                             "toScaleIn": False}

    def probe(self, monitor: HealthMonitor, n_instances: int) -> None:
        load = monitor.load()
        if load >= self.cfg.max_threshold and n_instances < self.cfg.max_instances:
            self.node_health["toScaleOut"] = True
            self.node_health["toScaleIn"] = False
        elif load <= self.cfg.min_threshold and n_instances > self.cfg.min_instances:
            self.node_health["toScaleIn"] = True
            self.node_health["toScaleOut"] = False


class IntelligentAdaptiveScaler:
    """Algorithm 6: atomically turn flags into exactly one scaling action."""

    def __init__(self, cfg: HealthConfig, n_instances: int):
        self.cfg = cfg
        self.state = ScalerState(n_instances=n_instances)

    def decide(self, probe: AdaptiveScalerProbe, step: int) -> Decision:
        st = self.state
        # waiting buffer: prevents cascaded scaling / jitter (paper §4.3.1)
        if step - st.last_scale_step < self.cfg.time_between_scaling:
            return Decision.NONE
        if probe.node_health["toScaleOut"]:
            probe.node_health["toScaleOut"] = False
            if st.key == 0:                         # atomic get-and-set
                st.key = 1
                st.n_instances = min(st.n_instances * 2,
                                     self.cfg.max_instances)
                st.last_scale_step = step
                st.history.append((step, "out", st.n_instances))
                st.key = 0
                return Decision.SCALE_OUT
        elif probe.node_health["toScaleIn"]:
            probe.node_health["toScaleIn"] = False
            if st.key == 0:
                st.key = -1
                st.n_instances = max(st.n_instances // 2,
                                     self.cfg.min_instances)
                st.last_scale_step = step
                st.history.append((step, "in", st.n_instances))
                st.key = 0
                return Decision.SCALE_IN
        return Decision.NONE


def reachable_member_counts(cfg: HealthConfig, start: int) -> frozenset:
    """Closure of member counts the IAS can reach from ``start`` under its
    doubling/halving dynamics (Algorithm 6: ``min(2n, max_instances)`` out,
    ``max(n // 2, min_instances)`` in).  The elastic simulation cluster pads
    entity sizes to the LCM of this set, so entity shapes — and hence PRNG
    draws and finish vectors — are identical at every reachable count."""
    seen, frontier = set(), {max(1, start)}
    while frontier:
        n = frontier.pop()
        seen.add(n)
        for nxt in (min(n * 2, cfg.max_instances),
                    max(n // 2, cfg.min_instances)):
            if nxt >= 1 and nxt not in seen:
                frontier.add(nxt)
    return frozenset(seen)


def entity_pad_multiple(cfg: HealthConfig, start: int) -> int:
    """LCM of every member count reachable from ``start`` — the entity/chunk
    pad multiple that keeps array shapes (hence PRNG draws and finish
    vectors) BIT-identical across every scale event the IAS can take.  Used
    by both the elastic simulation cluster and the dispatcher."""
    return functools.reduce(math.lcm, reachable_member_counts(cfg, start))


class ElasticController:
    """Step-boundary elasticity: monitor → probe → IAS → re-mesh callback.

    ``remesh_fn(new_n_instances)`` is supplied by the runner (training: save a
    checkpoint, rebuild the mesh with the new data-axis extent, re-shard the
    state, resume — see repro/train/elastic_runner.py).
    """

    def __init__(self, cfg: HealthConfig, n_instances: int,
                 remesh_fn: Optional[Callable[[int], None]] = None):
        self.cfg = cfg
        self.monitor = HealthMonitor(cfg)
        self.probe = AdaptiveScalerProbe(cfg)
        self.ias = IntelligentAdaptiveScaler(cfg, n_instances)
        self.remesh_fn = remesh_fn
        self._sim_step = 0                # tick() counter (simulation driver)

    @property
    def n_instances(self) -> int:
        return self.ias.state.n_instances

    def force_instances(self, n: int, reason: str = "failure") -> None:
        """Involuntary membership change (member failure/departure):
        synchronize the IAS to the surviving member count WITHOUT a scaling
        decision.  The change is recorded in the scaler history and starts
        a fresh hysteresis window (``time_between_scaling``) so the scaler
        doesn't immediately thrash on the post-recovery load transient.
        Does NOT invoke ``remesh_fn`` — the caller owns the failure remesh
        (it must drain in-flight work first)."""
        st = self.ias.state
        st.n_instances = max(1, min(n, self.cfg.max_instances))
        st.last_scale_step = self._sim_step
        st.history.append((self._sim_step, reason, st.n_instances))

    def tick(self, load: float) -> Decision:
        """Drive the scaler from a SIMULATION-side load signal: callers with
        no training step loop (e.g. the elastic DES cluster) feed one
        normalized load sample (observed/target, the paper's process-CPU
        analogue) per completed simulation; the step counter is managed
        internally so hysteresis (``time_between_scaling``) still applies."""
        self._sim_step += 1
        return self.on_step(HealthSample(
            step=self._sim_step,
            step_time=load * self.cfg.target_step_time,
            loss=0.0, grad_norm=0.0))

    def tick_queue(self, snapshot) -> Decision:
        """The queue-aware (``HealthConfig.policy="mmn"``) feed: one
        measured ``repro.core.stats.QueueSnapshot`` — arrival rate,
        per-member service rate, queue length — becomes the probe's load
        via the M/M/n utilization signal ``mmn_load`` (per-member demand
        ρ = λ/(n·μ₁), saturated-queue override).  Scale-out fires when
        ρ ≥ max_threshold, scale-in when ρ ≤ min_threshold — exactly the
        analytic M/M/n bottleneck call, validated in tests/test_stats.py."""
        from repro.core.stats import mmn_load
        return self.tick(mmn_load(snapshot, self.cfg.max_threshold,
                                  self.cfg.mmn_queue_cap))

    def on_step(self, sample) -> Decision:
        self.monitor.observe(sample)
        if sample.step % self.cfg.time_between_health_checks:
            return Decision.NONE
        before = self.ias.state.n_instances
        self.probe.probe(self.monitor, before)
        decision = self.ias.decide(self.probe, sample.step)
        if decision != Decision.NONE and self.remesh_fn is not None:
            self.remesh_fn(self.ias.state.n_instances)
        return decision
