"""Queueing-theoretic observability for the dispatch pipeline.

The thesis scales its Hazelcast/Infinispan clusters on coarse load probes;
the production-grade alternative (the Queueing middleware pattern: windowed
stats with warm-up/cool-down trimming, per-stage latency decomposition,
log-bucketed percentile histograms, operational-law bottleneck analysis)
lives here.  Three layers:

  ``StatsWindow``     append-only sample window with warm-up/cool-down
                      trimming: the first ``warmup`` and last ``cooldown``
                      samples are excluded from every statistic, so compile
                      transients and end-of-stream drain effects never skew
                      the percentiles the scaler reads.
  ``Histogram`` /     log-bucketed (geometric) histograms — p50/p95/p99 in
  ``HistogramSet``    O(buckets) memory with bounded relative error: the
                      reported quantile q̂ satisfies q ≤ q̂ ≤ q·growth for
                      in-range samples.
  ``DispatchStats``   the per-stream collector ``ElasticDispatcher.submit``
                      stamps at its four pipeline stages —

                        enqueue   chunk admitted to the dispatch queue
                                  (stream start, or requeue on retry/replay)
                        dispatch  chunk launched (staged + compiled + the
                                  async dispatch call issued)
                        retire    chunk's device computation completed
                                  (``block_until_ready`` returned)
                        validate  guarded validation finished (== retire on
                                  the unguarded path); the reduce boundary
                                  closes the stream

                      and turns into decomposed latencies (queue wait vs
                      service vs validation), arrival/throughput rates,
                      utilization, and time-averaged queue lengths via the
                      OPERATIONAL laws — no distributional assumption:
                      Little's law L = λW holds exactly on the recorded
                      event log because ∫N(t)dt = Σ sojourn_i when the
                      horizon covers every record.

On top sit the analytic M/M/n helpers (``erlang_c``, ``mmn_metrics``,
``mmn_required_members``) and the queue-aware scaling signal ``mmn_load``
that ``HealthConfig(policy="mmn")`` feeds to the IAS: measured per-member
service rate + demand arrival rate + queue backlog instead of a wall-time
EMA alone.  Tier-1 tests drive synthetic jobs of known service-time
distribution through this layer and pin the measured utilization and queue
length to the Erlang-C predictions (tests/test_stats.py).

Instrumentation is pure host-side timestamping — it never touches chunk
payloads, shapes, or reduce order, so streamed results are BIT-identical
with stats enabled (pinned by test_stats_instrumentation_bit_identical).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# interval names derived from the four stage stamps
INTERVALS = ("queue_wait", "service", "validate", "sojourn")


# --------------------------------------------------------------- StatsWindow

class StatsWindow:
    """Append-only sample window with warm-up/cool-down trimming.

    ``warmup`` samples at the head and ``cooldown`` at the tail are excluded
    from every statistic (the Queueing-middleware pattern: the measurement
    phase must not include ramp-up or drain transients).  Both accept an
    int (sample count) or a float in (0, 1) (fraction of samples, rounded
    down).  All statistics are computed over the trimmed view; ``raw()``
    exposes everything.
    """

    def __init__(self, warmup: float = 0, cooldown: float = 0):
        if warmup < 0 or cooldown < 0:
            raise ValueError("warmup/cooldown must be >= 0")
        self.warmup = warmup
        self.cooldown = cooldown
        self._samples: List[float] = []

    def add(self, value: float) -> None:
        self._samples.append(float(value))

    def extend(self, values: Sequence[float]) -> None:
        for v in values:
            self.add(v)

    def __len__(self) -> int:
        return len(self._samples)

    def _trim_counts(self) -> Tuple[int, int]:
        n = len(self._samples)
        w = (int(self.warmup * n) if isinstance(self.warmup, float)
             and self.warmup < 1 else int(self.warmup))
        c = (int(self.cooldown * n) if isinstance(self.cooldown, float)
             and self.cooldown < 1 else int(self.cooldown))
        return w, c

    def raw(self) -> np.ndarray:
        return np.asarray(self._samples, np.float64)

    def trimmed(self) -> np.ndarray:
        """The measurement phase: samples[warmup : n - cooldown] (empty when
        trimming consumes the window — statistics then return NaN)."""
        n = len(self._samples)
        w, c = self._trim_counts()
        if w + c >= n:
            return np.empty(0, np.float64)
        return np.asarray(self._samples[w:n - c], np.float64)

    def mean(self) -> float:
        t = self.trimmed()
        return float(t.mean()) if t.size else float("nan")

    def std(self) -> float:
        t = self.trimmed()
        return float(t.std()) if t.size else float("nan")

    def percentile(self, q: float) -> float:
        t = self.trimmed()
        return float(np.percentile(t, q)) if t.size else float("nan")

    def summary(self) -> Dict[str, float]:
        t = self.trimmed()
        if not t.size:
            return {"n": 0.0, "mean": float("nan"), "p50": float("nan"),
                    "p95": float("nan"), "p99": float("nan")}
        return {"n": float(t.size), "mean": float(t.mean()),
                "p50": float(np.percentile(t, 50)),
                "p95": float(np.percentile(t, 95)),
                "p99": float(np.percentile(t, 99))}


# ----------------------------------------------------------------- Histogram

class Histogram:
    """Log-bucketed histogram: geometric buckets from ``lo`` to ``hi`` with
    ratio ``growth``.  ``quantile(q)`` reports the upper edge of the bucket
    holding the q-th sample, clamped to the observed [min, max] — for
    samples inside [lo, hi] the estimate q̂ obeys  q_true ≤ q̂ ≤
    q_true·growth  (the bounded-relative-error contract the property tests
    pin).  Sub-``lo`` samples land in an underflow bucket reported as
    ``lo``; super-``hi`` samples land in an overflow bucket reported as the
    observed max."""

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 growth: float = 1.25):
        if not (lo > 0 and hi > lo and growth > 1):
            raise ValueError("need 0 < lo < hi and growth > 1")
        self.lo, self.hi, self.growth = float(lo), float(hi), float(growth)
        self._log_lo = math.log(lo)
        self._log_g = math.log(growth)
        self.n_buckets = int(math.ceil((math.log(hi) - self._log_lo)
                                       / self._log_g))
        # [0] underflow, [1..n_buckets] log buckets, [-1] overflow
        self.counts = np.zeros(self.n_buckets + 2, np.int64)
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def _bucket(self, v: float) -> int:
        if v <= self.lo:
            return 0
        if v > self.hi:
            return self.n_buckets + 1
        # ceil so bucket b's range is (lo·g^(b-1), lo·g^b]
        b = int(math.ceil((math.log(v) - self._log_lo) / self._log_g))
        return min(max(b, 1), self.n_buckets)

    def edge(self, bucket: int) -> float:
        """Upper edge of ``bucket`` (underflow -> lo, overflow -> hi)."""
        if bucket <= 0:
            return self.lo
        if bucket > self.n_buckets:
            return self.hi
        return self.lo * self.growth ** bucket

    def add(self, value: float) -> None:
        v = float(value)
        if not math.isfinite(v) or v < 0:
            raise ValueError(f"histogram samples must be finite and >= 0, "
                             f"got {value!r}")
        self.counts[self._bucket(v)] += 1
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def quantile(self, q: float) -> float:
        """Upper bucket edge at cumulative count ⌈q·n⌉, clamped to the
        observed extrema; NaN when empty."""
        if self.count == 0:
            return float("nan")
        rank = max(int(math.ceil(q / 100.0 * self.count)), 1)
        cum = np.cumsum(self.counts)
        bucket = int(np.searchsorted(cum, rank))
        if bucket > self.n_buckets:
            return self.max               # overflow: report the observed max
        return float(min(max(self.edge(bucket), self.min), self.max))

    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def merge(self, other: "Histogram") -> None:
        if (other.lo, other.hi, other.growth) != (self.lo, self.hi,
                                                  self.growth):
            raise ValueError("cannot merge histograms with different buckets")
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def summary(self) -> Dict[str, float]:
        return {"n": float(self.count), "mean": self.mean(),
                "p50": self.quantile(50), "p95": self.quantile(95),
                "p99": self.quantile(99)}


class HistogramSet:
    """Named histograms sharing one bucket layout — one per pipeline stage /
    derived interval, created on first record."""

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 growth: float = 1.25):
        self.lo, self.hi, self.growth = lo, hi, growth
        self.hists: Dict[str, Histogram] = {}

    def record(self, name: str, value: float) -> None:
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram(self.lo, self.hi, self.growth)
        h.add(value)

    def __getitem__(self, name: str) -> Histogram:
        return self.hists[name]

    def __contains__(self, name: str) -> bool:
        return name in self.hists

    def quantiles(self, qs: Sequence[float] = (50, 95, 99)
                  ) -> Dict[str, Dict[str, float]]:
        return {name: {f"p{int(q)}": h.quantile(q) for q in qs}
                for name, h in self.hists.items()}

    def summary(self) -> Dict[str, Dict[str, float]]:
        return {name: h.summary() for name, h in self.hists.items()}


# ----------------------------------------------------- per-stream collector

@dataclasses.dataclass
class ChunkTimeline:
    """Stage stamps for ONE launch attempt of one chunk (retries append a
    fresh record).  ``tainted`` marks compile/remesh-spanning attempts whose
    walls are trace/rebuild noise, not steady-state latency — they are kept
    in the time-integrals (real wall time) but excluded from the latency
    windows and histograms, mirroring the EMA-reset logic in ``submit``."""
    chunk: int
    t_enqueue: float
    t_dispatch: float = float("nan")
    t_retire: float = float("nan")
    t_validate: float = float("nan")
    tainted: bool = False

    @property
    def complete(self) -> bool:
        return math.isfinite(self.t_retire)


class DispatchStats:
    """The per-stream stage-stamp collector.

    ``serialized=True`` (the dispatcher's pipeline) measures SERVICE as the
    exclusive device interval ``retire_i - max(dispatch_i, retire_{i-1})``:
    under pipelining a chunk's launch-to-retire wall includes time queued
    BEHIND the previous chunk on the device, and the retirement-to-
    retirement gap is the honest per-chunk cost (the same quantity the
    auto-scale EMA samples).  ``serialized=False`` (direct feeding: tests,
    the serve layer, any open system with parallel servers) takes service
    as ``retire - dispatch`` verbatim.

    ``summary(n_servers=...)`` derives the queueing view:

      arrival_rate       records / horizon  (first enqueue -> last validate)
      throughput         completions / horizon
      utilization        Σ service / (horizon · n_servers)  — the
                         operational utilization law  U = X·S/n
      mean_queue_length  time-averaged #waiting  = Σ queue_wait / horizon
                         (exact:  ∫N_q(t)dt = Σ w_i)
      mean_in_system     time-averaged #in-system = Σ sojourn / horizon
                         (Little's law:  L = λ·W  holds exactly here)
    """

    def __init__(self, warmup: float = 1, cooldown: float = 0,
                 clock=time.perf_counter, serialized: bool = True,
                 hist_lo: float = 1e-6, hist_hi: float = 1e4,
                 hist_growth: float = 1.25):
        self.clock = clock
        self.serialized = serialized
        self.warmup, self.cooldown = warmup, cooldown
        self.records: List[ChunkTimeline] = []
        self.hist = HistogramSet(hist_lo, hist_hi, hist_growth)
        self.windows: Dict[str, StatsWindow] = {
            name: StatsWindow(warmup, cooldown) for name in INTERVALS}
        self.stall_s: List[float] = []
        self.checkpoint_s: List[float] = []
        self.rejections: Dict[str, int] = {}   # reason -> count (admission/
        #                                        shedding/serve-layer drops)
        self._open: Dict[int, ChunkTimeline] = {}    # enqueued, not launched
        self._live: Dict[int, ChunkTimeline] = {}    # launched, not validated
        self._last_retire: Optional[float] = None

    # ------------------------------------------------------------- stamping
    def enqueue(self, chunk: int, t: Optional[float] = None) -> None:
        self._open[chunk] = ChunkTimeline(
            chunk=chunk, t_enqueue=self.clock() if t is None else t)

    def dispatch(self, chunk: int, t: Optional[float] = None,
                 tainted: bool = False) -> None:
        rec = self._open.pop(chunk, None)
        if rec is None:                    # defensive: un-stamped admission
            rec = ChunkTimeline(chunk=chunk, t_enqueue=self.clock())
        rec.t_dispatch = self.clock() if t is None else t
        rec.tainted = rec.tainted or tainted
        self._live[chunk] = rec
        self.records.append(rec)

    def retire(self, chunk: int, t: Optional[float] = None,
               tainted: bool = False) -> None:
        rec = self._live.get(chunk)
        if rec is None:
            return
        rec.t_retire = self.clock() if t is None else t
        rec.tainted = rec.tainted or tainted

    def validate(self, chunk: int, t: Optional[float] = None,
                 tainted: bool = False) -> None:
        rec = self._live.pop(chunk, None)
        if rec is None:
            return
        now = self.clock() if t is None else t
        if not rec.complete:
            rec.t_retire = now
        rec.t_validate = now
        rec.tainted = rec.tainted or tainted
        self._close(rec)

    def record(self, chunk: int, t_enqueue: float, t_dispatch: float,
               t_retire: float, t_validate: Optional[float] = None,
               tainted: bool = False) -> None:
        """Feed one complete record directly (tests, serve layer, synthetic
        M/M/n streams) — equivalent to the four stamps in order."""
        self.enqueue(chunk, t_enqueue)
        self.dispatch(chunk, t_dispatch, tainted=tainted)
        self.retire(chunk, t_retire)
        self.validate(chunk, t_retire if t_validate is None else t_validate)

    def record_stall(self, delay_s: float) -> None:
        """An injected/detected stall's extra latency — fed to its own
        histogram so docs/robustness.md's stall records are quantified."""
        self.stall_s.append(float(delay_s))
        self.hist.record("stall", delay_s)

    def record_rejection(self, reason: str, n: int = 1) -> None:
        """One structured rejection (admission denial, overload shed, serve
        drop).  Rejected work never enters the four-stage pipeline, so the
        latency/queue views are unaffected; ``summary()`` surfaces the
        per-reason counts so shed load is observable, never silent."""
        self.rejections[reason] = self.rejections.get(reason, 0) + int(n)

    def record_checkpoint(self, write_s: float) -> None:
        """One durable checkpoint's write latency (tmp-dir + rename wall on
        the writer thread — overlap means it is NOT stream wall time; the
        stream-side cost is the host fold + digest, bounded by
        BENCH_resume.json's overhead entries)."""
        self.checkpoint_s.append(float(write_s))
        self.hist.record("checkpoint", write_s)

    # ------------------------------------------------------------ intervals
    def _close(self, rec: ChunkTimeline) -> None:
        prev_retire, self._last_retire = self._last_retire, rec.t_retire
        if rec.tainted:
            return                      # trace/rebuild noise: integrals only
        wait = rec.t_dispatch - rec.t_enqueue
        if self.serialized and prev_retire is not None:
            service = rec.t_retire - max(rec.t_dispatch, prev_retire)
        else:
            service = rec.t_retire - rec.t_dispatch
        validate = rec.t_validate - rec.t_retire
        sojourn = rec.t_validate - rec.t_enqueue
        for name, v in (("queue_wait", wait), ("service", service),
                        ("validate", validate), ("sojourn", sojourn)):
            v = max(v, 0.0)
            self.windows[name].add(v)
            self.hist.record(name, v)

    # -------------------------------------------------------------- queueing
    def horizon(self) -> Tuple[float, float]:
        done = [r for r in self.records if r.complete]
        if not done:
            return 0.0, 0.0
        t0 = min(r.t_enqueue for r in done)
        t1 = max(r.t_validate if math.isfinite(r.t_validate) else r.t_retire
                 for r in done)
        return t0, t1

    def queue_summary(self, n_servers: int = 1) -> Dict[str, float]:
        """The operational-law view over the FULL horizon (time-integrals
        are real elapsed time; trimming applies to the latency windows, not
        to conservation laws)."""
        done = [r for r in self.records if r.complete]
        t0, t1 = self.horizon()
        span = t1 - t0
        if not done or span <= 0:
            return {"n_completed": float(len(done)), "horizon_s": 0.0,
                    "arrival_rate": 0.0, "throughput": 0.0,
                    "utilization": 0.0, "mean_queue_length": 0.0,
                    "mean_in_system": 0.0}
        waits = [max(r.t_dispatch - r.t_enqueue, 0.0) for r in done]
        sojourns = [max((r.t_validate if math.isfinite(r.t_validate)
                         else r.t_retire) - r.t_enqueue, 0.0) for r in done]
        if self.serialized:
            services, prev = [], None
            for r in sorted(done, key=lambda r: r.t_retire):
                start = (r.t_dispatch if prev is None
                         else max(r.t_dispatch, prev))
                services.append(max(r.t_retire - start, 0.0))
                prev = r.t_retire
        else:
            services = [max(r.t_retire - r.t_dispatch, 0.0) for r in done]
        n = float(len(done))
        return {
            "n_completed": n,
            "horizon_s": span,
            "arrival_rate": n / span,
            "throughput": n / span,
            "utilization": sum(services) / (span * max(n_servers, 1)),
            "mean_queue_length": sum(waits) / span,
            "mean_in_system": sum(sojourns) / span,
        }

    def mean_service(self) -> float:
        """Trimmed mean service time (NaN until the window has steady
        samples) — the mmn policy's per-chunk cost input."""
        return self.windows["service"].mean()

    def summary(self, n_servers: int = 1) -> Dict[str, object]:
        """Everything ``DispatchReport.stats`` exposes: per-interval
        windowed stats, log-bucket percentiles, stall records, and the
        operational-law queueing view.  Plain dict of floats — survives
        ``dataclasses.asdict`` and JSON."""
        out: Dict[str, object] = {
            "n_records": float(len(self.records)),
            "n_tainted": float(sum(r.tainted for r in self.records)),
            "warmup": float(self.warmup), "cooldown": float(self.cooldown),
        }
        for name in INTERVALS:
            w = self.windows[name].summary()
            if name in self.hist:
                h = self.hist[name]
                w["hist_p50"] = h.quantile(50)
                w["hist_p95"] = h.quantile(95)
                w["hist_p99"] = h.quantile(99)
            out[name] = w
        if self.stall_s:
            out["stall"] = {"n": float(len(self.stall_s)),
                            "total_s": float(sum(self.stall_s)),
                            "p99": self.hist["stall"].quantile(99)}
        if self.checkpoint_s:
            out["checkpoint"] = {"n": float(len(self.checkpoint_s)),
                                 "total_s": float(sum(self.checkpoint_s)),
                                 "p99": self.hist["checkpoint"].quantile(99)}
        if self.rejections:
            out["rejections"] = {k: float(v)
                                 for k, v in sorted(self.rejections.items())}
            out["n_rejected"] = float(sum(self.rejections.values()))
        out["queue"] = self.queue_summary(n_servers)
        return out


# ------------------------------------------------------------ M/M/n analytics

def erlang_c(n: int, a: float) -> float:
    """P(wait) for an M/M/n queue with offered load ``a = λ/μ`` Erlangs.
    1.0 when the queue is unstable (a >= n)."""
    if n < 1:
        raise ValueError("n must be >= 1")
    if a <= 0:
        return 0.0
    if a >= n:
        return 1.0
    # iterative Erlang-B, then the standard C-from-B transform (numerically
    # stable for any n — no factorials)
    b = 1.0
    for k in range(1, n + 1):
        b = a * b / (k + a * b)
    rho = a / n
    return b / (1.0 - rho + rho * b)


def mmn_metrics(lam: float, mu: float, n: int) -> Dict[str, float]:
    """Analytic steady-state M/M/n quantities for arrival rate ``lam``,
    PER-SERVER service rate ``mu``, ``n`` servers: per-server utilization
    ``rho``, wait probability ``p_wait`` (Erlang C), mean waiting count
    ``lq``, mean in-system count ``l``, mean wait ``wq``, mean sojourn
    ``w``.  Infinite where the queue is unstable (rho >= 1)."""
    if lam < 0 or mu <= 0:
        raise ValueError("need lam >= 0 and mu > 0")
    a = lam / mu
    rho = a / n
    if rho >= 1.0:
        inf = float("inf")
        return {"rho": rho, "p_wait": 1.0, "lq": inf, "l": inf,
                "wq": inf, "w": inf}
    pw = erlang_c(n, a)
    lq = pw * rho / (1.0 - rho)
    wq = lq / lam if lam > 0 else 0.0
    return {"rho": rho, "p_wait": pw, "lq": lq, "l": lq + a,
            "wq": wq, "w": wq + 1.0 / mu}


def mmn_required_members(lam: float, mu: float, rho_target: float,
                         max_members: int = 1 << 16) -> int:
    """Smallest ``n`` with per-server utilization λ/(n·μ) below
    ``rho_target`` — the analytic bottleneck call the scaler's decisions
    are validated against."""
    if not 0 < rho_target:
        raise ValueError("rho_target must be > 0")
    n = max(int(math.ceil(lam / (mu * rho_target))), 1)
    return min(n, max_members)


@dataclasses.dataclass(frozen=True)
class QueueSnapshot:
    """One measured queue-state observation the mmn policy consumes.

    arrival_rate    demand in chunks/s.  For an OPEN stream (serve layer)
                    this is the measured admission rate; for a CLOSED
                    ``submit`` stream the queue is full by construction, so
                    the dispatcher anchors demand at the job class's target:
                    ``1 / target_step_time`` chunks/s.
    service_rate    per-MEMBER service rate μ₁ in chunks/s.  The dispatcher
                    derives it from the measured cluster service time s_n
                    under the linear-scaling assumption:  one chunk costs
                    ``s_n · n`` member-seconds, so  μ₁ = 1 / (s_n · n).
    n_members       current cluster size.
    queue_length    measured mean number waiting (0 for closed streams —
                    backlog there is not a demand signal).
    """
    arrival_rate: float
    service_rate: float
    n_members: int
    queue_length: float = 0.0

    @property
    def rho(self) -> float:
        """Per-member utilization demand λ/(n·μ₁) — the load the probe
        thresholds compare (directly in the paper's [0, 1+] CPU-load
        scale)."""
        return self.arrival_rate / (max(self.n_members, 1)
                                    * max(self.service_rate, 1e-12))


def mmn_load(snapshot: QueueSnapshot, max_threshold: float = 0.8,
             queue_cap: float = 4.0) -> float:
    """The probe-compatible load signal of the mmn policy: per-member
    utilization demand ρ = λ/(n·μ₁), pushed to at least ``max_threshold``
    when the measured backlog exceeds ``queue_cap`` waiting chunks per
    member — a saturated queue means the cluster is the bottleneck even
    when per-chunk service alone looks acceptable (Erlang-C's Lq explodes
    as ρ→1 long before measured utilization does)."""
    load = snapshot.rho
    if queue_cap > 0 and snapshot.queue_length > 0:
        pressure = (snapshot.queue_length
                    / (max(snapshot.n_members, 1) * queue_cap))
        if pressure >= 1.0:
            load = max(load, max_threshold * min(pressure, 2.0))
    return load
