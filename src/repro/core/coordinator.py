"""Multi-tenant Coordinator — §3.1.2 / Fig 3.4 & 3.7.

A *tenant* is one experiment (a cluster in the thesis); the Coordinator holds
a handle into every tenant, keeps the per-tenant health/scaling maps keyed by
tenant id (the thesis's distributed hash maps), allocates resources (device
sub-meshes), and presents the combined output — "a global view of the
deployment".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.elastic import Decision, ElasticController
from repro.core.health import HealthConfig, HealthSample


@dataclasses.dataclass
class Tenant:
    tenant_id: str
    run_fn: Callable[[Mesh, Dict], Dict]   # (mesh, ctx) -> result dict
    n_devices: int = 1
    controller: Optional[ElasticController] = None
    result: Optional[Dict] = None
    status: str = "pending"


class Coordinator:
    """Coordinates N tenants over one device pool.

    Devices are split into per-tenant sub-meshes (clusters can co-exist in the
    same nodes — multiple "Hazelcast instances" per node ≙ multiple sub-meshes
    drawing on the same chips is NOT possible under SPMD, so tenants get
    disjoint device slices; the thesis's node-sharing maps to time-sharing
    when the pool is too small, which we also support via sequential rounds).
    """

    def __init__(self, devices=None, health_cfg: Optional[HealthConfig] = None):
        self.devices = list(devices if devices is not None else jax.devices())
        self.health_cfg = health_cfg or HealthConfig()
        self.tenants: Dict[str, Tenant] = {}
        self.health_map: Dict[str, Dict] = {}     # tenant id -> health summary
        self.scaling_map: Dict[str, List] = {}    # tenant id -> scale events

    # ------------------------------------------------------------ tenancy
    def register(self, tenant_id: str, run_fn, n_devices: int = 1) -> Tenant:
        t = Tenant(tenant_id, run_fn, n_devices)
        t.controller = ElasticController(self.health_cfg, n_devices)
        self.tenants[tenant_id] = t
        return t

    def _allocate(self) -> Dict[str, List]:
        """Disjoint device slices per tenant; falls back to time-sharing."""
        alloc, cursor = {}, 0
        for tid, t in self.tenants.items():
            n = min(t.n_devices, max(len(self.devices) - cursor, 0))
            if n == 0:
                alloc[tid] = self.devices  # time-share the whole pool
            else:
                alloc[tid] = self.devices[cursor:cursor + n]
                cursor += n
        return alloc

    # ----------------------------------------------------------- execution
    def run_all(self) -> Dict[str, Dict]:
        """Run every tenant (sequentially on this single-process runtime —
        multi-process deployments run tenants concurrently per sub-mesh)."""
        alloc = self._allocate()
        for tid, t in self.tenants.items():
            devs = alloc[tid]
            mesh = Mesh(np.array(devs), ("data",))
            t.status = "running"
            t0 = time.perf_counter()
            ctx = {"tenant_id": tid, "controller": t.controller,
                   "coordinator": self}
            t.result = t.run_fn(mesh, ctx)
            t.status = "done"
            self.health_map[tid] = dict(t.controller.monitor.summary(),
                                        wall_s=time.perf_counter() - t0)
            self.scaling_map[tid] = list(t.controller.ias.state.history)
        return {tid: t.result for tid, t in self.tenants.items()}

    def report(self) -> Dict:
        """The Coordinator's combined view of multi-tenanted executions."""
        return {"tenants": {tid: t.status for tid, t in self.tenants.items()},
                "health": self.health_map, "scaling": self.scaling_map}
