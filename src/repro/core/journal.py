"""Durable dispatch — the append-only job journal + checkpointed reduce state.

PR 6 made ``ElasticDispatcher`` survive *member* failure; this module makes it
survive the COORDINATOR: kill the driver mid-stream (SIGKILL, preemption, a
scheduled ``coordinator_crash`` fault) and ``ElasticDispatcher.resume`` picks
the stream back up from durable state, bit-identical to the uninterrupted run.
The thesis pitches Cloud²Sim as "a general purpose auto scaler middleware for
a multi-tenanted deployment" — a middleware serving long tenant jobs must
outlive its own restarts, and the CloudSim-line campaigns it hosts are exactly
the runs too expensive to redo from scratch.

Two durability layers cooperate (see docs/robustness.md, "Coordinator failure
model"):

``JobJournal``      an append-only JSONL journal: one header record pinning
                    the job + environment signature and the chunk schedule,
                    then per-chunk records of validated output DIGESTS,
                    fault/retry records, scale events with partition-table
                    snapshots, checkpoint records, and a final ``complete``
                    record carrying the result digest.  Records are
                    self-contained lines; a torn tail line (the process died
                    mid-write) is ignored on load.

``CheckpointPolicy``  when/where ``submit`` persists PARTIAL REDUCE STATE.
                    Boundaries are aligned to power-of-two subtree roots of
                    the PR 5 deterministic chunk tree: the binary-counter
                    state after a validated prefix of k chunks is exactly the
                    pow2 subtrees of k's binary decomposition, so a
                    checkpointed partial float sum is an *exact* subtree
                    state and resume reproduces the uninterrupted bytes.
                    Writes reuse the seed's atomic tmp-dir+rename idiom
                    (``train/checkpoint.py``) on a background writer thread
                    (``train/async_ckpt.py``) so they never block the
                    dispatch-ahead pipeline.

Resume verifies the journal's environment signature (geometry, backend,
dtype/shape structs, chunk plan) against the resuming dispatcher and raises a
loud ``ResumeMismatchError`` on ANY divergence — never silent drift; replayed
chunks are additionally digest-checked against their journaled records.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import queue
import shutil
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "CheckpointPolicy", "DrainInterrupted", "JobJournal", "JournalState",
    "ResumeMismatchError", "counter_push", "counter_drain", "journal_dir",
    "stable_signature", "tree_decode", "tree_digest", "tree_encode",
]


def journal_dir(path: str) -> str:
    """Normalize a journal reference: accept either the journal DIRECTORY or
    its ``journal.jsonl`` file and return the directory — callers paste
    whichever path the crash log showed them."""
    if os.path.basename(path) == "journal.jsonl" or os.path.isfile(path):
        return os.path.dirname(path) or "."
    return path


class ResumeMismatchError(RuntimeError):
    """The journal's environment signature (or a replayed chunk's digest, or
    a checkpoint's integrity digest) does not match the resuming run.  Loud
    by design: a mismatched resume must never silently diverge from the
    journaled stream."""


class DrainInterrupted(RuntimeError):
    """A stream stopped early because ``request_drain`` (or an installed
    SIGTERM handler) asked for graceful preemption: in-flight chunks were
    retired, validated state was checkpointed, and the journal is ready for
    ``resume``.  Carries the partial ``DispatchReport`` and the journal
    path — the graceful twin of ``JobFailedError``."""

    def __init__(self, message: str, report, journal_path: str):
        super().__init__(message)
        self.report = report
        self.journal_path = journal_path


# -------------------------------------------------------------------- policy

def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """When/where ``submit`` journals and checkpoints a stream.

    path            journal directory (created on first write)
    every_n_chunks  checkpoint the validated prefix every N chunks, ROUNDED
                    UP to a power of two — boundaries then sit on pow2
                    subtree roots of the deterministic chunk tree, so each
                    persisted partial is an exact subtree state (the
                    alignment rule docs/robustness.md documents)
    async_write     hand encoded state to a background writer thread (the
                    ``train/async_ckpt`` pattern) so checkpoint writes never
                    block the dispatch-ahead pipeline; False writes inline
                    (tests / post-mortem debugging)
    digest_chunks   journal a sha256 digest per validated chunk; resume
                    verifies replayed chunks against it (bit-identity made
                    loud) at the cost of one host copy per chunk
    keep            rotated intermediate checkpoint dirs to retain (the
                    final checkpoint is never rotated away)
    fsync           "checkpoint" (default) fsyncs the journal at checkpoint /
                    completion / drain records, "always" at every record,
                    "never" leaves flushing to the OS
    """
    path: str
    every_n_chunks: int = 4
    async_write: bool = True
    digest_chunks: bool = True
    keep: int = 2
    fsync: str = "checkpoint"

    def __post_init__(self):
        if self.every_n_chunks < 1:
            raise ValueError("every_n_chunks must be >= 1")
        if self.fsync not in ("always", "checkpoint", "never"):
            raise ValueError(f"unknown fsync mode {self.fsync!r}")
        object.__setattr__(self, "every_n_chunks",
                           _next_pow2(self.every_n_chunks))


# ------------------------------------------------- pytree encode / decode

def tree_encode(tree) -> Tuple[dict, List[np.ndarray]]:
    """Encode a pytree of arrays/scalars/containers into a JSON-serializable
    spec plus a flat list of numpy leaves.  Self-describing and dependency-
    free (no pickled treedefs): dict/list/tuple/None/str/bool/int/float
    containers round-trip exactly, array leaves land in the flat list in
    spec order.  The checkpoint property test round-trips this."""
    leaves: List[np.ndarray] = []

    def enc(node):
        if node is None:
            return {"t": "none"}
        if isinstance(node, bool):          # before int: bool is an int
            return {"t": "py", "v": node}
        if isinstance(node, (int, float, str)):
            return {"t": "py", "v": node}
        if isinstance(node, dict):
            return {"t": "dict", "k": [enc(k) for k in node],
                    "v": [enc(v) for v in node.values()]}
        if isinstance(node, tuple):
            return {"t": "tuple", "v": [enc(v) for v in node]}
        if isinstance(node, list):
            return {"t": "list", "v": [enc(v) for v in node]}
        arr = np.asarray(node)              # jax array / np scalar / ndarray
        leaves.append(arr)
        return {"t": "arr", "i": len(leaves) - 1}

    return enc(tree), leaves


def tree_decode(spec: dict, leaves) -> object:
    """Inverse of ``tree_encode``: rebuild the pytree from (spec, leaves)."""

    def dec(node):
        t = node["t"]
        if t == "none":
            return None
        if t == "py":
            return node["v"]
        if t == "dict":
            return {dec(k): dec(v) for k, v in zip(node["k"], node["v"])}
        if t == "tuple":
            return tuple(dec(v) for v in node["v"])
        if t == "list":
            return [dec(v) for v in node["v"]]
        if t == "arr":
            return np.asarray(leaves[node["i"]])
        raise ValueError(f"unknown spec node type {t!r}")

    return dec(spec)


def tree_digest(tree) -> str:
    """sha256 over the encoded spec + every leaf's dtype/shape/bytes — the
    chunk/checkpoint integrity digest.  Canonical C-order bytes, so the
    digest is placement-independent (device vs host copies agree)."""
    spec, leaves = tree_encode(tree)
    h = hashlib.sha256(json.dumps(spec, sort_keys=True).encode())
    for leaf in leaves:
        arr = np.ascontiguousarray(leaf)
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def stable_signature(obj) -> str:
    """A process-stable string form of a job signature: callables render as
    ``fn:module.qualname`` (plain ``repr`` leaks memory addresses, which
    would make every resume a false ``ResumeMismatchError``), containers
    recurse, everything else reprs."""
    if callable(obj):
        mod = getattr(obj, "__module__", "?")
        name = getattr(obj, "__qualname__", getattr(obj, "__name__", "?"))
        return f"fn:{mod}.{name}"
    if isinstance(obj, (tuple, list)):
        inner = ",".join(stable_signature(v) for v in obj)
        return f"({inner})" if isinstance(obj, tuple) else f"[{inner}]"
    if isinstance(obj, dict):
        inner = ",".join(f"{stable_signature(k)}:{stable_signature(v)}"
                         for k, v in sorted(obj.items(), key=repr))
        return "{" + inner + "}"
    return repr(obj)


# ------------------------------------------------- binary-counter fold

def counter_push(pending: Dict[int, object], part, combine) -> None:
    """Push one chunk partial into the binary-counter tree state (the PR 5
    ``_chunk_tree_reduce`` counter, factored out so the checkpoint fold and
    the final combine share ONE tree).  ``pending`` maps level -> partial
    subtree; after k pushes its occupied levels are exactly the binary
    decomposition of k, each entry the root of an exact pow2 subtree —
    which is why a checkpoint of ``pending`` at any validated prefix is an
    exact subtree state and resume is bit-identical."""
    import jax

    level = 0
    while level in pending:
        part = jax.tree_util.tree_map(combine, pending.pop(level), part)
        level += 1
    pending[level] = part


def counter_drain(pending: Dict[int, object], combine):
    """Fold the surviving counter levels, ascending — latest chunks first,
    so each fold keeps earlier chunks on the LEFT of the combine."""
    import jax

    out = None
    for level in sorted(pending):
        out = (pending[level] if out is None
               else jax.tree_util.tree_map(combine, pending[level], out))
    return out


# ------------------------------------------------------------- the journal

def _ck_dirname(k: int, kind: str) -> str:
    return "ck_final" if kind == "final" else f"ck_{int(k):08d}"


class JobJournal:
    """Append-only journal writer with atomic background checkpoint writes.

    One instance per active stream.  ``append`` emits one self-contained
    JSON line; ``write_checkpoint`` encodes the state tree on the CALLING
    thread (host numpy — the device->host snapshot already happened) and,
    on the writer thread, writes ``<path>/ck_*/`` atomically (tmp dir +
    rename, the ``train/checkpoint.py`` idiom) and only THEN appends the
    checkpoint record — a record therefore always points at a fully-renamed
    directory.  Writer failures surface on the next call / ``wait`` instead
    of dying silently (the ``train/async_ckpt`` contract)."""

    def __init__(self, policy: CheckpointPolicy, *, fresh: bool):
        self.policy = policy
        self.path = policy.path
        self.journal_file = os.path.join(self.path, "journal.jsonl")
        os.makedirs(self.path, exist_ok=True)
        if fresh:
            for name in os.listdir(self.path):
                if name == "journal.jsonl" or name.startswith("ck_"):
                    full = os.path.join(self.path, name)
                    shutil.rmtree(full) if os.path.isdir(full) \
                        else os.remove(full)
        self._f = open(self.journal_file, "a", encoding="utf-8")
        self.n_checkpoints = 0
        self.write_s: List[float] = []      # per-checkpoint write latency
        self._err: Optional[BaseException] = None
        self._q: Optional["queue.Queue"] = None
        self._thread: Optional[threading.Thread] = None
        if policy.async_write:
            self._q = queue.Queue()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    @classmethod
    def create(cls, policy: CheckpointPolicy, header: dict) -> "JobJournal":
        """Fresh journal for a new stream: wipes any previous journal at the
        path and writes the header record first."""
        j = cls(policy, fresh=True)
        j.append({"type": "header", "version": 1, **header}, fsync=True)
        return j

    @classmethod
    def reopen(cls, policy: CheckpointPolicy) -> "JobJournal":
        """Append-mode writer for ``resume`` — existing records are kept."""
        return cls(policy, fresh=False)

    # ----------------------------------------------------------- writer side
    def _raise_pending(self):
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("journal writer failed") from err

    def append(self, record: dict, fsync: bool = False) -> None:
        """Append one JSON record line (ordered with checkpoint writes)."""
        self._raise_pending()
        record = dict(record)
        record.setdefault("t", time.time())
        if self._q is not None:
            self._q.put(("record", record, fsync))
        else:
            self._write_record(record, fsync)

    def defer(self, fn) -> None:
        """Run ``fn()`` on the writer thread, ordered with every queued
        record and checkpoint.  The dispatcher hands the whole per-chunk
        journaling tail over this way — device->host gather, sha256 digest,
        checkpoint fold + write — because all of it walks every output byte
        and would otherwise serialize against the dispatch-ahead pipeline.
        ``fn`` must write through the synchronous internals
        (``sync_append`` / ``checkpoint_now``): a nested ``append`` would
        re-enqueue behind later items and break record order.  Runs inline
        when ``async_write`` is off; failures surface on the next call /
        ``wait`` like any writer error."""
        self._raise_pending()
        if self._q is not None:
            self._q.put(("defer", fn))
        else:
            fn()

    def sync_append(self, record: dict, fsync: bool = False) -> None:
        """Write one record line ON THE CALLING THREAD — for ``defer``
        callbacks and post-``wait`` code where queue order is settled."""
        record = dict(record)
        record.setdefault("t", time.time())
        self._write_record(record, fsync)

    def checkpoint_now(self, k: int, kind: str, state, meta: dict) -> None:
        """Synchronous ``write_checkpoint``: encode + digest + atomic write
        on the calling thread.  Same ``defer``-callback contract as
        ``sync_append``."""
        spec, leaves = tree_encode(state)
        manifest = {"k": int(k), "kind": kind, "spec": spec,
                    "digest": tree_digest(state), **dict(meta)}
        self._write_checkpoint(manifest, leaves)

    def write_checkpoint(self, k: int, kind: str, state, meta: dict) -> None:
        """Persist reduce state atomically; ``kind`` is "pending" (the
        binary-counter dict), "prefix" (concat prefix list), or "final"
        (the completed stream's combined output).  Encode + digest + write
        all happen on the writer thread when async — the caller only pays a
        queue put; the state tree handed over is never mutated afterwards
        (folds rebuild fresh dicts/arrays)."""
        self._raise_pending()
        if self._q is not None:
            self._q.put(("checkpoint_state", int(k), kind, state, dict(meta)))
        else:
            spec, leaves = tree_encode(state)
            manifest = {"k": int(k), "kind": kind, "spec": spec,
                        "digest": tree_digest(state), **meta}
            self._write_checkpoint(manifest, leaves)

    def wait(self) -> None:
        """Block until every queued record/checkpoint is on disk."""
        if self._q is not None:
            self._q.join()
        self._raise_pending()

    def close(self) -> None:
        try:
            if self._q is not None:
                self._q.join()
                self._q.put(None)
                if self._thread is not None:
                    self._thread.join(timeout=10)
        finally:
            try:
                self._f.close()
            except Exception:
                pass
        self._raise_pending()

    # ------------------------------------------------------- worker internals
    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                if item[0] == "record":
                    self._write_record(item[1], item[2])
                elif item[0] == "defer":
                    item[1]()
                else:                        # "checkpoint_state"
                    _, k, kind, state, meta = item
                    spec, leaves = tree_encode(state)
                    manifest = {"k": k, "kind": kind, "spec": spec,
                                "digest": tree_digest(state), **meta}
                    self._write_checkpoint(manifest, leaves)
            except BaseException as e:       # surfaced on next append/wait
                self._err = e
            finally:
                self._q.task_done()

    def _write_record(self, record: dict, fsync: bool) -> None:
        self._f.write(json.dumps(record, default=str) + "\n")
        self._f.flush()
        mode = self.policy.fsync
        if mode == "always" or (mode == "checkpoint" and fsync):
            os.fsync(self._f.fileno())

    def _write_checkpoint(self, manifest: dict, leaves) -> None:
        t0 = time.perf_counter()
        final = os.path.join(self.path, _ck_dirname(manifest["k"],
                                                    manifest["kind"]))
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        # raw per-leaf .npy files, not a zipped .npz: the zip container
        # CRCs + copies every byte, which at MB-scale pending states costs
        # more CPU than the entire rest of the checkpoint
        manifest = dict(manifest, n_leaves=len(leaves))
        for i, leaf in enumerate(leaves):
            np.save(os.path.join(tmp, f"a{i}.npy"), np.asarray(leaf))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)               # atomic: never a torn checkpoint
        write_s = time.perf_counter() - t0
        self.write_s.append(write_s)
        self.n_checkpoints += 1
        self._write_record(
            {"type": "checkpoint", "k": manifest["k"],
             "kind": manifest["kind"], "dir": os.path.basename(final),
             "digest": manifest["digest"], "write_s": write_s,
             "t": time.time()}, fsync=True)
        self._rotate()

    def _rotate(self) -> None:
        dirs = sorted(d for d in os.listdir(self.path)
                      if d.startswith("ck_") and d != "ck_final"
                      and not d.endswith(".tmp"))
        for old in dirs[:-max(self.policy.keep, 1)]:
            shutil.rmtree(os.path.join(self.path, old))


# ------------------------------------------------------------- reader side

@dataclasses.dataclass
class JournalState:
    """Everything a resume needs, parsed from one journal directory."""
    path: str
    header: Optional[dict] = None
    chunks: Dict[int, dict] = dataclasses.field(default_factory=dict)
    scales: List[dict] = dataclasses.field(default_factory=list)
    checkpoints: List[dict] = dataclasses.field(default_factory=list)
    complete: Optional[dict] = None
    failed: Optional[dict] = None
    records: List[dict] = dataclasses.field(default_factory=list)

    @property
    def last_snapshot(self) -> Optional[dict]:
        """The most recent partition-table snapshot (scale records carry
        one), falling back to the header's starting topology."""
        for rec in reversed(self.scales):
            if "owner" in rec:
                return rec
        if self.header and "owner" in self.header:
            return self.header
        return None

    def usable_checkpoint(self, *, final: bool = False) -> Optional[dict]:
        """Latest checkpoint record whose directory still exists on disk
        (rotation may have dropped older ones).  ``final=True`` looks only
        at the completed stream's final-output checkpoint."""
        for rec in reversed(self.checkpoints):
            if (rec.get("kind") == "final") != final:
                continue
            if os.path.isdir(os.path.join(self.path, rec["dir"])):
                return rec
        return None


def load_journal(path: str) -> JournalState:
    """Parse a journal directory.  Torn tail lines (the coordinator died
    mid-append) are ignored; every complete record is kept in order."""
    path = journal_dir(path)
    state = JournalState(path=path)
    journal_file = os.path.join(path, "journal.jsonl")
    if not os.path.exists(journal_file):
        return state
    with open(journal_file, encoding="utf-8") as f:
        raw = f.read()
    for line in raw.splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue                        # torn tail line: crashed mid-write
        state.records.append(rec)
        kind = rec.get("type")
        if kind == "header":
            state.header = rec
        elif kind == "chunk":
            state.chunks[int(rec["chunk"])] = rec
        elif kind == "scale":
            state.scales.append(rec)
        elif kind == "checkpoint":
            state.checkpoints.append(rec)
        elif kind == "complete":
            state.complete = rec
        elif kind == "job_failed":
            state.failed = rec
    return state


def load_checkpoint(path: str, record: dict):
    """Load + integrity-check one checkpoint directory.  Returns the decoded
    state tree; raises ``ResumeMismatchError`` if the stored digest does not
    match the journaled record or the re-computed digest of the loaded
    bytes (corruption must be loud, never silently divergent)."""
    d = os.path.join(path, record["dir"])
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("digest") != record.get("digest"):
        raise ResumeMismatchError(
            f"checkpoint {record['dir']}: manifest digest does not match the "
            "journal record — the directory does not belong to this journal")
    leaves = [np.load(os.path.join(d, f"a{i}.npy"))
              for i in range(int(manifest["n_leaves"]))]
    state = tree_decode(manifest["spec"], leaves)
    if tree_digest(state) != manifest["digest"]:
        raise ResumeMismatchError(
            f"checkpoint {record['dir']}: stored arrays do not reproduce "
            "the manifest digest — corrupted checkpoint")
    return state, manifest
