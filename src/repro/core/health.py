"""HealthMonitor — §4.3.1, TPU-runtime-adapted.

The thesis monitors process/system CPU load via OperatingSystemMXBean and
notifies the scaler on threshold crossings.  The training-runtime analogues we
monitor per step: wall-clock step time, throughput (tokens/s), a *load*
metric (observed step time / target step time — directly comparable to the
paper's process CPU load in [0,1+]), gradient-norm spikes, NaN/Inf (the
"member crash" signal), and straggler skew across members.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class HealthConfig:
    max_threshold: float = 0.80       # scale OUT above (paper: maxThreshold)
    min_threshold: float = 0.20       # scale IN  below (paper: minThreshold)
    time_between_health_checks: int = 1    # steps between checks
    time_between_scaling: int = 10          # hysteresis buffer (anti-jitter)
    max_instances: int = 64                 # maxInstancesToBeSpawned
    min_instances: int = 1
    window: int = 8                         # smoothing window
    target_step_time: float = 1.0           # defines load = step_time/target
    ema_alpha: float = 0.4                  # async dispatch step-time EMA
    nan_is_fatal: bool = True
    # scaling-signal policy for auto_scale dispatchers:
    #   "ema"  (default, bit-compat) — wall-time EMA over the job target
    #   "mmn"  — queue-aware: measured per-member service rate + demand
    #            arrival rate + queue backlog through the M/M/n load signal
    #            (repro.core.stats.mmn_load); forces stats collection
    policy: str = "ema"
    mmn_queue_cap: float = 4.0        # waiting chunks/member ≙ full load
    stats_warmup: int = 1             # head samples trimmed from stat windows
    stats_cooldown: int = 0           # tail samples trimmed from stat windows
    # SLO shedding knee for serve-layer callers (TenantFrontEnd): when the
    # measured mmn utilization exceeds this AND the cluster is already at
    # max_instances, lowest-priority tenants are shed first (structured,
    # journaled rejections — see docs/serving.md); 1.0 disables shedding
    shed_utilization: float = 0.9


@dataclasses.dataclass
class HealthSample:
    step: int
    step_time: float
    tokens_per_s: float = 0.0
    grad_norm: float = 0.0
    loss: float = 0.0
    member_times: Optional[List[float]] = None  # per-member (straggler skew)
    # compile/remesh-spanning samples: their wall is trace/rebuild noise, so
    # load() and straggler_skew() exclude them (mirrors the EMA reset logic
    # in ElasticDispatcher.submit); non-finite detection still applies
    tainted: bool = False


class HealthMonitor:
    def __init__(self, cfg: HealthConfig):
        self.cfg = cfg
        self.samples: Deque[HealthSample] = deque(maxlen=256)
        self.events: List[str] = []

    # ------------------------------------------------------------- observe
    def observe(self, sample: HealthSample) -> None:
        self.samples.append(sample)
        if not math.isfinite(sample.loss) or not math.isfinite(sample.grad_norm):
            self.events.append(f"step {sample.step}: NON-FINITE "
                               f"(loss={sample.loss}, gnorm={sample.grad_norm})")

    def observe_chunk(self, step: int, wall_s: float, finite: bool = True,
                      member_times: Optional[List[float]] = None,
                      tainted: bool = False) -> HealthSample:
        """Dispatcher-side detector feed: one validated chunk becomes one
        sample.  A non-finite chunk output is recorded as ``loss=NaN`` —
        this module's documented "member crash" signal — so ``is_healthy()``
        flips and ``events`` logs the step; per-member launch walls feed
        ``straggler_skew`` (the stall/hang signal).  ``tainted=True`` tags
        compile/remesh-spanning chunks: their wall (often 10-100x steady
        state) is kept out of the load window and out of straggler-skew
        detection — a compile chunk's skew is trace noise, not a hung
        member — while non-finite detection still applies."""
        sample = HealthSample(step=step, step_time=wall_s,
                              loss=(0.0 if finite else float("nan")),
                              member_times=member_times, tainted=tainted)
        self.observe(sample)
        return sample

    # --------------------------------------------------------------- views
    def load(self) -> float:
        """Smoothed load in [0, inf): step_time / target (≈ process CPU
        load).  Tainted (compile/remesh) samples are excluded — they would
        ratchet the scaler toward max_instances on trace noise."""
        clean = [s.step_time for s in self.samples if not s.tainted]
        w = clean[-self.cfg.window:]
        if not w:
            return 0.0
        return (sum(w) / len(w)) / self.cfg.target_step_time

    def straggler_skew(self) -> float:
        """max/median member time of the newest UNTAINTED sample carrying
        per-member times (straggler signal); 1.0 when none exists."""
        for s in reversed(self.samples):
            if s.tainted or not s.member_times:
                continue
            ts = sorted(s.member_times)
            med = ts[len(ts) // 2]
            return (ts[-1] / med) if med > 0 else 1.0
        return 1.0

    def is_healthy(self) -> bool:
        if not self.samples:
            return True
        s = self.samples[-1]
        return math.isfinite(s.loss) and math.isfinite(s.grad_norm)

    def summary(self) -> Dict[str, float]:
        return {"load": self.load(), "skew": self.straggler_skew(),
                "n_samples": float(len(self.samples)),
                "healthy": float(self.is_healthy())}
