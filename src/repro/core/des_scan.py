"""Closed-form DES core — sort + segmented scan replaces the event loop.

The wave-loop reference (``cloudsim.simulate_completion``) replays the
CloudSim event loop: one ``lax.while_loop`` iteration per completion wave,
each wave a dense (C,V) one-hot matmul — O(waves × C × V) and inherently
master-only ("tightly coupled core fragments are not distributed", §4).

Time-shared scheduling has a closed form that collapses the loop.  On a VM
with MIPS μ running the cloudlets sorted ascending by length m_1 ≤ … ≤ m_k,
the shortest finishes first and every completion frees capacity for the
rest, so

    finish_j = finish_{j-1} + (m_j − m_{j-1}) · (k − j + 1) / μ

— a per-VM prefix sum.  Globally: sort cloudlets by (vm, length), take
first differences within each VM segment, weight by the number of still-
active sharers, and run ONE segmented prefix scan.  O(C log C) total, no
while_loop, no (C,V) one-hot, trivially vmappable (batched sweeps) and
partitionable by VM ownership (distributed phase 4).

Three execution paths:
  * ``simulate_completion_scan``        — pure-jnp sort + segmented cumsum
  * ``use_kernel=True``                 — the Pallas chunked segmented-scan
                                          kernel (``kernels/seg_scan``),
                                          interpret-mode fallback off-TPU
  * ``simulate_completion_distributed`` — per-VM segments partitioned over
                                          mesh members via
                                          ``DistributedExecutor.execute_on_key_owners``
plus ``run_simulation_batch`` — one jit over ≥32 stacked scenario variants.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

_EPS = 1e-6   # same "still running" threshold as the wave-loop reference


def _segmented_cumsum(term, start):
    """Segmented inclusive prefix sum via ``lax.associative_scan`` with the
    classic segmented operator — sums never cross a ``start`` flag.  Unlike
    global-cumsum-plus-rebase, rounding error stays proportional to the
    per-SEGMENT magnitudes (rebase cancels against the global running total,
    which at 100k cloudlets × hundreds of VMs costs ~1e-2 absolute)."""
    def combine(a, b):
        a_flag, a_sum = a
        b_flag, b_sum = b
        return a_flag | b_flag, b_sum + jnp.where(b_flag, 0.0, a_sum)

    _, sums = jax.lax.associative_scan(combine, (start, term))
    return sums


# ------------------------------------------------------------- the scan core

def simulate_completion_scan(vm_assign, cloudlet_mi, vm_mips, valid, *,
                             use_kernel: bool = False,
                             interpret: Optional[bool] = None):
    """Closed-form time-shared completion: sort by (vm, mi) + segmented scan.

    Numerically equivalent to ``cloudsim.simulate_completion`` (atol 1e-3):
    returns (finish_times (C,), makespan).  Cloudlets that never run —
    invalid padding rows, zero-length cloudlets, cloudlets bound to
    zero-MIPS (padded) VMs — keep finish time 0, exactly like the wave loop.
    """
    C = cloudlet_mi.shape[0]
    V = vm_mips.shape[0]
    mi = jnp.where(valid, cloudlet_mi, 0.0).astype(jnp.float32)
    mips = vm_mips.astype(jnp.float32)

    # segment id = owning VM; everything that never runs goes to sentinel V
    runnable = valid & (mi > _EPS) & (mips[vm_assign] > 0.0)
    seg = jnp.where(runnable, vm_assign, V).astype(jnp.int32)

    # lexicographic sort: primary by segment, secondary by length ascending
    order = jnp.lexsort((mi, seg))
    seg_s = seg[order]
    mi_s = mi[order]

    idx = jnp.arange(C, dtype=jnp.int32)
    prev_seg = jnp.concatenate([jnp.full((1,), -1, jnp.int32), seg_s[:-1]])
    start = seg_s != prev_seg                       # segment boundaries
    seg_start = jax.lax.cummax(jnp.where(start, idx, 0))
    pos = (idx - seg_start).astype(jnp.float32)     # j-1 within the segment

    # sharers count k per segment, gathered back per element
    counts = jax.ops.segment_sum(jnp.ones((C,), jnp.float32), seg_s,
                                 num_segments=V + 1)
    k = counts[seg_s]

    prev_mi = jnp.concatenate([jnp.zeros((1,), jnp.float32), mi_s[:-1]])
    delta = jnp.where(start, mi_s, mi_s - prev_mi)  # m_j − m_{j-1}
    seg_mips = jnp.concatenate([mips, jnp.zeros((1,), jnp.float32)])[seg_s]
    inv_mips = jnp.where(seg_mips > 0.0,
                         1.0 / jnp.maximum(seg_mips, 1e-30), 0.0)
    term = delta * (k - pos) * inv_mips             # (m_j−m_{j-1})(k−j+1)/μ

    if use_kernel:
        from repro.kernels.seg_scan.kernel import seg_cumsum
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        f_s = seg_cumsum(term, start.astype(jnp.float32),
                         interpret=interpret)
    else:
        f_s = _segmented_cumsum(term, start)

    f_s = jnp.where(seg_s == V, 0.0, f_s)           # sentinel never finishes
    finish = jnp.zeros((C,), jnp.float32).at[order].set(f_s)
    makespan = jnp.max(f_s, initial=0.0)
    return finish, makespan


# jitted entry point with the flags static, shared so repeated calls (e.g.
# run_simulation) hit the compile cache instead of re-wrapping in jax.jit
simulate_completion_scan_jit = jax.jit(
    simulate_completion_scan, static_argnames=("use_kernel", "interpret"))


# ------------------------------------------------- distributed phase 4

@functools.lru_cache(maxsize=32)
def _dist_core(mesh, axis, V):
    """Compiled distributed phase-4 core for one (mesh, VM-count); cached so
    every simulation on the same mesh reuses the executable."""
    from repro.core.executor import DistributedExecutor

    executor = DistributedExecutor(mesh, axis)
    n = executor.n_members
    shard = -(-V // n)                               # ceil(V / n) ranges
    members = jnp.arange(n, dtype=jnp.int32)

    def member_fn(mid, assign, mi, mips, val):
        lo = mid[0] * shard
        hi = jnp.minimum(lo + shard, V)
        mine = (assign >= lo) & (assign < hi)
        f, _ = simulate_completion_scan(assign, mi, mips, val & mine)
        return f[None, :]                            # (1, C) partial

    def call(vm_assign, cloudlet_mi, vm_mips, valid):
        parts = executor.execute_on_key_owners(
            member_fn, members,
            replicated_args=(vm_assign, cloudlet_mi, vm_mips, valid),
            out_specs=P(axis, None))
        finish = parts.sum(axis=0)
        return finish, jnp.max(finish, initial=0.0)

    return jax.jit(call)


def simulate_completion_distributed(vm_assign, cloudlet_mi, vm_mips, valid,
                                    executor):
    """Phase 4, distributed for the first time: per-VM completion segments
    are independent, so VM ownership is partitioned over mesh members
    (ceil-ranges, the PartitionUtil convention) and each member scans only
    the cloudlets bound to its VMs via ``execute_on_key_owners``.  The
    per-member partials are disjoint; their sum is the full finish vector —
    bit-identical for any member count (the thesis's accuracy claim)."""
    fn = _dist_core(executor.mesh, executor.axis, vm_mips.shape[0])
    return fn(vm_assign, cloudlet_mi, vm_mips, valid)


# ------------------------------------------------- batched scenario sweeps

@dataclasses.dataclass
class BatchSimulationResult:
    """One jit, B scenario variants (stacked seeds × length scales)."""
    vm_assign: np.ndarray        # (B, C)
    finish_times: np.ndarray     # (B, C)
    makespans: np.ndarray        # (B,)
    timings: Dict[str, float]

    @property
    def n_scenarios(self) -> int:
        return int(self.makespans.shape[0])

    def summary(self) -> Dict[str, float]:
        return {"n_scenarios": self.n_scenarios,
                "mean_makespan": float(self.makespans.mean()),
                "min_makespan": float(self.makespans.min()),
                "max_makespan": float(self.makespans.max()),
                **{f"t_{k}": v for k, v in self.timings.items()}}


def _scenario(cfg, seed, mi_scale):
    """One full scenario — entities + broker + scan core — pure-functionally
    (no DataGrid side effects), so the whole pipeline vmaps."""
    from repro.core.cloudsim import matchmaking_assign, round_robin_assign

    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    lo, hi = cfg.vm_mips_range
    vm_mips = jax.random.uniform(k1, (cfg.n_vms,), minval=lo, maxval=hi)
    lo, hi = cfg.cloudlet_mi_range
    mi = jax.random.uniform(k2, (cfg.n_cloudlets,), minval=lo,
                            maxval=hi) * mi_scale
    valid = jnp.ones((cfg.n_cloudlets,), bool)
    ids = jnp.arange(cfg.n_cloudlets, dtype=jnp.int32)

    if cfg.broker == "round_robin":
        assign = round_robin_assign(ids, cfg.n_vms)
    else:
        assign = matchmaking_assign(ids, mi, vm_mips, cfg.n_vms)
    finish, makespan = simulate_completion_scan(assign, mi, vm_mips, valid,
                                                use_kernel=cfg.use_kernel)
    return assign, finish, makespan


@functools.lru_cache(maxsize=32)
def _batch_fn(cfg):
    """Jitted vmap of the scenario pipeline, cached per (hashable, frozen)
    config so repeated sweeps with the same cfg and batch shape reuse the
    compiled executable."""
    return jax.jit(jax.vmap(functools.partial(_scenario, cfg)))


def run_simulation_batch(cfg, seeds, *, mi_scale=None) -> BatchSimulationResult:
    """Execute a stack of scenario variants in a SINGLE jitted vmap.

    seeds: (B,) int array — one PRNG stream per scenario.
    mi_scale: optional (B,) multiplier on cloudlet lengths (workload sweep).
    The closed-form core has no data-dependent loop, so B scenarios cost one
    XLA dispatch; ≥32 variants per jit is the intended operating point.
    ``cfg.use_kernel`` is honored; only the vmappable ``core="scan"`` is
    supported (the wave loop and the shard_map path don't batch).
    """
    if cfg.core != "scan":
        raise ValueError(
            f"run_simulation_batch only supports core='scan', got {cfg.core!r}")
    seeds = jnp.asarray(seeds, jnp.int32)
    B = seeds.shape[0]
    scale = (jnp.ones((B,), jnp.float32) if mi_scale is None
             else jnp.asarray(mi_scale, jnp.float32))

    fn = _batch_fn(cfg)
    t0 = time.perf_counter()
    assign, finish, makespans = fn(seeds, scale)
    jax.block_until_ready(makespans)
    wall = time.perf_counter() - t0
    return BatchSimulationResult(
        vm_assign=np.asarray(assign), finish_times=np.asarray(finish),
        makespans=np.asarray(makespans),
        timings={"batch_total": wall, "per_scenario": wall / max(B, 1)})
