"""Closed-form DES core — sort + segmented scan replaces the event loop.

The wave-loop reference (``cloudsim.simulate_completion``) replays the
CloudSim event loop: one ``lax.while_loop`` iteration per completion wave,
each wave a dense (C,V) one-hot matmul — O(waves × C × V) and inherently
master-only ("tightly coupled core fragments are not distributed", §4).

Time-shared scheduling has a closed form that collapses the loop.  On a VM
with MIPS μ running the cloudlets sorted ascending by length m_1 ≤ … ≤ m_k,
the shortest finishes first and every completion frees capacity for the
rest, so

    finish_j = finish_{j-1} + (m_j − m_{j-1}) · (k − j + 1) / μ

— a per-VM prefix sum.  Globally: sort cloudlets by (vm, length), take
first differences within each VM segment, weight by the number of still-
active sharers, and run ONE segmented prefix scan.  O(C log C) total, no
while_loop, no (C,V) one-hot, trivially vmappable (batched sweeps) and
partitionable by VM ownership (distributed phase 4).

Execution paths:
  * ``simulate_completion_scan``        — pure-jnp sort + segmented cumsum
  * ``use_kernel=True``                 — the Pallas chunked segmented-scan
                                          kernel (``kernels/seg_scan``),
                                          interpret-mode fallback off-TPU
  * ``simulate_completion_distributed`` — per-VM result segments owned by
                                          mesh members via a *runtime*
                                          ``PartitionTable``-backed VM→member
                                          map (elastic: rebalancing the table
                                          never recompiles; a scale event
                                          only retires the old mesh's
                                          executable via
                                          ``invalidate_dist_core``)
  * ``run_simulation_batch``            — one jit over a multi-axis scenario
                                          GRID (seeds × mi_scale × broker ×
                                          VM-count × MIPS-distribution),
                                          heterogeneous shapes padded so all
                                          variants stack; optionally sharded
                                          across mesh members (vmap of the
                                          scenario fn inside the partitioned
                                          member_fn).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

_EPS = 1e-6   # same "still running" threshold as the wave-loop reference


def _segmented_cumsum(term, start):
    """Segmented inclusive prefix sum via ``lax.associative_scan`` with the
    classic segmented operator — sums never cross a ``start`` flag.  Unlike
    global-cumsum-plus-rebase, rounding error stays proportional to the
    per-SEGMENT magnitudes (rebase cancels against the global running total,
    which at 100k cloudlets × hundreds of VMs costs ~1e-2 absolute)."""
    def combine(a, b):
        a_flag, a_sum = a
        b_flag, b_sum = b
        return a_flag | b_flag, b_sum + jnp.where(b_flag, 0.0, a_sum)

    _, sums = jax.lax.associative_scan(combine, (start, term))
    return sums


# ------------------------------------------------------------- the scan core

def simulate_completion_scan(vm_assign, cloudlet_mi, vm_mips, valid, *,
                             use_kernel: bool = False,
                             interpret: Optional[bool] = None):
    """Closed-form time-shared completion: sort by (vm, mi) + segmented scan.

    Numerically equivalent to ``cloudsim.simulate_completion`` (atol 1e-3):
    returns (finish_times (C,), makespan).  Cloudlets that never run —
    invalid padding rows, zero-length cloudlets, cloudlets bound to
    zero-MIPS (padded) VMs — keep finish time 0, exactly like the wave loop.
    """
    C = cloudlet_mi.shape[0]
    V = vm_mips.shape[0]
    mi = jnp.where(valid, cloudlet_mi, 0.0).astype(jnp.float32)
    mips = vm_mips.astype(jnp.float32)

    # segment id = owning VM; everything that never runs goes to sentinel V
    runnable = valid & (mi > _EPS) & (mips[vm_assign] > 0.0)
    seg = jnp.where(runnable, vm_assign, V).astype(jnp.int32)

    # lexicographic sort: primary by segment, secondary by length ascending
    order = jnp.lexsort((mi, seg))
    seg_s = seg[order]
    mi_s = mi[order]

    idx = jnp.arange(C, dtype=jnp.int32)
    prev_seg = jnp.concatenate([jnp.full((1,), -1, jnp.int32), seg_s[:-1]])
    start = seg_s != prev_seg                       # segment boundaries
    seg_start = jax.lax.cummax(jnp.where(start, idx, 0))
    pos = (idx - seg_start).astype(jnp.float32)     # j-1 within the segment

    # sharers count k per segment, gathered back per element
    counts = jax.ops.segment_sum(jnp.ones((C,), jnp.float32), seg_s,
                                 num_segments=V + 1)
    k = counts[seg_s]

    prev_mi = jnp.concatenate([jnp.zeros((1,), jnp.float32), mi_s[:-1]])
    delta = jnp.where(start, mi_s, mi_s - prev_mi)  # m_j − m_{j-1}
    seg_mips = jnp.concatenate([mips, jnp.zeros((1,), jnp.float32)])[seg_s]
    inv_mips = jnp.where(seg_mips > 0.0,
                         1.0 / jnp.maximum(seg_mips, 1e-30), 0.0)
    term = delta * (k - pos) * inv_mips             # (m_j−m_{j-1})(k−j+1)/μ

    if use_kernel:
        from repro.kernels.seg_scan.kernel import seg_cumsum
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        f_s = seg_cumsum(term, start.astype(jnp.float32),
                         interpret=interpret)
    else:
        f_s = _segmented_cumsum(term, start)

    f_s = jnp.where(seg_s == V, 0.0, f_s)           # sentinel never finishes
    finish = jnp.zeros((C,), jnp.float32).at[order].set(f_s)
    makespan = jnp.max(f_s, initial=0.0)
    return finish, makespan


# jitted entry point with the flags static, shared so repeated calls (e.g.
# run_simulation) hit the compile cache instead of re-wrapping in jax.jit
simulate_completion_scan_jit = jax.jit(
    simulate_completion_scan, static_argnames=("use_kernel", "interpret"))


# ------------------------------------------------- distributed phase 4

def default_vm_owner(n_vms: int, n_members: int) -> jnp.ndarray:
    """VM→member map from a freshly-balanced ``PartitionTable`` — the
    ownership an elastic cluster starts from before any scale event."""
    from repro.core.partition import PartitionTable
    table = PartitionTable(n_instances=n_members)
    return jnp.asarray(table.owners_of_range(n_vms))


# Compiled distributed cores, keyed on (mesh, axis, V).  A plain dict (not
# lru_cache) so a scale event can retire exactly the executables built for
# the mesh it replaces while every other member count's core stays warm;
# FIFO-bounded so non-elastic sweeps over many (mesh, V) combinations don't
# accumulate executables forever.
_DIST_CORE_CACHE: Dict[tuple, object] = {}
_DIST_CORE_CACHE_MAX = 32


def invalidate_dist_core(mesh=None, axis: Optional[str] = None) -> int:
    """Drop compiled distributed cores.  With a mesh (and optionally an
    axis), only that mesh's executables are invalidated — the elastic
    controller calls this on SCALE_OUT/IN so the retired member count's
    cores are freed but all other cached cores survive the event.  With no
    arguments, clears everything.  Returns the number of entries dropped."""
    keys = [k for k in _DIST_CORE_CACHE
            if (mesh is None or k[0] == mesh) and (axis is None or k[1] == axis)]
    for k in keys:
        del _DIST_CORE_CACHE[k]
    return len(keys)


def _dist_core(mesh, axis, V):
    """Compiled distributed phase-4 core for one (mesh, VM-count).  The
    VM→member ownership map is a RUNTIME operand, so rebalancing the
    partition table re-homes VMs without touching the executable."""
    key = (mesh, axis, V)
    cached = _DIST_CORE_CACHE.get(key)
    if cached is not None:
        return cached

    from repro.core.executor import DistributedExecutor

    executor = DistributedExecutor(mesh, axis)
    members = jnp.arange(executor.n_members, dtype=jnp.int32)

    def member_fn(mid, owner, assign, mi, mips, val):
        # Every member runs the IDENTICAL full scan (the O(C log C) sort is
        # replicated anyway — see ROADMAP's distributed-sample-sort item) and
        # keeps only the finish entries of the VMs it owns.  Masking the
        # *output* rather than the validity keeps each element's value
        # bit-identical to the single-member scan for ANY ownership map and
        # member count: the partials are disjoint, and x + 0.0 == x exactly.
        f, _ = simulate_completion_scan(assign, mi, mips, val)
        mine = owner[assign] == mid[0]
        return jnp.where(mine, f, 0.0)[None, :]     # (1, C) partial

    def call(vm_owner, vm_assign, cloudlet_mi, vm_mips, valid):
        parts = executor.execute_on_key_owners(
            member_fn, members,
            replicated_args=(vm_owner, vm_assign, cloudlet_mi, vm_mips,
                             valid),
            out_specs=P(axis, None))
        finish = parts.sum(axis=0)
        return finish, jnp.max(finish, initial=0.0)

    fn = jax.jit(call)
    while len(_DIST_CORE_CACHE) >= _DIST_CORE_CACHE_MAX:
        del _DIST_CORE_CACHE[next(iter(_DIST_CORE_CACHE))]
    _DIST_CORE_CACHE[key] = fn
    return fn


def simulate_completion_distributed(vm_assign, cloudlet_mi, vm_mips, valid,
                                    executor, vm_owner=None):
    """Phase 4 distributed: per-VM completion segments are independent, so
    each member owns the finish entries of its VMs — ownership given by a
    ``PartitionTable``-backed VM→member map (``vm_owner``, a (V,) int32
    runtime array; defaults to a freshly-balanced table).  The per-member
    partials are disjoint and their sum is the full finish vector —
    BIT-identical to ``simulate_completion_scan`` for any member count and
    any ownership map (the thesis's accuracy claim), so an IAS scale event
    mid-run cannot perturb results."""
    V = vm_mips.shape[0]
    if vm_owner is None:
        vm_owner = default_vm_owner(V, executor.n_members)
    fn = _dist_core(executor.mesh, executor.axis, V)
    return fn(jnp.asarray(vm_owner, jnp.int32), vm_assign, cloudlet_mi,
              vm_mips, valid)


# ------------------------------------------------- batched scenario sweeps

BROKER_IDS = {"round_robin": 0, "matchmaking": 1}
MIPS_DIST_IDS = {"uniform": 0, "fixed": 1, "bimodal": 2}


@dataclasses.dataclass
class BatchSimulationResult:
    """One jit, B scenario variants (a multi-axis grid)."""
    vm_assign: np.ndarray        # (B, C)
    finish_times: np.ndarray     # (B, C)
    makespans: np.ndarray        # (B,)
    timings: Dict[str, float]
    broker: Optional[np.ndarray] = None      # (B,) broker id per variant
    n_vms: Optional[np.ndarray] = None       # (B,) live VMs per variant
    n_cloudlets: Optional[np.ndarray] = None  # (B,) live cloudlets per variant
    mips_dist: Optional[np.ndarray] = None   # (B,) MIPS-distribution id

    @property
    def n_scenarios(self) -> int:
        return int(self.makespans.shape[0])

    def summary(self) -> Dict[str, float]:
        return {"n_scenarios": self.n_scenarios,
                "mean_makespan": float(self.makespans.mean()),
                "min_makespan": float(self.makespans.min()),
                "max_makespan": float(self.makespans.max()),
                **{f"t_{k}": v for k, v in self.timings.items()}}


def grid_scenario_inputs(cfg, seed, mi_scale, n_vms, n_cloudlets, mips_dist):
    """Entities for ONE grid variant at the padded (cfg.n_vms, cfg.n_cloudlets)
    shape — pure and vmappable.  Shape padding: VMs beyond ``n_vms`` get
    0 MIPS and cloudlets beyond ``n_cloudlets`` get ``valid=False``, so
    heterogeneous variants stack into one batch and padded rows keep finish
    time exactly 0 (the scan core's sentinel-segment invariant).

    ``mips_dist`` selects the VM-capacity distribution family: 0 = uniform
    over ``vm_mips_range``, 1 = fixed at the range midpoint, 2 = bimodal
    (each VM at the low or high end, fair coin).
    """
    V, C = cfg.n_vms, cfg.n_cloudlets
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    lo, hi = cfg.vm_mips_range
    mips_u = jax.random.uniform(k1, (V,), minval=lo, maxval=hi)
    mips_f = jnp.full((V,), (lo + hi) / 2.0, jnp.float32)
    mips_b = jnp.where(jax.random.bernoulli(k3, 0.5, (V,)), hi, lo)
    vm_mips = jnp.select([mips_dist == 0, mips_dist == 1],
                         [mips_u, mips_f], mips_b)
    vm_valid = jnp.arange(V) < n_vms
    vm_mips = jnp.where(vm_valid, vm_mips, 0.0)

    lo, hi = cfg.cloudlet_mi_range
    mi = jax.random.uniform(k2, (C,), minval=lo, maxval=hi) * mi_scale
    valid = jnp.arange(C) < n_cloudlets
    mi = jnp.where(valid, mi, 0.0)
    return vm_mips, vm_valid, mi, valid


def _grid_scenario(cfg, seed, mi_scale, broker, n_vms, n_cloudlets,
                   mips_dist):
    """One full scenario — entities + broker + scan core — pure-functionally
    (no DataGrid side effects) with every grid axis a traced scalar, so the
    whole pipeline vmaps over a heterogeneous variant stack."""
    from repro.core.cloudsim import matchmaking_assign_masked

    vm_mips, vm_valid, mi, valid = grid_scenario_inputs(
        cfg, seed, mi_scale, n_vms, n_cloudlets, mips_dist)
    ids = jnp.arange(cfg.n_cloudlets, dtype=jnp.int32)
    rr = (ids % n_vms).astype(jnp.int32)
    mm = matchmaking_assign_masked(ids, mi, vm_mips, vm_valid)
    assign = jnp.where(broker == BROKER_IDS["round_robin"], rr, mm)
    finish, makespan = simulate_completion_scan(assign, mi, vm_mips, valid,
                                                use_kernel=cfg.use_kernel)
    return assign, finish, makespan


@functools.lru_cache(maxsize=32)
def _batch_fn(cfg):
    """Jitted vmap of the grid-scenario pipeline, cached per (hashable,
    frozen) config so repeated sweeps with the same cfg and batch shape
    reuse the compiled executable."""
    return jax.jit(jax.vmap(functools.partial(_grid_scenario, cfg)))


@functools.lru_cache(maxsize=32)
def _batch_dist_fn(cfg, mesh, axis):
    """Batch-sharded grid: the scenario vmap INSIDE the partitioned
    member_fn, so a grid of B variants shards B/n-per-member across the
    mesh — CloudSim-scale scenario throughput from data-parallel members."""
    from repro.core.executor import DistributedExecutor

    executor = DistributedExecutor(mesh, axis)

    def member_fn(local):
        return jax.vmap(functools.partial(_grid_scenario, cfg))(*local)

    def call(seeds, scale, broker, n_vms, n_cl, mips_dist):
        return executor.execute_on_key_owners(
            member_fn, (seeds, scale, broker, n_vms, n_cl, mips_dist),
            out_specs=P(axis))

    return jax.jit(call)


def _axis_array(value, B, dtype, name, id_map=None):
    """Normalize one grid axis to a (B,) array: scalars broadcast, str
    entries map through ``id_map`` (broker / MIPS-distribution names)."""
    if value is None:
        return None
    if isinstance(value, str) or np.isscalar(value):
        value = [value] * B
    vals = value if hasattr(value, "dtype") else np.asarray(value)
    if getattr(vals.dtype, "kind", "") in "USO":   # names -> ids
        vals = np.asarray([id_map[str(v)] for v in np.asarray(vals).ravel()])
    arr = jnp.asarray(vals, dtype)
    if arr.shape != (B,):
        raise ValueError(f"{name} must have shape ({B},), got {arr.shape}")
    return arr


def run_simulation_batch(cfg, seeds, *, mi_scale=None, broker=None,
                         n_vms=None, n_cloudlets=None, mips_dist=None,
                         executor=None) -> BatchSimulationResult:
    """Execute a multi-axis scenario GRID in a SINGLE jitted vmap.

    seeds: (B,) int array — one PRNG stream per scenario.  The optional grid
    axes are each a (B,) per-variant array (or a scalar applied to all):

      mi_scale    — float multiplier on cloudlet lengths (workload sweep)
      broker      — "round_robin" | "matchmaking" (names or BROKER_IDS ints)
      n_vms       — live VM count ≤ cfg.n_vms; the rest are 0-MIPS padding
      n_cloudlets — live cloudlet count ≤ cfg.n_cloudlets; rest valid=False
      mips_dist   — "uniform" | "fixed" | "bimodal" (or MIPS_DIST_IDS ints)

    The closed-form core has no data-dependent loop and every axis is a
    traced scalar, so B heterogeneous variants cost one XLA dispatch; ≥96
    variants per jit is the intended operating point.  With ``executor``
    (a multi-member mesh) the grid is sharded B/n-per-member: the scenario
    vmap runs inside the partitioned member_fn.  ``cfg.use_kernel`` is
    honored; only the vmappable ``core="scan"`` is supported (the wave loop
    doesn't batch).
    """
    if cfg.core != "scan":
        raise ValueError(
            f"run_simulation_batch only supports core='scan', got {cfg.core!r}")
    seeds = jnp.asarray(seeds, jnp.int32)
    B = seeds.shape[0]

    def default(arr, fill, dtype):
        return jnp.full((B,), fill, dtype) if arr is None else arr

    scale = default(_axis_array(mi_scale, B, jnp.float32, "mi_scale"),
                    1.0, jnp.float32)
    broker = default(_axis_array(broker, B, jnp.int32, "broker", BROKER_IDS),
                     BROKER_IDS[cfg.broker], jnp.int32)
    n_vms = default(_axis_array(n_vms, B, jnp.int32, "n_vms"),
                    cfg.n_vms, jnp.int32)
    n_cl = default(_axis_array(n_cloudlets, B, jnp.int32, "n_cloudlets"),
                   cfg.n_cloudlets, jnp.int32)
    # live counts must fit the padded shapes — JAX's clamping gather would
    # otherwise turn an oversized variant into silently-wrong results
    for name, arr, cap in (("n_vms", n_vms, cfg.n_vms),
                           ("n_cloudlets", n_cl, cfg.n_cloudlets)):
        lo, hi = int(arr.min()), int(arr.max())
        if lo < 1 or hi > cap:
            raise ValueError(f"{name} axis must lie in [1, {cap}] "
                             f"(the padded cfg shape), got [{lo}, {hi}]")
    mips_dist = default(_axis_array(mips_dist, B, jnp.int32, "mips_dist",
                                    MIPS_DIST_IDS),
                        MIPS_DIST_IDS["uniform"], jnp.int32)
    args = (seeds, scale, broker, n_vms, n_cl, mips_dist)

    t0 = time.perf_counter()
    if executor is not None and executor.n_members > 1:
        n = executor.n_members
        pad = (-B) % n                   # round B up to a whole shard each
        if pad:
            args = tuple(jnp.concatenate([a, a[-1:].repeat(pad)])
                         for a in args)
        fn = _batch_dist_fn(cfg, executor.mesh, executor.axis)
        assign, finish, makespans = (o[:B] for o in fn(*args))
    else:
        assign, finish, makespans = _batch_fn(cfg)(*args)
    jax.block_until_ready(makespans)
    wall = time.perf_counter() - t0
    return BatchSimulationResult(
        vm_assign=np.asarray(assign), finish_times=np.asarray(finish),
        makespans=np.asarray(makespans),
        timings={"batch_total": wall, "per_scenario": wall / max(B, 1)},
        broker=np.asarray(broker), n_vms=np.asarray(n_vms),
        n_cloudlets=np.asarray(n_cl), mips_dist=np.asarray(mips_dist))


def make_scenario_grid(seeds: Sequence[int],
                       mi_scales: Sequence[float] = (1.0,),
                       brokers: Sequence[Union[str, int]] = ("round_robin",),
                       vm_counts: Sequence[int] = (0,),
                       cloudlet_counts: Sequence[int] = (0,),
                       mips_dists: Sequence[Union[str, int]] = ("uniform",),
                       ) -> Dict[str, np.ndarray]:
    """Cartesian product of grid axes → per-variant (B,) arrays, B = the
    product of axis lengths.  A 0 in ``vm_counts``/``cloudlet_counts`` means
    "the config's full count" — the sentinel is resolved against a config by
    ``run_scenario_grid(cfg, grid)``, the intended way to execute the
    product."""
    brokers = [BROKER_IDS[b] if isinstance(b, str) else int(b)
               for b in brokers]
    mips_dists = [MIPS_DIST_IDS[d] if isinstance(d, str) else int(d)
                  for d in mips_dists]
    axes = np.meshgrid(np.asarray(seeds, np.int32),
                       np.asarray(mi_scales, np.float32),
                       np.asarray(brokers, np.int32),
                       np.asarray(vm_counts, np.int32),
                       np.asarray(cloudlet_counts, np.int32),
                       np.asarray(mips_dists, np.int32), indexing="ij")
    flat = [a.ravel() for a in axes]
    return {"seeds": flat[0], "mi_scale": flat[1], "broker": flat[2],
            "n_vms": flat[3], "n_cloudlets": flat[4], "mips_dist": flat[5]}


def run_scenario_grid(cfg, grid: Dict[str, np.ndarray], *,
                      executor=None) -> BatchSimulationResult:
    """Run a ``make_scenario_grid`` product through ``run_simulation_batch``
    (0-valued VM/cloudlet counts resolve to the config's full counts)."""
    g = dict(grid)
    g["n_vms"] = np.where(np.asarray(g["n_vms"]) == 0, cfg.n_vms,
                          g["n_vms"]).astype(np.int32)
    g["n_cloudlets"] = np.where(np.asarray(g["n_cloudlets"]) == 0,
                                cfg.n_cloudlets,
                                g["n_cloudlets"]).astype(np.int32)
    seeds = g.pop("seeds")
    return run_simulation_batch(cfg, seeds, executor=executor, **g)
