"""Closed-form DES core — sort + segmented scan replaces the event loop.

The wave-loop reference (``cloudsim.simulate_completion``) replays the
CloudSim event loop: one ``lax.while_loop`` iteration per completion wave,
each wave a dense (C,V) one-hot matmul — O(waves × C × V) and inherently
master-only ("tightly coupled core fragments are not distributed", §4).

Time-shared scheduling has a closed form that collapses the loop.  On a VM
with MIPS μ running the cloudlets sorted ascending by length m_1 ≤ … ≤ m_k,
the shortest finishes first and every completion frees capacity for the
rest, so

    finish_j = finish_{j-1} + (m_j − m_{j-1}) · (k − j + 1) / μ

— a per-VM prefix sum.  Globally: sort cloudlets by (vm, length), take
first differences within each VM segment, weight by the number of still-
active sharers, and run ONE segmented prefix scan.  O(C log C) total, no
while_loop, no (C,V) one-hot, trivially vmappable (batched sweeps) and
partitionable by VM ownership (distributed phase 4).

Execution paths:
  * ``simulate_completion_scan``        — pure-jnp sort + segmented cumsum
  * ``use_kernel=True``                 — the v2 position-gated fused kernel
                                          (``kernels/seg_scan/v2``): one
                                          3-operand stable sort replaces
                                          lexsort + two gathers, the chunked
                                          Pallas scan reproduces the lax
                                          addition tree BIT-exactly, and the
                                          sentinel mask + result scatter are
                                          fused into the epilogue kernel.
                                          Off-TPU the kernel falls back to a
                                          bit-exact jnp emulation (one-time
                                          ``KernelInterpretFallbackWarning``);
                                          ``kernel_chunk=None`` resolves via
                                          the roofline autotuner
                                          (``roofline/autotune``).
  * ``simulate_completion_distributed`` — COMPUTE-partitioned phase 4: an
                                          owner-keyed exchange re-homes each
                                          cloudlet to the member owning its
                                          VM, and each member lexsorts+scans
                                          only its own ~C/M cloudlets
  * ``run_simulation_batch``            — one jit over a multi-axis scenario
                                          GRID (seeds × mi_scale × broker ×
                                          VM-count × MIPS-distribution),
                                          heterogeneous shapes padded so all
                                          variants stack; optionally sharded
                                          across mesh members (vmap of the
                                          scenario fn inside the partitioned
                                          member_fn).

The exchange protocol (``method="exchange"``, the default distributed core):

  1. Each member buckets its cloudlet shard (C/M contiguous rows) by
     ``vm_owner[vm_assign]`` — the ``PartitionTable`` map, a RUNTIME operand,
     so IAS rebalances re-home VMs without recompiling.
  2. One padded all-to-all ships each cloudlet's ``(orig, assign, mi, valid)``
     to the owner member.  Per-(src, dst) capacity is ``block`` entries
     (static, part of the compile-cache key): heuristically
     ``ceil(shard * slack / M)`` or, by default, the exact observed
     ``exchange_load(...).max()`` rounded up to a power of two.  Unused
     capacity is ``valid=False`` fill, which the scan maps to the sentinel
     segment — padding contributes exactly 0.0.  Capacity violations are
     counted on-device and raised as ``ExchangeCapacityError`` — loud, never
     silent truncation.
  3. The owner lexsorts + scans only its own cloudlets: per-member work drops
     from O(C log C), replicated M times, to O((C/M) log(C/M)) each.
  4. Finish partials are scattered back to global row positions and psum-med;
     partials are disjoint (each cloudlet has exactly one owner) and
     x + 0.0 == x, so the sum is exact.

Bit-identity argument (the thesis's accuracy claim, preserved from PR 2):
every per-element quantity in the scan depends only on the element's segment
(its VM's cloudlet multiset) and its in-segment position p — the sort key
(vm, mi), first differences, sharer counts (exact small-int f32 sums), and
the segmented prefix sum, which ``_segmented_cumsum`` computes with a
position-gated Hillis–Steele doubling scan whose addition tree is a function
of p ALONE (never of the element's global offset or the array length).  A
member's exchanged sub-array therefore reproduces the full array's finish
values bit-for-bit, for any member count, ownership map, slack, or mid-run
rebalance.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import dispatch
from repro.core.dispatch import CompileCache, DispatchJob

_EPS = 1e-6   # same "still running" threshold as the wave-loop reference


def _segmented_cumsum(term, start):
    """Segmented inclusive prefix sum, position-gated Hillis–Steele.

    log2(C) doubling steps; step ``d`` adds the value ``d`` slots back iff
    that slot is in the same segment (in-segment position ``p >= d``).  The
    value at p is therefore combined by a fixed tree determined by p ALONE:
    x_d(p) = x_{d-1}(p) + [p >= d] * x_{d-1}(p - d).  Unlike
    ``lax.associative_scan`` (whose combine tree follows GLOBAL offsets),
    this makes the result layout-invariant — a segment scanned inside an
    owner-keyed sub-array of any length reproduces the full array's values
    BIT-exactly, which is what lets the distributed exchange core stay
    bit-identical to the single-member scan.  Extra steps past a segment's
    length are gated no-ops, so differing array lengths don't perturb it.
    Rounding error stays proportional to per-SEGMENT magnitudes, as with the
    segmented-operator scan this replaces."""
    C = term.shape[0]
    idx = jnp.arange(C, dtype=jnp.int32)
    seg_start = jax.lax.cummax(jnp.where(start, idx, 0))   # exact int scan
    pos = idx - seg_start                                  # in-segment p
    x = term
    d = 1
    while d < C:
        shifted = jnp.concatenate([jnp.zeros((d,), x.dtype), x[:-d]])
        x = x + jnp.where(pos >= d, shifted, jnp.zeros((), x.dtype))
        d *= 2
    return x


# ------------------------------------------------------------- the scan core

def simulate_completion_scan(vm_assign, cloudlet_mi, vm_mips, valid, *,
                             use_kernel: bool = False,
                             interpret: Optional[bool] = None,
                             kernel_chunk: Optional[int] = None):
    """Closed-form time-shared completion: sort by (vm, mi) + segmented scan.

    Numerically equivalent to ``cloudsim.simulate_completion`` (atol 1e-3):
    returns (finish_times (C,), makespan).  Cloudlets that never run —
    invalid padding rows, zero-length cloudlets, cloudlets bound to
    zero-MIPS (padded) VMs — keep finish time 0, exactly like the wave loop.

    ``use_kernel=True`` runs the v2 fused kernel path, BIT-identical to the
    default path: one stable 3-operand ``lax.sort`` carries (seg, mi, row)
    together (same permutation as the lexsort, without the two post-sort
    gathers), ``seg_cumsum_v2`` reproduces ``_segmented_cumsum``'s exact
    position-gated addition tree, and the sentinel mask + scatter fuse into
    the epilogue.  ``kernel_chunk`` (power of two, static) picks the
    in-kernel level split; ``None`` asks the roofline autotuner for the
    persisted/analytic choice.  ``interpret=None`` resolves to the backend
    default — compiled on TPU, bit-exact jnp emulation elsewhere (a
    one-time ``KernelInterpretFallbackWarning`` flags the fallback)."""
    C = cloudlet_mi.shape[0]
    V = vm_mips.shape[0]
    mi = jnp.where(valid, cloudlet_mi, 0.0).astype(jnp.float32)
    mips = vm_mips.astype(jnp.float32)

    # segment id = owning VM; everything that never runs goes to sentinel V
    runnable = valid & (mi > _EPS) & (mips[vm_assign] > 0.0)
    seg = jnp.where(runnable, vm_assign, V).astype(jnp.int32)

    idx = jnp.arange(C, dtype=jnp.int32)
    if use_kernel:
        # fused gather: ONE stable sort with (seg, mi) keys carries mi and
        # the row index as payload — the identical permutation to
        # lexsort((mi, seg)) (both are the stable (seg, mi) sort), minus
        # the two O(C) gathers the lax path pays after it.
        seg_s, mi_s, order = jax.lax.sort((seg, mi, idx), num_keys=2,
                                          is_stable=True)
    else:
        # lexicographic sort: primary by segment, secondary by length asc
        order = jnp.lexsort((mi, seg))
        seg_s = seg[order]
        mi_s = mi[order]

    prev_seg = jnp.concatenate([jnp.full((1,), -1, jnp.int32), seg_s[:-1]])
    start = seg_s != prev_seg                       # segment boundaries
    seg_start = jax.lax.cummax(jnp.where(start, idx, 0))
    pos = (idx - seg_start).astype(jnp.float32)     # j-1 within the segment

    # sharers count k per segment, gathered back per element
    counts = jax.ops.segment_sum(jnp.ones((C,), jnp.float32), seg_s,
                                 num_segments=V + 1)
    k = counts[seg_s]

    prev_mi = jnp.concatenate([jnp.zeros((1,), jnp.float32), mi_s[:-1]])
    delta = jnp.where(start, mi_s, mi_s - prev_mi)  # m_j − m_{j-1}
    seg_mips = jnp.concatenate([mips, jnp.zeros((1,), jnp.float32)])[seg_s]
    inv_mips = jnp.where(seg_mips > 0.0,
                         1.0 / jnp.maximum(seg_mips, 1e-30), 0.0)
    term = delta * (k - pos) * inv_mips             # (m_j−m_{j-1})(k−j+1)/μ

    if use_kernel:
        from repro.core.compat import resolve_kernel_interpret
        from repro.kernels.seg_scan.v2 import scatter_finish_v2, seg_cumsum_v2
        interpret = resolve_kernel_interpret(interpret)
        if kernel_chunk is None:
            from repro.roofline.autotune import tuned_chunk
            kernel_chunk = tuned_chunk(int(C))
        f_s = seg_cumsum_v2(term, start, chunk=kernel_chunk,
                            interpret=interpret)
        sentinel = seg_s == V                       # sentinel never finishes
        finish = scatter_finish_v2(f_s, order, sentinel, chunk=kernel_chunk,
                                   interpret=interpret)
        f_s = jnp.where(sentinel, 0.0, f_s)
    else:
        f_s = _segmented_cumsum(term, start)
        f_s = jnp.where(seg_s == V, 0.0, f_s)       # sentinel never finishes
        finish = jnp.zeros((C,), jnp.float32).at[order].set(f_s)
    makespan = jnp.max(f_s, initial=0.0)
    return finish, makespan


# jitted entry point with the flags static, shared so repeated calls (e.g.
# run_simulation) hit the compile cache instead of re-wrapping in jax.jit
simulate_completion_scan_jit = jax.jit(
    simulate_completion_scan,
    static_argnames=("use_kernel", "interpret", "kernel_chunk"))


# ------------------------------------------------- distributed phase 4

def default_vm_owner(n_vms: int, n_members: int) -> jnp.ndarray:
    """VM→member map from a freshly-balanced ``PartitionTable`` — the
    ownership an elastic cluster starts from before any scale event."""
    from repro.core.partition import PartitionTable
    table = PartitionTable(n_instances=n_members)
    return jnp.asarray(table.owners_of_range(n_vms))


class ExchangeCapacityError(RuntimeError):
    """The owner-keyed all-to-all's per-(src, dst) ``block`` capacity was
    exceeded: some cloudlets could not be shipped to their VM's owner and the
    finish vector would be silently wrong.  Raise ``block``/``slack`` (the
    exception message carries the observed requirement) or use the default
    auto capacity, which sizes ``block`` from the exact ``exchange_load``."""


# Compiled distributed cores, keyed on (mesh, axis, method, shapes, capacity).
# A ``CompileCache`` (the dispatcher's generalized LRU executable cache, which
# grew out of this dict) so a scale event can retire exactly the executables
# built for the mesh it replaces while every other member count's core stays
# warm; LRU-bounded (hits move to the back, the FRONT is evicted) so long
# grid sweeps over many (mesh, V, capacity) combinations don't accumulate
# executables forever — and don't evict the hottest mesh.
_DIST_CORE_CACHE = CompileCache()
_DIST_CORE_CACHE_MAX = 32

# Auto-sized exchange capacities, keyed (mesh, axis, V, C_pad): steady-state
# calls reuse the measured block instead of re-histogramming the ownership
# map on the host every call; overflow triggers an exact-requirement retry
# that updates the entry (see ``simulate_completion_distributed``).
_AUTO_BLOCK_CACHE = CompileCache()

# a dispatcher scale event retires the outgoing mesh's entries from both
# caches automatically (the auto-block capacities are metadata, not
# executables, so they don't count toward the event's retired-core tally)
dispatch.register_geometry_cache("dist_core", _DIST_CORE_CACHE)
dispatch.register_geometry_cache("auto_block", _AUTO_BLOCK_CACHE,
                                 counts_as_core=False)


def _cache_put(key, fn):
    # the cap stays a module global (not CompileCache(max_entries=...)) so
    # tests can monkeypatch _DIST_CORE_CACHE_MAX around a shared cache
    _DIST_CORE_CACHE.put(key, fn, max_entries=_DIST_CORE_CACHE_MAX)


def invalidate_dist_core(mesh=None, axis: Optional[str] = None) -> int:
    """Drop compiled distributed cores.  With a mesh (and optionally an
    axis), only that mesh's executables are invalidated — the elastic
    controller calls this on SCALE_OUT/IN so the retired member count's
    cores are freed but all other cached cores survive the event.  With no
    arguments, clears everything.  Returns the number of entries dropped."""
    def match(k):
        return ((mesh is None or k[0] == mesh)
                and (axis is None or k[1] == axis))

    n = _DIST_CORE_CACHE.invalidate(match)
    _AUTO_BLOCK_CACHE.invalidate(match)
    return n


def _dist_core_replicated(mesh, axis, V, use_kernel, interpret,
                          kernel_chunk=None):
    """The PR-2 distributed core, kept as the benchmark baseline: every
    member runs the IDENTICAL full O(C log C) scan and masks the finish
    entries of the VMs it doesn't own — result-partitioned, not
    compute-partitioned."""
    key = (mesh, axis, "replicated", V, use_kernel, interpret, kernel_chunk)
    cached = _DIST_CORE_CACHE.get(key)
    if cached is not None:
        return cached

    from repro.core.executor import DistributedExecutor

    executor = DistributedExecutor(mesh, axis)
    members = jnp.arange(executor.n_members, dtype=jnp.int32)

    def member_fn(mid, owner, assign, mi, mips, val):
        # Masking the *output* rather than the validity keeps each element's
        # value bit-identical to the single-member scan for ANY ownership map
        # and member count: partials are disjoint, and x + 0.0 == x exactly.
        f, _ = simulate_completion_scan(assign, mi, mips, val,
                                        use_kernel=use_kernel,
                                        interpret=interpret,
                                        kernel_chunk=kernel_chunk)
        mine = owner[assign] == mid[0]
        return jnp.where(mine, f, 0.0)[None, :]     # (1, C) partial

    def call(vm_owner, vm_assign, cloudlet_mi, vm_mips, valid):
        parts = executor.execute_on_key_owners(
            member_fn, members,
            replicated_args=(vm_owner, vm_assign, cloudlet_mi, vm_mips,
                             valid),
            out_specs=P(axis, None))
        finish = parts.sum(axis=0)
        return finish, jnp.max(finish, initial=0.0)

    fn = jax.jit(call)
    _cache_put(key, fn)
    return fn


def _dist_core_exchange(mesh, axis, V, C_pad, block, use_kernel, interpret,
                        kernel_chunk=None):
    """Compute-partitioned distributed core: bucket by VM owner, all-to-all,
    then each member lexsorts + scans ONLY its own cloudlets.  ``C_pad`` and
    ``block`` (the per-(src, dst) exchange capacity) are static — part of
    this cache key — while the VM→member ownership map stays a RUNTIME
    operand, so rebalancing the partition table never recompiles."""
    key = (mesh, axis, "exchange", V, C_pad, block, use_kernel, interpret,
           kernel_chunk)
    cached = _DIST_CORE_CACHE.get(key)
    if cached is not None:
        return cached

    from repro.core.executor import DistributedExecutor

    executor = DistributedExecutor(mesh, axis)
    M = executor.n_members
    S = C_pad // M                       # local cloudlet shard
    R = M * block                        # per-member receive capacity

    def member_fn(local, owner, mips):
        assign, mi, val = local                               # (S,) each
        mid = executor.member_id()
        orig = (mid * S + jnp.arange(S, dtype=jnp.int32))     # global rows
        # --- 1. bucket the local shard by destination owner --------------
        dest = jnp.where(val, owner[assign], M).astype(jnp.int32)
        order = jnp.argsort(dest)                 # group rows by destination
        dest_s = dest[order]
        idx = jnp.arange(S, dtype=jnp.int32)
        prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), dest_s[:-1]])
        bucket_start = jax.lax.cummax(jnp.where(dest_s != prev, idx, 0))
        rank = idx - bucket_start                 # position within bucket
        live = dest_s < M                         # invalid rows don't ship
        # overflowed rows land OUT of range and are dropped — but counted,
        # so the caller can fail loudly instead of returning wrong results
        slot = jnp.where(live & (rank < block), dest_s * block + rank, R)
        overflow = jnp.sum(live & (rank >= block)).astype(jnp.int32)
        need = jnp.max(jnp.where(live, rank, -1), initial=-1) + 1
        # fill: assign 0, orig C_pad (dropped at scatter-back), valid False
        fill = jnp.broadcast_to(jnp.array([0, C_pad, 0], jnp.int32), (R, 3))
        ints = fill.at[slot].set(
            jnp.stack([assign[order], orig[order],
                       val[order].astype(jnp.int32)], axis=-1), mode="drop")
        s_mi = jnp.zeros((R,), jnp.float32).at[slot].set(
            mi[order].astype(jnp.float32), mode="drop")
        # --- 2. one padded all-to-all re-homes the triples ---------------
        r_ints = executor.all_to_all(ints.reshape(M, block, 3)).reshape(R, 3)
        r_mi = executor.all_to_all(s_mi.reshape(M, block)).reshape(R)
        r_assign = r_ints[:, 0]
        r_orig, r_val = r_ints[:, 1], r_ints[:, 2] == 1
        # --- 3. sort + scan ONLY the ~C/M cloudlets this member owns -----
        f_loc, _ = simulate_completion_scan(r_assign, r_mi, mips, r_val,
                                            use_kernel=use_kernel,
                                            interpret=interpret,
                                            kernel_chunk=kernel_chunk)
        # --- 4. scatter finishes back to global rows; disjoint partials --
        part = jnp.zeros((C_pad,), jnp.float32).at[r_orig].set(
            f_loc, mode="drop")
        return (executor.psum(part), executor.psum(overflow),
                executor.pmax(need))

    def call(vm_owner, vm_assign, cloudlet_mi, vm_mips, valid):
        finish, overflow, need = executor.execute_on_key_owners(
            member_fn, (vm_assign, cloudlet_mi, valid),
            replicated_args=(vm_owner, vm_mips),
            out_specs=(P(), P(), P()))
        return finish, jnp.max(finish, initial=0.0), overflow, need

    fn = jax.jit(call)
    _cache_put(key, fn)
    return fn


def _pow2_ceil(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def simulate_completion_distributed(vm_assign, cloudlet_mi, vm_mips, valid,
                                    executor, vm_owner=None, *,
                                    method: str = "exchange",
                                    block: Optional[int] = None,
                                    slack: Optional[float] = None,
                                    use_kernel: bool = False,
                                    interpret: Optional[bool] = None,
                                    kernel_chunk: Optional[int] = None,
                                    weight_observer: Optional[
                                        Callable] = None):
    """Phase 4 distributed: per-VM completion segments are independent, so
    each member owns the finish entries of its VMs — ownership given by a
    ``PartitionTable``-backed VM→member map (``vm_owner``, a (V,) int32
    runtime array; defaults to a freshly-balanced table).

    ``method="exchange"`` (default) is COMPUTE-partitioned: an owner-keyed
    all-to-all re-homes each cloudlet to its VM's owner and each member
    sorts + scans only its own ~C/M cloudlets (see the module docstring for
    the protocol and padding invariants).  ``method="replicated"`` keeps the
    PR-2 baseline (every member scans the full problem, masks its output).

    Exchange capacity: ``block`` fixes the per-(src, dst) all-to-all block;
    ``slack`` sizes it heuristically (``exchange_block_size``).  Both fail
    LOUDLY (``ExchangeCapacityError``) when violated — never a silently-
    truncated result.  With neither, capacity is automatic and adaptive: the
    exact requirement is measured once from the concrete ownership map
    (``exchange_load``), rounded up to a power of two, and cached per
    (mesh, axis, V, C) so steady-state calls skip the host-side histogram
    entirely; if a later call's skew outgrows the cached block, the core's
    on-device overflow counter reports the exact new requirement and the
    call transparently retries once at that capacity (one recompile, still
    never a wrong result).

    ``weight_observer`` (optional) AUTO-wires the run's measured per-VM
    exchange load into locality-aware rebalancing: it is called with the
    (V,) count of valid cloudlets bound to each VM — exactly the per-key
    column mass of ``exchange_load`` — so passing a dispatcher's
    ``observe_key_weights`` makes the NEXT scale event spread hot VMs
    across members with no caller cooperation (the elastic simulation
    cluster wires this automatically).

    The per-member partials are disjoint and their sum is the full finish
    vector — BIT-identical to ``simulate_completion_scan`` for any member
    count, ownership map, and capacity (the thesis's accuracy claim), so an
    IAS scale event mid-run cannot perturb results."""
    from repro.core.partition import (exchange_block_size, exchange_load,
                                      pad_to_shards)

    V = vm_mips.shape[0]
    M = executor.n_members
    if vm_owner is None:
        vm_owner = default_vm_owner(V, M)
    vm_owner = jnp.asarray(vm_owner, jnp.int32)
    if use_kernel:
        from repro.core.compat import resolve_kernel_interpret
        interpret = resolve_kernel_interpret(interpret)
    if weight_observer is not None:
        a = np.asarray(vm_assign)
        live = np.asarray(valid).astype(bool)
        weight_observer(np.bincount(a[live], minlength=V).astype(np.float64))

    if method == "replicated":
        fn = _dist_core_replicated(executor.mesh, executor.axis, V,
                                   use_kernel, interpret, kernel_chunk)
        return fn(vm_owner, vm_assign, cloudlet_mi, vm_mips, valid)
    if method != "exchange":
        raise ValueError(f"unknown distributed method {method!r}")

    C = int(cloudlet_mi.shape[0])
    C_pad = pad_to_shards(max(C, 1), M)
    shard = C_pad // M
    auto = block is None and slack is None
    measured = False        # only a fresh measurement updates the cache
    if block is None:
        if slack is not None:
            block = exchange_block_size(C, M, slack)
        else:       # auto: exact requirement, cached per core geometry
            bkey = (executor.mesh, executor.axis, V, C_pad)
            block = _AUTO_BLOCK_CACHE.get(bkey)
            if block is None:
                need = int(exchange_load(vm_owner, vm_assign, valid, M).max())
                block = _pow2_ceil(max(need, 1))
                measured = True
    block = max(1, min(int(block), shard))

    vm_assign = jnp.asarray(vm_assign, jnp.int32)
    cloudlet_mi = jnp.asarray(cloudlet_mi, jnp.float32)
    valid = jnp.asarray(valid, bool)
    if C_pad != C:      # pad to whole shards; fill never runs nor ships
        pad = C_pad - C
        vm_assign = jnp.concatenate([vm_assign, jnp.zeros((pad,), jnp.int32)])
        cloudlet_mi = jnp.concatenate([cloudlet_mi, jnp.zeros((pad,))])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])

    while True:
        fn = _dist_core_exchange(executor.mesh, executor.axis, V, C_pad,
                                 block, use_kernel, interpret, kernel_chunk)
        finish, makespan, overflow, need = fn(vm_owner, vm_assign,
                                              cloudlet_mi, vm_mips, valid)
        if int(overflow) == 0:
            break
        if not auto:
            raise ExchangeCapacityError(
                f"{int(overflow)} cloudlet(s) exceeded the exchange block "
                f"capacity {block} (observed per-(src,dst) requirement: "
                f"{int(need)}); raise block/slack or use the default auto "
                f"capacity")
        # adaptive retry at the device-reported exact requirement; clamped
        # to the shard size, so the second attempt cannot overflow
        block = min(_pow2_ceil(int(need)), shard)
        measured = True
    if auto and measured:   # steady-state hits don't rewrite (or churn) it
        _AUTO_BLOCK_CACHE[bkey] = block
    return finish[:C], makespan


# ------------------------------------------------- batched scenario sweeps

BROKER_IDS = {"round_robin": 0, "matchmaking": 1}
MIPS_DIST_IDS = {"uniform": 0, "fixed": 1, "bimodal": 2}


@dataclasses.dataclass
class BatchSimulationResult:
    """One jit, B scenario variants (a multi-axis grid)."""
    vm_assign: np.ndarray        # (B, C)
    finish_times: np.ndarray     # (B, C)
    makespans: np.ndarray        # (B,)
    timings: Dict[str, float]
    broker: Optional[np.ndarray] = None      # (B,) broker id per variant
    n_vms: Optional[np.ndarray] = None       # (B,) live VMs per variant
    n_cloudlets: Optional[np.ndarray] = None  # (B,) live cloudlets per variant
    mips_dist: Optional[np.ndarray] = None   # (B,) MIPS-distribution id
    n_datacenters: Optional[np.ndarray] = None  # (B,) topology (0 = flat)
    is_loaded: Optional[np.ndarray] = None   # (B,) workload attached?
    workload_checksum: Optional[np.ndarray] = None  # (B,) isLoaded checksum
    dispatch: Optional[Dict] = None          # ElasticDispatcher report

    @property
    def n_scenarios(self) -> int:
        return int(self.makespans.shape[0])

    def summary(self) -> Dict[str, float]:
        return {"n_scenarios": self.n_scenarios,
                "mean_makespan": float(self.makespans.mean()),
                "min_makespan": float(self.makespans.min()),
                "max_makespan": float(self.makespans.max()),
                **{f"t_{k}": v for k, v in self.timings.items()}}


def grid_scenario_inputs(cfg, seed, mi_scale, n_vms, n_cloudlets, mips_dist,
                         n_datacenters=None):
    """Entities for ONE grid variant at the padded (cfg.n_vms, cfg.n_cloudlets)
    shape — pure and vmappable.  Shape padding: VMs beyond ``n_vms`` get
    0 MIPS and cloudlets beyond ``n_cloudlets`` get ``valid=False``, so
    heterogeneous variants stack into one batch and padded rows keep finish
    time exactly 0 (the scan core's sentinel-segment invariant).

    ``mips_dist`` selects the VM-capacity distribution family: 0 = uniform
    over ``vm_mips_range``, 1 = fixed at the range midpoint, 2 = bimodal
    (each VM at the low or high end, fair coin).

    ``n_datacenters`` (optional, traced) is the datacenter-topology axis:
    VMs are struck round-robin across that many datacenters, each datacenter
    carrying a seed-deterministic capacity factor in [0.5, 1.5], so the same
    VM population performs differently under different topologies.  The
    sentinel 0 (and ``None``) means FLAT topology — a bit-exact ×1.0 no-op,
    so pre-axis results are unchanged.  Padded VMs stay at exactly 0 MIPS.
    """
    V, C = cfg.n_vms, cfg.n_cloudlets
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    lo, hi = cfg.vm_mips_range
    mips_u = jax.random.uniform(k1, (V,), minval=lo, maxval=hi)
    mips_f = jnp.full((V,), (lo + hi) / 2.0, jnp.float32)
    mips_b = jnp.where(jax.random.bernoulli(k3, 0.5, (V,)), hi, lo)
    vm_mips = jnp.select([mips_dist == 0, mips_dist == 1],
                         [mips_u, mips_f], mips_b)
    vm_valid = jnp.arange(V) < n_vms
    vm_mips = jnp.where(vm_valid, vm_mips, 0.0)

    if n_datacenters is not None:
        n_dc = jnp.asarray(n_datacenters, jnp.int32)
        kd = jax.random.fold_in(key, 3)    # independent of k1/k2/k3 draws
        D = max(int(cfg.n_datacenters), 1)
        dc_factor = jax.random.uniform(kd, (D,), minval=0.5, maxval=1.5)
        vm_dc = jnp.arange(V, dtype=jnp.int32) % jnp.maximum(n_dc, 1)
        factor = jnp.where(n_dc > 0, dc_factor[vm_dc], 1.0)
        vm_mips = vm_mips * factor         # flat: ×1.0, bit-exact no-op

    lo, hi = cfg.cloudlet_mi_range
    mi = jax.random.uniform(k2, (C,), minval=lo, maxval=hi) * mi_scale
    valid = jnp.arange(C) < n_cloudlets
    mi = jnp.where(valid, mi, 0.0)
    return vm_mips, vm_valid, mi, valid


def _grid_workload(cfg, mi, valid, is_loaded):
    """Per-variant ``isLoaded`` checksum: every live cloudlet runs the real
    workload payload (``cloudsim._one_workload``) and the sum is the
    variant's checksum — 0.0 when the variant's ``is_loaded`` flag is off
    (padded/invalid cloudlets contribute exactly 0 either way)."""
    from repro.core.cloudsim import _one_workload, workload_iters

    iters = workload_iters(cfg)
    per = jax.vmap(lambda m: _one_workload(m, cfg.workload_dim, iters))(
        jnp.where(valid, mi, 0.0))
    total = jnp.where(valid, per, 0.0).sum()
    return jnp.where(is_loaded > 0, total, 0.0)


def _grid_scenario(cfg, with_workload, seed, mi_scale, broker, n_vms,
                   n_cloudlets, mips_dist, n_datacenters, is_loaded):
    """One full scenario — entities + broker + workload + scan core — pure-
    functionally (no DataGrid side effects) with every grid axis a traced
    scalar, so the whole pipeline vmaps over a heterogeneous variant stack.
    ``with_workload`` is STATIC: grids without an ``is_loaded`` axis never
    trace the workload payload at all."""
    from repro.core.cloudsim import matchmaking_assign_masked

    vm_mips, vm_valid, mi, valid = grid_scenario_inputs(
        cfg, seed, mi_scale, n_vms, n_cloudlets, mips_dist,
        n_datacenters=n_datacenters)
    ids = jnp.arange(cfg.n_cloudlets, dtype=jnp.int32)
    rr = (ids % n_vms).astype(jnp.int32)
    mm = matchmaking_assign_masked(ids, mi, vm_mips, vm_valid)
    assign = jnp.where(broker == BROKER_IDS["round_robin"], rr, mm)
    workload = (_grid_workload(cfg, mi, valid, is_loaded) if with_workload
                else jnp.zeros((), jnp.float32))
    finish, makespan = simulate_completion_scan(
        assign, mi, vm_mips, valid, use_kernel=cfg.use_kernel,
        kernel_chunk=cfg.kernel_chunk)
    return assign, finish, makespan, workload


@functools.lru_cache(maxsize=32)
def _batch_fn(cfg, with_workload):
    """Jitted vmap of the grid-scenario pipeline, cached per (hashable,
    frozen) config so repeated sweeps with the same cfg and batch shape
    reuse the compiled executable."""
    return jax.jit(jax.vmap(
        functools.partial(_grid_scenario, cfg, with_workload)))


@functools.lru_cache(maxsize=32)
def _batch_dist_fn(cfg, mesh, axis, with_workload):
    """Batch-sharded grid: the scenario vmap INSIDE the partitioned
    member_fn, so a grid of B variants shards B/n-per-member across the
    mesh — CloudSim-scale scenario throughput from data-parallel members."""
    from repro.core.executor import DistributedExecutor

    executor = DistributedExecutor(mesh, axis)

    def member_fn(local):
        return jax.vmap(
            functools.partial(_grid_scenario, cfg, with_workload))(*local)

    def call(*axes):
        return executor.execute_on_key_owners(member_fn, axes,
                                              out_specs=P(axis))

    return jax.jit(call)


def scenario_grid_job(cfg, with_workload: bool = False) -> DispatchJob:
    """The scenario grid as a dispatcher job: chunk items are the per-variant
    axis arrays, each member vmaps the scenario pipeline over its local
    variants, rows concatenate in submission order.  The signature is fully
    determined by the (frozen, hashable) config + the static workload gate,
    so every chunk of a geometry reuses one executable."""
    fn = functools.partial(_grid_scenario, cfg, with_workload)

    def member_fn(local, valid, *_):
        del valid                          # concat path: pad rows trimmed off
        return jax.vmap(fn)(*local)

    from repro.core.compat import kernel_path

    return DispatchJob(name="scenario_grid",
                       signature=("scenario_grid", cfg, with_workload),
                       member_fn=member_fn, reduce="concat",
                       kernel_path=kernel_path(cfg.use_kernel))


def _axis_array(value, B, dtype, name, id_map=None):
    """Normalize one grid axis to a (B,) array: scalars broadcast, str
    entries map through ``id_map`` (broker / MIPS-distribution names)."""
    if value is None:
        return None
    if isinstance(value, str) or np.isscalar(value):
        value = [value] * B
    vals = value if hasattr(value, "dtype") else np.asarray(value)
    if getattr(vals.dtype, "kind", "") in "USO":   # names -> ids
        vals = np.asarray([id_map[str(v)] for v in np.asarray(vals).ravel()])
    arr = jnp.asarray(vals, dtype)
    if arr.shape != (B,):
        raise ValueError(f"{name} must have shape ({B},), got {arr.shape}")
    return arr


def _batch_axis_args(cfg, seeds, *, mi_scale=None, broker=None, n_vms=None,
                     n_cloudlets=None, mips_dist=None, n_datacenters=None,
                     is_loaded=None):
    """Normalize the grid axes of a scenario batch into the positional
    operand stack ``_grid_scenario`` consumes: ``(seeds, scale, broker,
    n_vms, n_cloudlets, mips_dist, n_datacenters, is_loaded)``, each a (B,)
    array, plus the STATIC workload gate.  Shared by ``run_simulation_batch``
    and the resume path (``grid_batch_args``) so a restarted coordinator
    rebuilds bit-identical operands from the same cfg + grid."""
    if cfg.core != "scan":
        raise ValueError(
            f"run_simulation_batch only supports core='scan', got {cfg.core!r}")
    seeds = jnp.asarray(seeds, jnp.int32)
    B = seeds.shape[0]

    def default(arr, fill, dtype):
        return jnp.full((B,), fill, dtype) if arr is None else arr

    scale = default(_axis_array(mi_scale, B, jnp.float32, "mi_scale"),
                    1.0, jnp.float32)
    broker = default(_axis_array(broker, B, jnp.int32, "broker", BROKER_IDS),
                     BROKER_IDS[cfg.broker], jnp.int32)
    n_vms = default(_axis_array(n_vms, B, jnp.int32, "n_vms"),
                    cfg.n_vms, jnp.int32)
    n_cl = default(_axis_array(n_cloudlets, B, jnp.int32, "n_cloudlets"),
                   cfg.n_cloudlets, jnp.int32)
    n_dc = default(_axis_array(n_datacenters, B, jnp.int32, "n_datacenters"),
                   0, jnp.int32)
    with_workload = is_loaded is not None      # STATIC workload gate
    loaded = default(_axis_array(is_loaded, B, jnp.int32, "is_loaded"),
                     0, jnp.int32)
    # live counts must fit the padded shapes — JAX's clamping gather would
    # otherwise turn an oversized variant into silently-wrong results
    for name, arr, low, cap in (
            ("n_vms", n_vms, 1, cfg.n_vms),
            ("n_cloudlets", n_cl, 1, cfg.n_cloudlets),
            ("n_datacenters", n_dc, 0, cfg.n_datacenters),
            ("is_loaded", loaded, 0, 1)):
        if B == 0:
            break                        # nothing to validate (or run)
        lo, hi = int(arr.min()), int(arr.max())
        if lo < low or hi > cap:
            raise ValueError(f"{name} axis must lie in [{low}, {cap}] "
                             f"(the padded cfg shape), got [{lo}, {hi}]")
    mips_dist = default(_axis_array(mips_dist, B, jnp.int32, "mips_dist",
                                    MIPS_DIST_IDS),
                        MIPS_DIST_IDS["uniform"], jnp.int32)
    args = (seeds, scale, broker, n_vms, n_cl, mips_dist, n_dc, loaded)
    return args, with_workload


def run_simulation_batch(cfg, seeds, *, mi_scale=None, broker=None,
                         n_vms=None, n_cloudlets=None, mips_dist=None,
                         n_datacenters=None, is_loaded=None,
                         executor=None, dispatcher=None, chunk=None,
                         on_chunk=None, dispatch_ahead=None,
                         checkpoint=None) -> BatchSimulationResult:
    """Execute a multi-axis scenario GRID in a SINGLE jitted vmap.

    seeds: (B,) int array — one PRNG stream per scenario.  The optional grid
    axes are each a (B,) per-variant array (or a scalar applied to all):

      mi_scale      — float multiplier on cloudlet lengths (workload sweep)
      broker        — "round_robin" | "matchmaking" (names or BROKER_IDS ints)
      n_vms         — live VM count ≤ cfg.n_vms; the rest are 0-MIPS padding
      n_cloudlets   — live cloudlet count ≤ cfg.n_cloudlets; rest valid=False
      mips_dist     — "uniform" | "fixed" | "bimodal" (or MIPS_DIST_IDS ints)
      n_datacenters — datacenter-topology axis: VMs round-robin over that
                      many datacenters with seed-deterministic capacity
                      factors; 0 = flat topology (bit-exact no-op)
      is_loaded     — 0/1: attach the real ``isLoaded`` workload payload and
                      report its per-variant checksum (finish times are
                      untouched; padded rows keep finish exactly 0)

    The closed-form core has no data-dependent loop and every axis is a
    traced scalar, so B heterogeneous variants cost one XLA dispatch; ≥96
    variants per jit is the intended operating point.  With ``executor``
    (a multi-member mesh) the grid is sharded B/n-per-member: the scenario
    vmap runs inside the partitioned member_fn.  With ``dispatcher`` (an
    ``ElasticDispatcher``) the grid is submitted as a STREAMING job: cut
    into ``chunk``-variant chunks (grids larger than device memory), one
    compile per (geometry, job-signature), surviving IAS scale events
    between chunks (``on_chunk`` can feed ``observe_load``); the stream is
    ASYNC double-buffered — ``dispatch_ahead`` overrides the dispatcher's
    pipeline depth (0 = synchronous baseline), and the grid axes (jnp
    arrays) are chunked on DEVICE, never round-tripping to host.  ``cfg.
    use_kernel`` is honored; only the vmappable ``core="scan"`` is
    supported (the wave loop doesn't batch).
    """
    args, with_workload = _batch_axis_args(
        cfg, seeds, mi_scale=mi_scale, broker=broker, n_vms=n_vms,
        n_cloudlets=n_cloudlets, mips_dist=mips_dist,
        n_datacenters=n_datacenters, is_loaded=is_loaded)
    (seeds, scale, broker, n_vms, n_cl, mips_dist, n_dc, loaded) = args
    B = seeds.shape[0]

    report = None
    t0 = time.perf_counter()
    if dispatcher is not None and executor is not None:
        raise ValueError("pass either executor= (fixed mesh-sharded batch) "
                         "or dispatcher= (elastic chunk streaming), not "
                         "both — the dispatcher owns its own geometry")
    if dispatcher is not None:
        job = scenario_grid_job(cfg, with_workload)
        # deliver="host": the result dataclass materializes to numpy right
        # below, so the reduce lands on host directly — one gather, not a
        # sharded device concat plus a gather
        # checkpoint= journals the scenario stream (durable dispatch): a
        # long campaign killed mid-sweep resumes bit-identically via
        # ElasticDispatcher.resume with the same cfg/grid/chunking
        (assign, finish, makespans, workload), report = dispatcher.submit(
            job, args, chunk=chunk, on_chunk=on_chunk,
            dispatch_ahead=dispatch_ahead, deliver="host",
            checkpoint=checkpoint)
    elif executor is not None and executor.n_members > 1:
        n = executor.n_members
        pad = (-B) % n                   # round B up to a whole shard each
        if pad:
            args = tuple(jnp.concatenate([a, a[-1:].repeat(pad)])
                         for a in args)
        fn = _batch_dist_fn(cfg, executor.mesh, executor.axis, with_workload)
        assign, finish, makespans, workload = (o[:B] for o in fn(*args))
    else:
        assign, finish, makespans, workload = _batch_fn(cfg, with_workload)(
            *args)
    jax.block_until_ready(makespans)
    wall = time.perf_counter() - t0
    return BatchSimulationResult(
        vm_assign=np.asarray(assign), finish_times=np.asarray(finish),
        makespans=np.asarray(makespans),
        timings={"batch_total": wall, "per_scenario": wall / max(B, 1)},
        broker=np.asarray(broker), n_vms=np.asarray(n_vms),
        n_cloudlets=np.asarray(n_cl), mips_dist=np.asarray(mips_dist),
        n_datacenters=np.asarray(n_dc), is_loaded=np.asarray(loaded),
        workload_checksum=(np.asarray(workload) if with_workload else None),
        dispatch=(report.summary() if report is not None else None))


def make_scenario_grid(seeds: Sequence[int],
                       mi_scales: Sequence[float] = (1.0,),
                       brokers: Sequence[Union[str, int]] = ("round_robin",),
                       vm_counts: Sequence[int] = (0,),
                       cloudlet_counts: Sequence[int] = (0,),
                       mips_dists: Sequence[Union[str, int]] = ("uniform",),
                       dc_counts: Sequence[int] = (0,),
                       loaded: Sequence[int] = (0,),
                       ) -> Dict[str, np.ndarray]:
    """Cartesian product of grid axes → per-variant (B,) arrays, B = the
    product of axis lengths.  A 0 in ``vm_counts``/``cloudlet_counts`` means
    "the config's full count"; a 0 in ``dc_counts`` means flat datacenter
    topology; ``loaded`` entries are 0/1 ``isLoaded`` flags.  The sentinels
    are resolved against a config by ``run_scenario_grid(cfg, grid)``, the
    intended way to execute the product."""
    brokers = [BROKER_IDS[b] if isinstance(b, str) else int(b)
               for b in brokers]
    mips_dists = [MIPS_DIST_IDS[d] if isinstance(d, str) else int(d)
                  for d in mips_dists]
    axes = np.meshgrid(np.asarray(seeds, np.int32),
                       np.asarray(mi_scales, np.float32),
                       np.asarray(brokers, np.int32),
                       np.asarray(vm_counts, np.int32),
                       np.asarray(cloudlet_counts, np.int32),
                       np.asarray(mips_dists, np.int32),
                       np.asarray(dc_counts, np.int32),
                       np.asarray([int(v) for v in loaded], np.int32),
                       indexing="ij")
    flat = [a.ravel() for a in axes]
    return {"seeds": flat[0], "mi_scale": flat[1], "broker": flat[2],
            "n_vms": flat[3], "n_cloudlets": flat[4], "mips_dist": flat[5],
            "n_datacenters": flat[6], "is_loaded": flat[7]}


def _resolve_grid(cfg, grid: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Resolve a ``make_scenario_grid`` product against a config: 0-valued
    VM/cloudlet counts become the config's full counts, and an all-zero
    ``is_loaded`` axis is dropped so the workload payload is never traced
    for grids that don't use it (the STATIC gate)."""
    g = dict(grid)
    g["n_vms"] = np.where(np.asarray(g["n_vms"]) == 0, cfg.n_vms,
                          g["n_vms"]).astype(np.int32)
    g["n_cloudlets"] = np.where(np.asarray(g["n_cloudlets"]) == 0,
                                cfg.n_cloudlets,
                                g["n_cloudlets"]).astype(np.int32)
    if "is_loaded" in g and not np.asarray(g["is_loaded"]).any():
        g.pop("is_loaded")                # static gate: skip workload tracing
    return g


def grid_batch_args(cfg, grid: Dict[str, np.ndarray]):
    """Rebuild the (operand stack, dispatch job) of a scenario-grid stream
    from its cfg + grid — the resume-path counterpart of
    ``run_scenario_grid``.  ``ElasticDispatcher.resume`` needs the SAME
    args and job the original coordinator journaled so the environment
    signature verifies and replayed chunks are bit-identical; going through
    the same ``_resolve_grid`` + ``_batch_axis_args`` normalization
    guarantees that.  Returns ``(args, job, with_workload)``."""
    g = _resolve_grid(cfg, grid)
    seeds = g.pop("seeds")
    args, with_workload = _batch_axis_args(cfg, seeds, **g)
    return args, scenario_grid_job(cfg, with_workload), with_workload


def run_scenario_grid(cfg, grid: Dict[str, np.ndarray], *,
                      executor=None, dispatcher=None, chunk=None,
                      on_chunk=None, dispatch_ahead=None,
                      checkpoint=None) -> BatchSimulationResult:
    """Run a ``make_scenario_grid`` product through ``run_simulation_batch``
    (0-valued VM/cloudlet counts resolve to the config's full counts).
    With ``dispatcher``, the grid streams through the elastic dispatch
    middleware in ``chunk``-sized dispatches (see ``run_simulation_batch``).
    An ``is_loaded`` axis that is all-zero is dropped so the workload
    payload is never traced for grids that don't use it."""
    g = _resolve_grid(cfg, grid)
    seeds = g.pop("seeds")
    return run_simulation_batch(cfg, seeds, executor=executor,
                                dispatcher=dispatcher, chunk=chunk,
                                on_chunk=on_chunk,
                                dispatch_ahead=dispatch_ahead,
                                checkpoint=checkpoint, **g)
