"""DistributedExecutor — ``IExecutorService`` over a mesh.

``execute_on_key_owners(fn, data)`` ships ``fn`` to every shard and runs it on
the locally-resident partition (the paper's ``executeOnKeyOwner`` data-locality
principle): implemented with ``shard_map``, so *logic moves to the data* and no
operand crosses the interconnect.  ``submit`` mirrors plain ExecutorService
round-robin task submission (a vmapped task batch partitioned over members).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map


@functools.partial(jax.jit, static_argnames=("length",))
def _slice_chunk(src, lo, n_live, *, length):
    """Fixed-shape device-side chunk cut: rows [lo, lo+length) of ``src``
    plus the live-row mask ``arange(length) < n_live``.  ``length`` is static
    (one executable per chunk shape); ``lo``/``n_live`` are traced operands,
    so streaming a whole corpus reuses a single compiled slicer."""
    sl = jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, lo, length, axis=0), src)
    valid = jnp.arange(length, dtype=jnp.int32) < n_live
    return sl, valid


class DistributedExecutor:
    def __init__(self, mesh: Mesh, axis: str = "data"):
        self.mesh = mesh
        self.axis = axis

    @classmethod
    def for_devices(cls, devices, axis: str = "data") -> "DistributedExecutor":
        """Executor over an explicit device list — the elastic cluster
        rebuilds one per scale event from its (fixed) device pool."""
        import numpy as np
        return cls(Mesh(np.array(devices), (axis,)), axis)

    @property
    def n_members(self) -> int:
        return self.mesh.shape[self.axis]

    @property
    def device_list(self):
        """The devices backing this executor's mesh in axis order — the
        member-slot → device map the dispatcher's fault-injection launch
        hook consumes (slot i of the mesh is device_list[i])."""
        return list(self.mesh.devices.ravel())

    def sharding(self, spec: P) -> NamedSharding:
        """A NamedSharding on this executor's mesh — the placement vocabulary
        the dispatcher's auto-SPMD (global_fn) path speaks."""
        return NamedSharding(self.mesh, spec)

    def put(self, value, spec: P = None):
        """Place ``value`` on the mesh: partitioned on dim 0 by default
        (scalars replicate — there is no dim to partition), replicated with
        ``P()``, or any explicit spec."""
        value = jnp.asarray(value)
        if spec is None:
            spec = (P() if value.ndim == 0
                    else P(self.axis, *([None] * (value.ndim - 1))))
        return jax.device_put(value, self.sharding(spec))

    def slice_chunk(self, src, lo: int, length: int, n_live: int):
        """Cut a fixed-shape ``length``-row chunk starting at row ``lo`` from
        a DEVICE-resident item source, entirely on device (``lax.
        dynamic_slice`` + a valid mask for the first ``n_live`` rows) — a
        corpus produced by a previous job never round-trips to host just to
        be re-chunked.  The caller must guarantee ``lo + length`` does not
        exceed the source's rows (the dispatcher pads the source once, at
        stream start); ``dynamic_slice`` would otherwise clamp ``lo`` and
        silently shift the window.  ``lo``/``n_live`` ride into the jit as
        weak-typed scalars — no per-chunk eager device_put."""
        return _slice_chunk(src, lo, n_live, length=length)

    def execute_on_key_owners(self, fn: Callable, data, *, out_specs=None,
                              replicated_args=()):
        """Run ``fn(local_shard, *replicated_args)`` on each member's partition.

        data: array (or pytree) partitioned on dim 0 over the executor axis.
        fn must be shape-polymorphic in dim 0 (it receives 1/n of the rows).
        """
        in_spec = P(self.axis)
        out_specs = out_specs if out_specs is not None else P(self.axis)
        rep = P()

        f = shard_map(
            lambda d, *r: fn(d, *r), mesh=self.mesh,
            in_specs=(jax.tree_util.tree_map(lambda _: in_spec, data),
                      *[jax.tree_util.tree_map(lambda _: rep, a)
                        for a in replicated_args]),
            out_specs=out_specs, check_vma=False)
        return f(data, *replicated_args)

    def map_reduce(self, map_fn: Callable, reduce_kind: str, data,
                   *, replicated_args=()):
        """map per shard then a collective reduce ('sum'|'max'|'concat')."""
        axis = self.axis

        def body(local, *rep):
            mapped = map_fn(local, *rep)
            if reduce_kind == "sum":
                return jax.lax.psum(mapped, axis)
            if reduce_kind == "max":
                return jax.lax.pmax(mapped, axis)
            if reduce_kind == "concat":
                return jax.lax.all_gather(mapped, axis, tiled=True)
            raise ValueError(reduce_kind)

        f = shard_map(
            body, mesh=self.mesh,
            in_specs=(jax.tree_util.tree_map(lambda _: P(axis), data),
                      *[jax.tree_util.tree_map(lambda _: P(), a)
                        for a in replicated_args]),
            out_specs=P(), check_vma=False)
        return f(data, *replicated_args)

    # -- member-side collectives: only valid INSIDE an execute_on_key_owners
    #    (or map_reduce) body, where the executor axis is bound by shard_map.

    def member_id(self):
        """This member's index on the executor axis (0..n_members-1)."""
        return jax.lax.axis_index(self.axis)

    def all_to_all(self, x, split_axis: int = 0, concat_axis: int = 0):
        """Exchange: scatters ``split_axis`` (length n_members) across the
        members and gathers the received blocks along ``concat_axis`` — the
        owner-keyed cloudlet re-home of the distributed scan core."""
        return jax.lax.all_to_all(x, self.axis, split_axis, concat_axis)

    def psum(self, x):
        return jax.lax.psum(x, self.axis)

    def pmax(self, x):
        return jax.lax.pmax(x, self.axis)

    def submit(self, task_fn: Callable, args_batch):
        """ExecutorService.submit of a task batch: tasks are round-robin
        partitioned over members and vmapped locally."""
        def local(batch):
            return jax.vmap(task_fn)(batch)
        return self.execute_on_key_owners(local, args_batch)
