"""Fault injection + retry policy — failure as a first-class middleware event.

The thesis's premise is that simulations inherit the properties of the
middleware they model, and Hazelcast's defining property beyond elasticity is
SURVIVING MEMBER DEPARTURE: Cloud²Sim's dynamic scaler treats nodes joining
and leaving as normal operation, and CloudSim itself models failure as a
first-class simulation event (arXiv:0903.2525; the federated extensions of
arXiv:0907.4878 argue real cloud tooling must).  This module supplies the two
halves the ``ElasticDispatcher`` needs to make an INVOLUNTARY failure
mid-stream a recoverable event instead of a dead job:

  ``FaultInjector``   a deterministic, seeded chaos harness a test or
                      benchmark hands to the dispatcher.  Each fault is
                      addressable by ``(chunk_index, member, kind)`` so chaos
                      schedules replay bit-for-bit:

                        member_crash   the device backing mesh slot ``member``
                                       at chunk ``chunk`` dies; every launch
                                       touching it fails until the dispatcher
                                       removes it from the pool (Hazelcast's
                                       member-departure signal)
                        nan_poison     chunk ``chunk``'s float output rows on
                                       slot ``member`` become NaN — the
                                       silent-corruption case the
                                       ``HealthMonitor`` docstring calls the
                                       "member crash" signal
                        stall          chunk ``chunk``'s retirement is delayed
                                       ``delay_s`` past its launch — a hung
                                       launch / straggler, detected by the
                                       ``RetryPolicy`` chunk deadline
                        compile_fail   building chunk ``chunk``'s executable
                                       raises once
                        coordinator_crash  the COORDINATOR process itself
                                       dies at chunk ``chunk``'s launch —
                                       ``CoordinatorCrashError`` by default
                                       (in-process preemption a test can
                                       catch), ``os._exit(137)`` when
                                       ``hard_exit=True`` (indistinguishable
                                       from ``kill -9``); recovery is
                                       ``ElasticDispatcher.resume`` from the
                                       journal, not a retry

  ``RetryPolicy``     what ``submit`` does about a detected failure: per-chunk
                      attempt budget, chunk deadline, exponential backoff,
                      and member quarantine (N retryable failures attributed
                      to one member ⇒ treat the member as failed and remesh
                      onto the survivors).

Because chunks are pure functions of (item slice, replicated operands) and
the deterministic chunk-tree reduce fixes the combine order by chunk INDEX,
a replayed chunk — on the same mesh or on the post-failure mesh — produces
bit-identical bytes, so a recovered stream equals a fault-free run exactly.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

FAULT_KINDS = ("member_crash", "nan_poison", "stall", "compile_fail",
               "coordinator_crash")


# ------------------------------------------------------------------ failures

class MemberFailedError(RuntimeError):
    """A launch touched a dead member (the involuntary-departure signal).
    Carries the failing mesh slot and its backing device so the dispatcher
    can retire exactly that device from the pool."""

    def __init__(self, chunk: int, member: int, device):
        super().__init__(f"member {member} (device {device}) failed at "
                         f"chunk {chunk}")
        self.chunk = chunk
        self.member = member
        self.device = device


class CompileFailedError(RuntimeError):
    """Building a chunk's executable failed (retryable)."""

    def __init__(self, chunk: int):
        super().__init__(f"compile failed for chunk {chunk}")
        self.chunk = chunk


class CoordinatorCrashError(RuntimeError):
    """The coordinator process was killed mid-stream (a scheduled
    ``coordinator_crash`` fault in its default in-process mode).  NOT a
    retryable chunk failure: the dispatcher lets it propagate — the stream
    dies exactly as a real preemption would — and recovery is
    ``ElasticDispatcher.resume`` from the journaled state."""

    def __init__(self, chunk: int):
        super().__init__(f"coordinator crashed at chunk {chunk}")
        self.chunk = chunk


class JobFailedError(RuntimeError):
    """A stream exhausted its recovery options (per-chunk attempts spent, or
    survivors dropped below ``min_instances``).  Carries the structured
    ``DispatchReport`` — failures, retries, recovery events — instead of a
    bare traceback; the dispatcher is left drained (``in_flight == 0``) and
    fully reusable."""

    def __init__(self, message: str, report):
        super().__init__(message)
        self.report = report


# ------------------------------------------------------------------- policy

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How ``submit`` turns a detected chunk failure into a recovery.

    max_attempts      per-chunk failure budget: the job fails loudly
                      (``JobFailedError``) once one chunk accumulates this
                      many failures (member-crash replays don't count — the
                      member failed, not the chunk)
    chunk_timeout_s   launch-to-retirement deadline; exceeding it is a
                      retryable "stall" failure (None = no deadline).  Under
                      pipelining the measured wall includes queue wait, so
                      size it against ``dispatch_ahead`` steady-state walls,
                      not raw compute
    backoff_s         sleep before attempt k's replay:
                      ``backoff_s * backoff_factor**(k-1)`` (0 = immediate)
    quarantine_after  N retryable failures attributed to ONE member ⇒ the
                      member is treated as failed: forced failure remesh onto
                      the survivors (0 = never quarantine)
    check_finite      opt-in cheap non-finite check on every chunk output —
                      the ``HealthMonitor`` docstring's "member crash"
                      signal.  Costs one device reduction + scalar sync per
                      chunk on the already-retired output (see
                      BENCH_fault.json's overhead entry)
    """
    max_attempts: int = 3
    chunk_timeout_s: Optional[float] = None
    backoff_s: float = 0.0
    backoff_factor: float = 2.0
    quarantine_after: int = 2
    check_finite: bool = False

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.chunk_timeout_s is not None and self.chunk_timeout_s <= 0:
            raise ValueError("chunk_timeout_s must be positive (or None)")

    @property
    def active(self) -> bool:
        """True when the policy asks for per-chunk validation (a deadline or
        a finiteness check) — the dispatcher then retires every chunk
        through the guarded path instead of the lazy clear."""
        return self.chunk_timeout_s is not None or self.check_finite

    def backoff_for(self, attempt: int) -> float:
        if self.backoff_s <= 0:
            return 0.0
        return self.backoff_s * self.backoff_factor ** max(attempt - 1, 0)


# ----------------------------------------------------------------- injector

@dataclasses.dataclass
class FaultSpec:
    """One addressable fault: ``(chunk, member, kind)`` + kind parameters.
    ``times`` bounds how often it fires (default once — the transient-fault
    model: the replay succeeds), so recovery is observable, not a loop.
    ``tenant`` scopes the fault to ONE tenant's streams: it fires only when
    the injector is bound to that tenant (``bind_tenant``, which
    ``ElasticDispatcher.submit(tenant=...)`` does for the stream's
    duration) — the multi-tenant front end uses this to target chaos at a
    single misbehaving tenant while every other tenant's requests pass the
    same injector untouched.  ``None`` (the default) matches any stream,
    tenant-bound or not — pre-existing schedules behave exactly as
    before."""
    kind: str
    chunk: int
    member: int = 0
    delay_s: float = 0.25            # stall: injected extra latency
    times: int = 1
    tenant: Optional[str] = None     # None = fires for every stream

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.chunk < 0 or self.member < 0:
            raise ValueError("chunk and member must be >= 0")


class FaultInjector:
    """Deterministic chaos harness for the dispatcher's chunk stream.

    Hand one to ``ElasticDispatcher(fault_injector=...)`` (or per-stream via
    ``submit``); the dispatcher calls the hooks below at its launch / compile
    / retire points.  The schedule is a plain list of ``FaultSpec``s — no
    hidden clocks or RNG at fire time — so a chaos run replays exactly;
    ``random_schedule`` derives a reproducible schedule from a seed.
    ``fired`` logs every fault that actually triggered, in firing order."""

    def __init__(self, schedule: Sequence[FaultSpec] = (),
                 hard_exit: bool = False):
        self.schedule: List[FaultSpec] = list(schedule)
        self.dead_devices: Set = set()
        self.fired: List[dict] = []
        # coordinator_crash mode: False raises CoordinatorCrashError (an
        # in-process preemption tests can catch and resume from), True calls
        # os._exit(137) — no atexit, no finally blocks, the SIGKILL shape
        self.hard_exit = hard_exit
        # the tenant the CURRENT stream belongs to (bind_tenant): tenant-
        # scoped specs fire only when it matches; None-tenant specs always do
        self._tenant: Optional[str] = None

    @classmethod
    def random_schedule(cls, seed: int, n_chunks: int, max_members: int = 1,
                        n_faults: int = 3,
                        kinds: Sequence[str] = FAULT_KINDS,
                        stall_delay_s: float = 0.25,
                        tenants: Optional[Sequence[str]] = None
                        ) -> "FaultInjector":
        """A reproducible chaos schedule: ``n_faults`` specs drawn uniformly
        over (kind, chunk, member) from ``np.random.RandomState(seed)`` —
        the same seed always yields the same schedule, on any host.  The
        default pool is ALL of ``FAULT_KINDS`` (``coordinator_crash``
        included since the durable-dispatch PR); pass an explicit ``kinds``
        to pin a pre-existing schedule.  ``tenants`` additionally draws a
        tenant target per spec (the extra rng draws happen AFTER every
        pre-existing one, so a given seed's (kind, chunk, member) triples
        are unchanged whether or not tenants are requested) — chaos tests
        can aim a whole schedule at one tenant deterministically with
        ``tenants=["t3"]``."""
        rng = np.random.RandomState(seed)
        triples = [(str(rng.choice(list(kinds))),
                    int(rng.randint(0, max(n_chunks, 1))),
                    int(rng.randint(0, max(max_members, 1))))
                   for _ in range(n_faults)]
        owners = ([None] * n_faults if tenants is None else
                  [str(rng.choice(list(tenants))) for _ in range(n_faults)])
        return cls([FaultSpec(kind=k, chunk=c, member=m,
                              delay_s=stall_delay_s, tenant=t)
                    for (k, c, m), t in zip(triples, owners)])

    # ------------------------------------------------------------- scoping
    @contextlib.contextmanager
    def bind_tenant(self, tenant: Optional[str]):
        """Scope the injector to ``tenant`` for one stream: tenant-addressed
        specs fire only while their tenant is bound (``ElasticDispatcher.
        submit(tenant=...)`` holds the binding for the whole stream,
        replays included).  Bindings don't nest — the dispatcher runs one
        stream at a time — and the previous binding is restored on exit."""
        prev, self._tenant = self._tenant, tenant
        try:
            yield self
        finally:
            self._tenant = prev

    # ------------------------------------------------------------- matching
    def _take(self, kind: str, chunk: int) -> Optional[FaultSpec]:
        """Consume one firing of the first live spec matching (kind, chunk)
        whose tenant scope matches the bound stream (None = any)."""
        for spec in self.schedule:
            if (spec.kind == kind and spec.chunk == chunk and spec.times > 0
                    and (spec.tenant is None
                         or spec.tenant == self._tenant)):
                spec.times -= 1
                return spec
        return None

    def _log(self, kind: str, chunk: int, member, **extra) -> None:
        if self._tenant is not None:
            extra.setdefault("tenant", self._tenant)
        self.fired.append({"kind": kind, "chunk": chunk, "member": member,
                           **extra})

    # ---------------------------------------------------------------- hooks
    def on_launch(self, chunk: int, devices: Sequence) -> None:
        """Called before every chunk launch with the devices backing the
        current mesh.  Fires a pending ``coordinator_crash`` first — the
        coordinator dies before it can launch anything (raise, or hard
        ``os._exit(137)``; see ``hard_exit``) — then pending
        ``member_crash`` specs for this chunk (marking the slot's device
        dead), then fails the launch if ANY mesh device is dead — a killed
        member fails every launch touching it until the dispatcher retires
        it from the pool."""
        if self._take("coordinator_crash", chunk) is not None:
            self._log("coordinator_crash", chunk, None)
            if self.hard_exit:
                import os
                os._exit(137)
            raise CoordinatorCrashError(chunk)
        while True:
            spec = self._take("member_crash", chunk)
            if spec is None:
                break
            dev = devices[spec.member % len(devices)]
            self.dead_devices.add(dev)
            self._log("member_crash", chunk, spec.member % len(devices))
        for slot, dev in enumerate(devices):
            if dev in self.dead_devices:
                raise MemberFailedError(chunk, slot, dev)

    def on_compile(self, chunk: int) -> None:
        """Called before an executable build; fires ``compile_fail``."""
        if self._take("compile_fail", chunk) is not None:
            self._log("compile_fail", chunk, None)
            raise CompileFailedError(chunk)

    def maybe_poison(self, chunk: int, out, n_rows: int, n_members: int):
        """Fire a pending ``nan_poison`` for this chunk: float leaves with a
        row-shaped leading dim get the target slot's rows NaN'd (so the
        detector can ATTRIBUTE the corruption to a member); other float
        leaves (replicated partials) are poisoned whole."""
        import jax
        import jax.numpy as jnp

        spec = self._take("nan_poison", chunk)
        if spec is None:
            return out
        slot = spec.member % max(n_members, 1)
        self._log("nan_poison", chunk, slot)
        shard = max(n_rows // max(n_members, 1), 1)
        lo, hi = slot * shard, (slot + 1) * shard

        def poison(leaf):
            if not jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf
            if leaf.ndim >= 1 and leaf.shape[0] == n_rows:
                rows = jnp.arange(n_rows)
                mask = ((rows >= lo) & (rows < hi)).reshape(
                    (n_rows,) + (1,) * (leaf.ndim - 1))
                return jnp.where(mask, jnp.nan, leaf)
            return jnp.full_like(leaf, jnp.nan)

        return jax.tree_util.tree_map(poison, out)

    def stall_for(self, chunk: int) -> Tuple[float, Optional[int]]:
        """Fire a pending ``stall`` for this chunk: returns (extra latency
        the dispatcher should sleep before measuring the chunk's wall,
        responsible member slot) — (0.0, None) when nothing is scheduled."""
        spec = self._take("stall", chunk)
        if spec is None:
            return 0.0, None
        # stall entries carry the injected latency so the fired log can be
        # cross-checked against the collector's stall histogram
        self._log("stall", chunk, spec.member, delay_s=spec.delay_s)
        return spec.delay_s, spec.member

    # ---------------------------------------------------------------- views
    def pending(self) -> Dict[str, int]:
        """Remaining firings per kind (chaos tests assert exhaustion)."""
        out: Dict[str, int] = {}
        for spec in self.schedule:
            if spec.times > 0:
                out[spec.kind] = out.get(spec.kind, 0) + spec.times
        return out
