"""Cloud²Sim core: the paper's contribution as composable JAX modules.

  compat       version-tolerant jax shims (shard_map location / kwarg renames)
  partition    PartitionUtil + 271-virtual-shard consistent partition table
  grid         DataGrid — the in-memory data grid over a device mesh
  executor     DistributedExecutor — logic-to-data shard_map execution
  dispatch     ElasticDispatcher — the unified remesh-aware, chunk-streaming
               job middleware (grids, MapReduce, and the elastic cluster all
               run on it) + the CompileCache executable cache
  mapreduce    dual-backend (hazelcast/infinispan) MapReduce engine, run as
               dispatcher jobs (chunk streaming + adaptive scaling)
  health       HealthMonitor (Algorithm 4 signals)
  elastic      AdaptiveScalerProbe / IntelligentAdaptiveScaler (Algs 5-6)
  coordinator  multi-tenant Coordinator
  speedup      analytical model, Eqs (3.1)-(3.11)
  cloudsim     the distributed DES cloud simulator (RR + matchmaking brokers)
  des_scan     closed-form O(C log C) segmented-scan DES core (+ distributed
               phase-4 and batched scenario sweeps)
"""
from repro.core.compat import shard_map  # noqa: F401  (re-export the shim)
