"""DataGrid — the in-memory data grid (Hazelcast IMap) as sharded jax Arrays.

A grid holds named arrays with explicit shardings over a mesh.  Fidelity map:

  IMap.put/get            -> put(name, value, spec) / get(name)
  BINARY vs OBJECT format -> dtype policy (bf16 "wire" vs f32 "object")
  synchronous backup      -> backup(name): neighbor-shifted replica
                             (jnp.roll along the sharded axis ≈ Hazelcast
                             placing backups on a different member)
  member crash + recovery -> restore_from_backup(name, lost_shard)
  near-cache              -> replicate(name): fully-replicated copy

The grid is the storage substrate of the DES simulator and the MapReduce
engine; training state uses the same principle via NamedSharding directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class GridEntry:
    value: jax.Array
    spec: P
    backup: Optional[jax.Array] = None
    in_memory_format: str = "OBJECT"   # OBJECT=f32, BINARY=bf16


class DataGrid:
    def __init__(self, mesh: Mesh, axis: str = "data", backup_count: int = 0):
        self.mesh = mesh
        self.axis = axis
        self.backup_count = backup_count
        self._store: Dict[str, GridEntry] = {}
        # entries whose sharded spec was downgraded to replicated by a
        # remesh (leading dim not divisible by the new member count)
        self.downgraded: Dict[str, P] = {}

    @property
    def n_members(self) -> int:
        return self.mesh.shape[self.axis]

    def _sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def put(self, name: str, value, spec: Optional[P] = None,
            in_memory_format: str = "OBJECT"):
        value = jnp.asarray(value)
        if in_memory_format == "BINARY" and value.dtype == jnp.float32:
            value = value.astype(jnp.bfloat16)  # serialized wire format
        if spec is None:
            spec = P(self.axis, *([None] * (value.ndim - 1)))
        value = jax.device_put(value, self._sharding(spec))
        entry = GridEntry(value, spec, in_memory_format=in_memory_format)
        if self.backup_count > 0:
            entry.backup = self._make_backup(value)
        self._store[name] = entry
        # a replaced entry's spec is authoritative: drop any stale remesh-
        # downgrade record, else a later remesh would resurrect the old spec
        self.downgraded.pop(name, None)
        return value

    def get(self, name: str) -> jax.Array:
        return self._store[name].value

    def spec(self, name: str) -> P:
        return self._store[name].spec

    def keys(self):
        return sorted(self._store)

    def remove(self, name: str):
        self._store.pop(name, None)
        self.downgraded.pop(name, None)

    def clear(self):
        """clearDistributedObjects() — end-of-simulation cleanup."""
        self._store.clear()
        self.downgraded.clear()

    # ------------------------------------------------------------- backups
    def _make_backup(self, value: jax.Array) -> jax.Array:
        """Synchronous backup: every member stores its *neighbor's* shard
        (shift by one shard along the partitioned axis)."""
        n = self.n_members
        if value.shape[0] % n != 0 or n == 1:
            return value  # degenerate: replicate
        shard = value.shape[0] // n
        return jnp.roll(value, shard, axis=0)

    def restore_from_backup(self, name: str, lost_member: int) -> jax.Array:
        """Recover a member's shard from the neighbor backup (fail-over)."""
        e = self._store[name]
        if e.backup is None:
            raise RuntimeError(f"no synchronous backup for {name!r}")
        n = self.n_members
        if e.value.shape[0] % n != 0 or n == 1:
            # degenerate backup (see _make_backup): a full replicated copy,
            # not neighbor-rolled — unrolling it would corrupt the restore
            out = jax.device_put(jnp.asarray(e.backup),
                                 self._sharding(e.spec))
            self._store[name] = dataclasses.replace(e, value=out)
            return out
        shard = e.value.shape[0] // n
        lo = lost_member * shard
        val = np.asarray(e.value).copy()
        # backup = roll(value, +shard): member m+1 holds m's shard; unroll it.
        unrolled = np.roll(np.asarray(e.backup), -shard, axis=0)
        val[lo:lo + shard] = unrolled[lo:lo + shard]
        out = jax.device_put(jnp.asarray(val), self._sharding(e.spec))
        self._store[name] = dataclasses.replace(e, value=out)
        return out

    def fail_over(self, lost_member: int) -> list:
        """Member-failure recovery sweep: restore EVERY entry holding a
        synchronous backup from its neighbor's replica (Hazelcast's
        partition fail-over — the backup owner promotes its copy when a
        member departs).  Returns the restored names; entries without
        backups are left untouched.  The dispatcher calls this BEFORE the
        failure remesh so restored values re-home onto the survivor mesh
        like any other entry."""
        restored = []
        for name, e in list(self._store.items()):
            if e.backup is not None:
                self.restore_from_backup(name, lost_member)
                restored.append(name)
        return restored

    # ------------------------------------------------------------ elasticity
    def remesh(self, mesh: Mesh) -> int:
        """Elastic re-shard (scale event): re-home every entry onto the new
        mesh with its original spec — the IMap's virtual partitions migrating
        to the new member set.  Logical content is unchanged; only device
        placement moves.  Entries whose leading dim does not divide the new
        member count (entities are normally padded via ``pad_to_shards`` at
        creation, but a dispatcher-streamed grid may hold odd-shaped
        intermediates) fall back to REPLICATED placement instead of failing
        the whole scale event; the downgrade is recorded in
        ``self.downgraded`` and automatically REVERSED by a later remesh
        whose member count divides the entry again.  Returns the number of
        entries re-homed."""
        self.mesh = mesh
        for name, e in list(self._store.items()):
            spec = e.spec
            original = self.downgraded.get(name)
            if (original is not None
                    and e.value.shape[0] % self.n_members == 0):
                spec = original              # geometry fits again: re-shard
                del self.downgraded[name]
            if (spec and len(spec) > 0 and spec[0] == self.axis
                    and e.value.shape[0] % self.n_members != 0):
                self.downgraded[name] = spec
                spec = P(*([None] * e.value.ndim))
            value = jax.device_put(e.value, self._sharding(spec))
            # backups are neighbor-rolled by the OLD shard size — rebuild
            # them for the new member count, else fail-over would restore a
            # stale-offset shard
            backup = None if e.backup is None else self._make_backup(value)
            self._store[name] = dataclasses.replace(e, value=value,
                                                    backup=backup, spec=spec)
        return len(self._store)

    def replicate(self, name: str) -> jax.Array:
        """Near-cache: a fully-replicated copy (memory for latency)."""
        e = self._store[name]
        return jax.device_put(e.value, self._sharding(P(*([None] * e.value.ndim))))

    def total_bytes(self) -> int:
        return sum(int(e.value.size * e.value.dtype.itemsize)
                   for e in self._store.values())
