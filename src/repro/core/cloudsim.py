"""Concurrent & distributed cloud (DES) simulator — the thesis's CloudSim side.

Entity model (struct-of-arrays, stored in the DataGrid as the thesis stores
them in Hazelcast IMaps): Datacenters ⊃ Hosts ⊃ VMs ⊂ Cloudlets.  Brokers:

  * RoundRobinBroker      — cloudlet i → VM (i mod V)           (§5.1.1)
  * MatchmakingBroker     — fair matchmaking (Raman et al.): each cloudlet
    requires a minimal VM size f(length); it binds to an adequate VM while
    *not* overloading the large VMs — among adequate candidates the broker
    round-robins by cloudlet index (§5.1.2).

Execution phases, mirroring §3.4.1.2 / Fig 3.10:
  1. create entities          (distributed: partitions created shard-locally)
  2. schedule (broker)        (distributed: matchmaking over local partitions,
                               VM table replicated — executeOnKeyOwner)
  3. cloudlet workloads       (distributed: the ``isLoaded`` real compute)
  4. core event simulation    (distributed: the closed-form segmented-scan
                               core in ``des_scan`` re-homes each cloudlet
                               to its VM-owner member with one owner-keyed
                               all-to-all and each member sorts + scans only
                               its own ~C/M cloudlets — the thesis left this
                               phase master-only because "tightly coupled
                               core fragments are not distributed", §4; the
                               closed form decouples them and the exchange
                               makes phase 4 COMPUTE-partitioned end-to-end)
``SimulationConfig.core`` selects the phase-4 engine: "scan" (default,
O(C log C) closed form), "scan_dist" (scan partitioned over members;
``dist_method`` picks the owner-keyed "exchange" pipeline or the PR-2
"replicated" baseline), "wave" (the original master-only event loop — kept
as the equivalence oracle).  Outputs are identical regardless of the number
of members (tests assert the thesis's accuracy claim).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.executor import DistributedExecutor
from repro.core.grid import DataGrid
from repro.core.partition import pad_to_shards
from repro.core import des_scan


@dataclasses.dataclass(frozen=True)
class SimulationConfig:
    n_datacenters: int = 15
    n_hosts: int = 60
    n_vms: int = 200
    n_cloudlets: int = 400
    vm_mips_range: tuple = (500.0, 2000.0)
    cloudlet_mi_range: tuple = (1000.0, 50000.0)   # million instructions
    broker: str = "round_robin"                    # | "matchmaking"
    core: str = "scan"                             # | "scan_dist" | "wave"
    dist_method: str = "exchange"                  # | "replicated" (PR-2 core)
    exchange_slack: Optional[float] = None         # None = exact auto capacity
    use_kernel: bool = False                       # Pallas seg-scan kernel
    kernel_chunk: Optional[int] = None             # None = roofline-autotuned
    is_loaded: bool = False                        # attach a real workload
    workload_dim: int = 64                         # loaded-matmul size
    workload_iters_per_gmi: float = 2.0            # iterations per 1000 MI
    seed: int = 42


# ----------------------------------------------------------------- entities

def create_entities(cfg: SimulationConfig, grid: DataGrid,
                    pad_multiple: int = 1) -> Dict[str, jax.Array]:
    """Create datacenters/hosts/VMs/cloudlets into the data grid (padded so
    every member owns an equal partition, per PartitionUtil).

    ``pad_multiple`` additionally pads entity array sizes to a multiple of
    that value: the elastic cluster passes the LCM of every member count its
    IAS can reach, so padded shapes — and hence the PRNG draws — are
    IDENTICAL across scale events without requiring the LIVE entity counts
    to be divisible by anything.  Padding rows are inert (0-MIPS VMs,
    ``valid=False`` cloudlets) and never scheduled onto."""
    n = math.lcm(grid.n_members, max(pad_multiple, 1))
    key = jax.random.PRNGKey(cfg.seed)
    k1, k2 = jax.random.split(key)
    V = pad_to_shards(cfg.n_vms, n)
    C = pad_to_shards(cfg.n_cloudlets, n)

    lo, hi = cfg.vm_mips_range
    vm_mips = jax.random.uniform(k1, (V,), minval=lo, maxval=hi)
    vm_mips = jnp.where(jnp.arange(V) < cfg.n_vms, vm_mips, 0.0)
    vm_host = jnp.arange(V, dtype=jnp.int32) % max(cfg.n_hosts, 1)

    lo, hi = cfg.cloudlet_mi_range
    cl_mi = jax.random.uniform(k2, (C,), minval=lo, maxval=hi)
    cl_valid = jnp.arange(C) < cfg.n_cloudlets
    cl_mi = jnp.where(cl_valid, cl_mi, 0.0)

    grid.put("vm_mips", vm_mips)
    grid.put("vm_host", vm_host)
    grid.put("cloudlet_mi", cl_mi)
    grid.put("cloudlet_valid", cl_valid)
    return {"vm_mips": vm_mips, "vm_host": vm_host, "cloudlet_mi": cl_mi,
            "cloudlet_valid": cl_valid, "n_vms": cfg.n_vms,
            "n_cloudlets": cfg.n_cloudlets}


# ------------------------------------------------------------------ brokers

def round_robin_assign(local_ids, n_vms: int):
    return (local_ids % n_vms).astype(jnp.int32)


def matchmaking_assign(local_ids, local_mi, vm_mips, n_vms: int):
    """Fair matchmaking over the (replicated) VM table for a local partition.

    required(cl) = mi-proportional minimal MIPS; candidates = VMs with
    mips >= required; bind to the (id mod n_candidates)-th smallest adequate
    VM — best-fit with round-robin fairness (no overloading the largest VMs).
    """
    return matchmaking_assign_masked(local_ids, local_mi, vm_mips[:n_vms],
                                     jnp.ones((n_vms,), bool))


def matchmaking_assign_masked(local_ids, local_mi, vm_mips, vm_valid):
    """``matchmaking_assign`` with the VM count TRACED: padded VMs are masked
    by ``vm_valid`` instead of sliced off, so scenario-grid variants with
    heterogeneous VM counts batch into one vmap.  Equals the static version
    exactly when every VM is valid (padded VMs sort to +inf, past every
    candidate window)."""
    n_vms = vm_valid.sum().astype(jnp.int32)
    keyed = jnp.where(vm_valid, vm_mips, jnp.inf)
    order = jnp.argsort(keyed)                           # valid ascending first
    sorted_mips = keyed[order]
    max_mi = 50000.0
    max_mips = jnp.max(jnp.where(vm_valid, vm_mips, -jnp.inf))
    required = local_mi / max_mi * (max_mips * 0.9)
    first_ok = jnp.searchsorted(sorted_mips, required)   # (c,)
    first_ok = jnp.minimum(first_ok, n_vms - 1)
    n_cand = n_vms - first_ok
    pick = first_ok + (local_ids % n_cand)
    return order[pick].astype(jnp.int32)


def schedule(cfg: SimulationConfig, grid: DataGrid,
             executor: DistributedExecutor) -> jax.Array:
    """Distributed scheduling: each member matches its cloudlet partition."""
    C = grid.get("cloudlet_mi").shape[0]
    ids = jnp.arange(C, dtype=jnp.int32)
    mi = grid.get("cloudlet_mi")
    vm_mips = grid.replicate("vm_mips")                  # near-cache the VM table

    if cfg.broker == "round_robin":
        fn = lambda data, vm: round_robin_assign(data[0], cfg.n_vms)
    else:
        fn = lambda data, vm: matchmaking_assign(data[0], data[1], vm,
                                                 cfg.n_vms)
    assign = executor.execute_on_key_owners(fn, (ids, mi),
                                            replicated_args=(vm_mips,))
    grid.put("cloudlet_vm", assign)
    return assign


# ----------------------------------------------------------------- workloads

def _one_workload(mi, dim: int, iters: int):
    """The ``isLoaded`` cloudlet payload: real (distributable) compute whose
    size scales with the cloudlet length."""
    a = (jnp.ones((dim, dim), jnp.float32) * (mi / 50000.0) +
         jnp.eye(dim, dtype=jnp.float32))

    def body(_, m):
        return jnp.tanh(m @ a) * 0.5 + a * 0.1

    out = jax.lax.fori_loop(0, iters, body, a)
    return jnp.sum(out)


def workload_iters(cfg: SimulationConfig) -> int:
    """The ``isLoaded`` payload's iteration count — ONE definition shared by
    the per-simulation path (``run_workloads``) and the scenario grid's
    ``is_loaded`` axis, so both report the same checksum for a config."""
    return int(cfg.workload_iters_per_gmi * (cfg.cloudlet_mi_range[1] / 1000.0))


def run_workloads(cfg: SimulationConfig, grid: DataGrid,
                  executor: DistributedExecutor) -> jax.Array:
    mi = grid.get("cloudlet_mi")
    iters = workload_iters(cfg)

    def member(local_mi):
        return jax.vmap(lambda m: _one_workload(m, cfg.workload_dim, iters))(
            local_mi)

    checks = executor.execute_on_key_owners(member, mi)
    grid.put("workload_checksum", checks)
    return checks


# ------------------------------------------------- core DES (master instance)

def simulate_completion(vm_assign, cloudlet_mi, vm_mips, valid):
    """Time-shared completion waves (CloudletSchedulerTimeShared).

    Event loop: between consecutive completions every active cloudlet on VM v
    progresses at mips_v / active_v.  Returns (finish_times, makespan).
    Pure JAX while_loop — one iteration per completion wave.

    O(waves × C × V): kept as the equivalence ORACLE for the O(C log C)
    closed-form core in ``repro.core.des_scan`` (the production path).

    Dtype-generic: the arithmetic runs in the dtype of ``cloudlet_mi``, so
    under ``jax.experimental.enable_x64`` the oracle accumulates ``now`` in
    f64 and the equivalence tolerance measures only the scan's own f32
    error, not the oracle's sequential drift (~eps·|t|·√waves in f32).
    """
    C = cloudlet_mi.shape[0]
    V = vm_mips.shape[0]
    dtype = cloudlet_mi.dtype if jnp.issubdtype(cloudlet_mi.dtype,
                                                jnp.floating) else jnp.float32
    remaining = jnp.where(valid, cloudlet_mi, 0.0).astype(dtype)
    vm_mips = vm_mips.astype(dtype)
    finish = jnp.zeros((C,), dtype)
    onehot_vm = jax.nn.one_hot(vm_assign, V, dtype=dtype)

    def cond(state):
        remaining, _, _ = state
        return jnp.any(remaining > 1e-6)

    def body(state):
        remaining, finish, now = state
        active = remaining > 1e-6
        counts = (active.astype(dtype))[None, :] @ onehot_vm  # (1,V)
        counts = counts[0]
        rate_vm = jnp.where(counts > 0, vm_mips / jnp.maximum(counts, 1.0), 0.0)
        rate = (onehot_vm @ rate_vm) * active                        # (C,)
        tte = jnp.where(active & (rate > 0), remaining / rate, jnp.inf)
        dt = jnp.min(tte)
        dt = jnp.where(jnp.isfinite(dt), dt, 0.0)
        new_remaining = jnp.maximum(remaining - rate * dt, 0.0)
        just_done = active & (new_remaining <= 1e-6)
        finish = jnp.where(just_done, now + dt, finish)
        # guard: if nothing progresses (all rates 0), zero out to terminate
        stalled = (dt <= 0) & active & (rate <= 0)
        new_remaining = jnp.where(stalled, 0.0, new_remaining)
        return new_remaining, finish, now + dt

    _, finish, makespan = jax.lax.while_loop(
        cond, body, (remaining, finish, jnp.zeros((), dtype)))
    return finish, makespan


_simulate_completion_jit = jax.jit(simulate_completion)


# ----------------------------------------------------------------- full run

@dataclasses.dataclass
class SimulationResult:
    vm_assign: np.ndarray
    finish_times: np.ndarray
    makespan: float
    workload_checksum: Optional[np.ndarray]
    timings: Dict[str, float]

    def summary(self) -> Dict[str, float]:
        return {"makespan": float(self.makespan),
                "mean_finish": float(self.finish_times.mean()),
                **{f"t_{k}": v for k, v in self.timings.items()}}


def run_simulation(cfg: SimulationConfig, mesh: Mesh,
                   backup_count: int = 0, *, grid: Optional[DataGrid] = None,
                   executor: Optional[DistributedExecutor] = None,
                   vm_owner=None, pad_multiple: int = 1,
                   weight_observer=None) -> SimulationResult:
    """One full simulation on ``mesh``.  ``grid``/``executor`` may be
    supplied by an elastic cluster that re-homes them across scale events
    (caller-owned grids are NOT cleared at the end); ``vm_owner`` is the
    PartitionTable-backed VM→member map for ``core="scan_dist"``;
    ``pad_multiple`` additionally pads entity sizes (see
    ``create_entities``) so elastic runs keep identical shapes across
    member counts; ``weight_observer`` receives the scan core's measured
    per-VM exchange load (see ``simulate_completion_distributed``) — the
    elastic cluster passes its dispatcher's ``observe_key_weights`` so the
    next rebalance is locality-aware with no caller cooperation."""
    own_grid = grid is None
    grid = grid if grid is not None else DataGrid(mesh,
                                                 backup_count=backup_count)
    executor = executor if executor is not None else DistributedExecutor(mesh)
    timings = {}

    t0 = time.perf_counter()
    ents = create_entities(cfg, grid, pad_multiple)
    jax.block_until_ready(grid.get("cloudlet_mi"))
    timings["create"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    assign = schedule(cfg, grid, executor)
    jax.block_until_ready(assign)
    timings["schedule"] = time.perf_counter() - t0

    checks = None
    if cfg.is_loaded:
        t0 = time.perf_counter()
        checks = run_workloads(cfg, grid, executor)
        jax.block_until_ready(checks)
        timings["workload"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    core_args = (assign, grid.get("cloudlet_mi"), grid.get("vm_mips"),
                 grid.get("cloudlet_valid"))
    if cfg.core == "wave":
        finish, makespan = _simulate_completion_jit(*core_args)
    elif cfg.core == "scan_dist":
        finish, makespan = des_scan.simulate_completion_distributed(
            *core_args, executor, vm_owner=vm_owner, method=cfg.dist_method,
            slack=cfg.exchange_slack, use_kernel=cfg.use_kernel,
            kernel_chunk=cfg.kernel_chunk, weight_observer=weight_observer)
    elif cfg.core == "scan":
        finish, makespan = des_scan.simulate_completion_scan_jit(
            *core_args, use_kernel=cfg.use_kernel,
            kernel_chunk=cfg.kernel_chunk)
    else:
        raise ValueError(f"unknown core {cfg.core!r}")
    jax.block_until_ready(finish)
    timings["core_sim"] = time.perf_counter() - t0

    if own_grid:
        grid.clear()   # clearDistributedObjects()
    return SimulationResult(
        vm_assign=np.asarray(assign), finish_times=np.asarray(finish),
        makespan=float(makespan),
        workload_checksum=None if checks is None else np.asarray(checks),
        timings=timings)


# ------------------------------------------------- elastic simulation cluster

class ElasticSimulationCluster:
    """Elastic mesh for ``core="scan_dist"`` — a THIN CLIENT of the
    ``ElasticDispatcher`` middleware (``core/dispatch.py``).

    All the moving parts live in the dispatcher now: the 271-virtual-
    partition ``PartitionTable``, the ``ElasticController``→IAS wiring, the
    remesh callback (rebalance table → retire exactly the outgoing
    geometry's executables → rebuild mesh → re-home the ``DataGrid``), and
    the compile cache.  This class only binds a simulation config to the
    dispatcher's current geometry: it pads entities to the dispatcher's
    ``entity_pad`` (the LCM of every member count the IAS can reach) and
    ships the table-backed VM→member map as the distributed core's runtime
    operand, so finish vectors are BIT-identical before and after any scale
    event (PAPER §4.1.3 / §4.3).
    """

    def __init__(self, devices=None, axis: str = "data",
                 health_cfg: Optional["HealthConfig"] = None,
                 start_members: int = 1,
                 partition_count: Optional[int] = None,
                 dispatcher=None):
        from repro.core.dispatch import ElasticDispatcher

        if dispatcher is not None:
            # the dispatcher IS the topology: silently dropping conflicting
            # kwargs would run a differently-configured cluster
            if (devices is not None or axis != "data"
                    or health_cfg is not None or start_members != 1
                    or partition_count is not None):
                raise ValueError(
                    "pass either a dispatcher OR topology kwargs (devices/"
                    "axis/health_cfg/start_members/partition_count), not "
                    "both — the dispatcher already owns the topology")
            self.dispatcher = dispatcher
        else:
            self.dispatcher = ElasticDispatcher(
                devices=devices, axis=axis, health_cfg=health_cfg,
                start_members=start_members, partition_count=partition_count)

    # ------------------------------------------- dispatcher-backed topology
    @property
    def devices(self):
        return self.dispatcher.devices

    @property
    def axis(self) -> str:
        return self.dispatcher.axis

    @property
    def table(self):
        return self.dispatcher.table

    @property
    def controller(self):
        return self.dispatcher.controller

    @property
    def mesh(self):
        return self.dispatcher.mesh

    @property
    def executor(self) -> DistributedExecutor:
        return self.dispatcher.executor

    @property
    def grid(self) -> Optional[DataGrid]:
        return self.dispatcher.grid

    @property
    def entity_pad(self) -> int:
        return self.dispatcher.entity_pad

    @property
    def scale_events(self):
        return self.dispatcher.scale_events

    @property
    def n_members(self) -> int:
        return self.dispatcher.n_members

    def vm_owner(self, n_vms: int) -> jnp.ndarray:
        """Current VM→member map (the runtime operand of the scan core)."""
        return self.dispatcher.vm_owner(n_vms)

    # ------------------------------------------------------------- scaling
    def observe_load(self, load: float):
        """Feed one load sample (observed/target, the paper's process-CPU
        analogue) to the monitor→probe→IAS chain; a threshold crossing
        triggers the dispatcher's remesh callback at this step boundary."""
        return self.dispatcher.observe_load(load)

    # ----------------------------------------------------------- simulation
    def simulate(self, cfg: SimulationConfig) -> SimulationResult:
        """Run one simulation on the CURRENT member count with table-backed
        VM ownership.  Entity sizes are auto-padded to the LCM of every
        member count the IAS can reach (``self.entity_pad``), so padded
        shapes — and hence PRNG draws and finish vectors — are BIT-identical
        across scale events for ARBITRARY ``n_vms``/``n_cloudlets``; no
        divisibility requirement.  Results are trimmed back to the
        configured live entity counts.

        Each run also AUTO-feeds its measured per-VM exchange load into the
        dispatcher's ``observe_key_weights``, so the next IAS scale event
        rebalances locality-aware (hot VMs spread across members) with no
        caller cooperation."""
        if cfg.core != "scan_dist":
            cfg = dataclasses.replace(cfg, core="scan_dist")
        grid = self.dispatcher.ensure_grid()
        V = pad_to_shards(cfg.n_vms, math.lcm(self.n_members,
                                              self.entity_pad))
        r = run_simulation(cfg, self.mesh, grid=grid,
                           executor=self.executor,
                           vm_owner=self.vm_owner(V),
                           pad_multiple=self.entity_pad,
                           weight_observer=(
                               self.dispatcher.observe_key_weights))
        C = cfg.n_cloudlets
        return dataclasses.replace(
            r, vm_assign=r.vm_assign[:C], finish_times=r.finish_times[:C],
            workload_checksum=(None if r.workload_checksum is None
                               else r.workload_checksum[:C]))

    def simulate_grid(self, cfg: SimulationConfig, grid, *,
                      chunk: Optional[int] = None, on_chunk=None,
                      dispatch_ahead: Optional[int] = None,
                      checkpoint=None):
        """Stream a ``make_scenario_grid`` product through this cluster's
        elastic dispatcher — the cloudsim face of the scenario-grid batch
        path (``des_scan.run_scenario_grid``), with mid-stream IAS scale
        events and, via ``checkpoint`` (a ``core.journal.CheckpointPolicy``),
        DURABLE dispatch: the campaign's chunk stream is journaled and
        checkpointed so a killed coordinator resumes bit-identically
        (``resume_grid``).  Returns a ``BatchSimulationResult`` whose
        ``dispatch`` field carries the ``DispatchReport`` summary."""
        from repro.core.des_scan import run_scenario_grid
        return run_scenario_grid(cfg, grid, dispatcher=self.dispatcher,
                                 chunk=chunk, on_chunk=on_chunk,
                                 dispatch_ahead=dispatch_ahead,
                                 checkpoint=checkpoint)

    def resume_grid(self, path: str, cfg: SimulationConfig, grid, *,
                    chunk: Optional[int] = None, on_chunk=None):
        """Continue a journaled ``simulate_grid`` after a coordinator
        crash/drain: rebuild the scenario job + operand stack exactly as
        ``simulate_grid`` would (the journal's environment signature is
        verified against it), then hand off to
        ``ElasticDispatcher.resume``.  Returns the same tuple-of-arrays
        payload the scenario job produces, bit-identical to an
        uninterrupted ``simulate_grid``."""
        from repro.core.des_scan import grid_batch_args
        args, job, _ = grid_batch_args(cfg, grid)
        return self.dispatcher.resume(path, job, args, chunk=chunk,
                                      on_chunk=on_chunk)
