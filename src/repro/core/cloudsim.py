"""Concurrent & distributed cloud (DES) simulator — the thesis's CloudSim side.

Entity model (struct-of-arrays, stored in the DataGrid as the thesis stores
them in Hazelcast IMaps): Datacenters ⊃ Hosts ⊃ VMs ⊂ Cloudlets.  Brokers:

  * RoundRobinBroker      — cloudlet i → VM (i mod V)           (§5.1.1)
  * MatchmakingBroker     — fair matchmaking (Raman et al.): each cloudlet
    requires a minimal VM size f(length); it binds to an adequate VM while
    *not* overloading the large VMs — among adequate candidates the broker
    round-robins by cloudlet index (§5.1.2).

Execution phases, mirroring §3.4.1.2 / Fig 3.10:
  1. create entities          (distributed: partitions created shard-locally)
  2. schedule (broker)        (distributed: matchmaking over local partitions,
                               VM table replicated — executeOnKeyOwner)
  3. cloudlet workloads       (distributed: the ``isLoaded`` real compute)
  4. core event simulation    (distributed: the closed-form segmented-scan
                               core in ``des_scan`` partitions independent
                               per-VM completion segments over members —
                               the thesis left this phase master-only
                               because "tightly coupled core fragments are
                               not distributed", §4; the closed form
                               decouples them)
``SimulationConfig.core`` selects the phase-4 engine: "scan" (default,
O(C log C) closed form), "scan_dist" (scan partitioned over members),
"wave" (the original master-only event loop — kept as the equivalence
oracle).  Outputs are identical regardless of the number of members (tests
assert the thesis's accuracy claim).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.executor import DistributedExecutor
from repro.core.grid import DataGrid
from repro.core.partition import pad_to_shards
from repro.core import des_scan


@dataclasses.dataclass(frozen=True)
class SimulationConfig:
    n_datacenters: int = 15
    n_hosts: int = 60
    n_vms: int = 200
    n_cloudlets: int = 400
    vm_mips_range: tuple = (500.0, 2000.0)
    cloudlet_mi_range: tuple = (1000.0, 50000.0)   # million instructions
    broker: str = "round_robin"                    # | "matchmaking"
    core: str = "scan"                             # | "scan_dist" | "wave"
    use_kernel: bool = False                       # Pallas seg-scan kernel
    is_loaded: bool = False                        # attach a real workload
    workload_dim: int = 64                         # loaded-matmul size
    workload_iters_per_gmi: float = 2.0            # iterations per 1000 MI
    seed: int = 42


# ----------------------------------------------------------------- entities

def create_entities(cfg: SimulationConfig, grid: DataGrid) -> Dict[str, jax.Array]:
    """Create datacenters/hosts/VMs/cloudlets into the data grid (padded so
    every member owns an equal partition, per PartitionUtil)."""
    n = grid.n_members
    key = jax.random.PRNGKey(cfg.seed)
    k1, k2 = jax.random.split(key)
    V = pad_to_shards(cfg.n_vms, n)
    C = pad_to_shards(cfg.n_cloudlets, n)

    lo, hi = cfg.vm_mips_range
    vm_mips = jax.random.uniform(k1, (V,), minval=lo, maxval=hi)
    vm_mips = jnp.where(jnp.arange(V) < cfg.n_vms, vm_mips, 0.0)
    vm_host = jnp.arange(V, dtype=jnp.int32) % max(cfg.n_hosts, 1)

    lo, hi = cfg.cloudlet_mi_range
    cl_mi = jax.random.uniform(k2, (C,), minval=lo, maxval=hi)
    cl_valid = jnp.arange(C) < cfg.n_cloudlets
    cl_mi = jnp.where(cl_valid, cl_mi, 0.0)

    grid.put("vm_mips", vm_mips)
    grid.put("vm_host", vm_host)
    grid.put("cloudlet_mi", cl_mi)
    grid.put("cloudlet_valid", cl_valid)
    return {"vm_mips": vm_mips, "vm_host": vm_host, "cloudlet_mi": cl_mi,
            "cloudlet_valid": cl_valid, "n_vms": cfg.n_vms,
            "n_cloudlets": cfg.n_cloudlets}


# ------------------------------------------------------------------ brokers

def round_robin_assign(local_ids, n_vms: int):
    return (local_ids % n_vms).astype(jnp.int32)


def matchmaking_assign(local_ids, local_mi, vm_mips, n_vms: int):
    """Fair matchmaking over the (replicated) VM table for a local partition.

    required(cl) = mi-proportional minimal MIPS; candidates = VMs with
    mips >= required; bind to the (id mod n_candidates)-th smallest adequate
    VM — best-fit with round-robin fairness (no overloading the largest VMs).
    """
    mips_valid = vm_mips[:n_vms]
    order = jnp.argsort(mips_valid)                      # ascending by size
    sorted_mips = mips_valid[order]
    max_mi = 50000.0
    required = local_mi / max_mi * (sorted_mips[-1] * 0.9)
    first_ok = jnp.searchsorted(sorted_mips, required)   # (c,)
    first_ok = jnp.minimum(first_ok, n_vms - 1)
    n_cand = n_vms - first_ok
    pick = first_ok + (local_ids % n_cand)
    return order[pick].astype(jnp.int32)


def schedule(cfg: SimulationConfig, grid: DataGrid,
             executor: DistributedExecutor) -> jax.Array:
    """Distributed scheduling: each member matches its cloudlet partition."""
    C = grid.get("cloudlet_mi").shape[0]
    ids = jnp.arange(C, dtype=jnp.int32)
    mi = grid.get("cloudlet_mi")
    vm_mips = grid.replicate("vm_mips")                  # near-cache the VM table

    if cfg.broker == "round_robin":
        fn = lambda data, vm: round_robin_assign(data[0], cfg.n_vms)
    else:
        fn = lambda data, vm: matchmaking_assign(data[0], data[1], vm,
                                                 cfg.n_vms)
    assign = executor.execute_on_key_owners(fn, (ids, mi),
                                            replicated_args=(vm_mips,))
    grid.put("cloudlet_vm", assign)
    return assign


# ----------------------------------------------------------------- workloads

def _one_workload(mi, dim: int, iters: int):
    """The ``isLoaded`` cloudlet payload: real (distributable) compute whose
    size scales with the cloudlet length."""
    a = (jnp.ones((dim, dim), jnp.float32) * (mi / 50000.0) +
         jnp.eye(dim, dtype=jnp.float32))

    def body(_, m):
        return jnp.tanh(m @ a) * 0.5 + a * 0.1

    out = jax.lax.fori_loop(0, iters, body, a)
    return jnp.sum(out)


def run_workloads(cfg: SimulationConfig, grid: DataGrid,
                  executor: DistributedExecutor) -> jax.Array:
    mi = grid.get("cloudlet_mi")
    iters = int(cfg.workload_iters_per_gmi *
                (cfg.cloudlet_mi_range[1] / 1000.0))

    def member(local_mi):
        return jax.vmap(lambda m: _one_workload(m, cfg.workload_dim, iters))(
            local_mi)

    checks = executor.execute_on_key_owners(member, mi)
    grid.put("workload_checksum", checks)
    return checks


# ------------------------------------------------- core DES (master instance)

def simulate_completion(vm_assign, cloudlet_mi, vm_mips, valid):
    """Time-shared completion waves (CloudletSchedulerTimeShared).

    Event loop: between consecutive completions every active cloudlet on VM v
    progresses at mips_v / active_v.  Returns (finish_times, makespan).
    Pure JAX while_loop — one iteration per completion wave.

    O(waves × C × V): kept as the equivalence ORACLE for the O(C log C)
    closed-form core in ``repro.core.des_scan`` (the production path).
    """
    C = cloudlet_mi.shape[0]
    V = vm_mips.shape[0]
    remaining = jnp.where(valid, cloudlet_mi, 0.0)
    finish = jnp.zeros((C,), jnp.float32)
    onehot_vm = jax.nn.one_hot(vm_assign, V, dtype=jnp.float32)

    def cond(state):
        remaining, _, _ = state
        return jnp.any(remaining > 1e-6)

    def body(state):
        remaining, finish, now = state
        active = remaining > 1e-6
        counts = (active.astype(jnp.float32))[None, :] @ onehot_vm  # (1,V)
        counts = counts[0]
        rate_vm = jnp.where(counts > 0, vm_mips / jnp.maximum(counts, 1.0), 0.0)
        rate = (onehot_vm @ rate_vm) * active                        # (C,)
        tte = jnp.where(active & (rate > 0), remaining / rate, jnp.inf)
        dt = jnp.min(tte)
        dt = jnp.where(jnp.isfinite(dt), dt, 0.0)
        new_remaining = jnp.maximum(remaining - rate * dt, 0.0)
        just_done = active & (new_remaining <= 1e-6)
        finish = jnp.where(just_done, now + dt, finish)
        # guard: if nothing progresses (all rates 0), zero out to terminate
        stalled = (dt <= 0) & active & (rate <= 0)
        new_remaining = jnp.where(stalled, 0.0, new_remaining)
        return new_remaining, finish, now + dt

    _, finish, makespan = jax.lax.while_loop(
        cond, body, (remaining, finish, jnp.float32(0.0)))
    return finish, makespan


_simulate_completion_jit = jax.jit(simulate_completion)


# ----------------------------------------------------------------- full run

@dataclasses.dataclass
class SimulationResult:
    vm_assign: np.ndarray
    finish_times: np.ndarray
    makespan: float
    workload_checksum: Optional[np.ndarray]
    timings: Dict[str, float]

    def summary(self) -> Dict[str, float]:
        return {"makespan": float(self.makespan),
                "mean_finish": float(self.finish_times.mean()),
                **{f"t_{k}": v for k, v in self.timings.items()}}


def run_simulation(cfg: SimulationConfig, mesh: Mesh,
                   backup_count: int = 0) -> SimulationResult:
    grid = DataGrid(mesh, backup_count=backup_count)
    executor = DistributedExecutor(mesh)
    timings = {}

    t0 = time.perf_counter()
    ents = create_entities(cfg, grid)
    jax.block_until_ready(grid.get("cloudlet_mi"))
    timings["create"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    assign = schedule(cfg, grid, executor)
    jax.block_until_ready(assign)
    timings["schedule"] = time.perf_counter() - t0

    checks = None
    if cfg.is_loaded:
        t0 = time.perf_counter()
        checks = run_workloads(cfg, grid, executor)
        jax.block_until_ready(checks)
        timings["workload"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    core_args = (assign, grid.get("cloudlet_mi"), grid.get("vm_mips"),
                 grid.get("cloudlet_valid"))
    if cfg.core == "wave":
        finish, makespan = _simulate_completion_jit(*core_args)
    elif cfg.core == "scan_dist":
        finish, makespan = des_scan.simulate_completion_distributed(
            *core_args, executor)
    elif cfg.core == "scan":
        finish, makespan = des_scan.simulate_completion_scan_jit(
            *core_args, use_kernel=cfg.use_kernel)
    else:
        raise ValueError(f"unknown core {cfg.core!r}")
    jax.block_until_ready(finish)
    timings["core_sim"] = time.perf_counter() - t0

    grid.clear()   # clearDistributedObjects()
    return SimulationResult(
        vm_assign=np.asarray(assign), finish_times=np.asarray(finish),
        makespan=float(makespan),
        workload_checksum=None if checks is None else np.asarray(checks),
        timings=timings)
