"""Analytical speedup/performance model — thesis §3.3, Eqs (3.1)–(3.11).

    T_n = k·T_1/n + (1−k)·T_1 + S + C + γ + F − θ            (3.1/3.6)
    S_n = T_1 / T_n                                          (3.7)
    E_n = S_n / n                                            (3.8)
    P   = (1 − 1/S_n)·100                                    (3.10)

with S = f1(s) serialization, C = f2(n,d,w,s) communication, γ = f3(n,d,w)
coordination, F fixed costs and θ = f4(N) the data-grid resource gain.

For the TPU port the terms are *measurable from the dry-run roofline*:
  S  -> re-shard/cast bytes ÷ HBM bandwidth
  C  -> collective bytes ÷ link bandwidth (grows with n via the comm term)
  γ  -> per-hop collective latency × collective count
  F  -> dispatch/launch overhead per step
  θ  -> HBM-fit gain (paging/spill avoided once the working set fits n·HBM)

The model reproduces the thesis's four scalability regimes (§5.1.1):
positive, negative (coordination-heavy), positive-then-negative (common), and
complex borderline — see benchmarks/speedup_model.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List


@dataclasses.dataclass(frozen=True)
class SpeedupModel:
    t1: float                 # serial time T_1 (s)
    k: float                  # distributable fraction of the code
    s_cost: float = 0.0       # S: serialization (independent of n)
    c_per_n: float = 0.0      # C: communication cost slope in n
    c_size: float = 0.0       # C: size-dependent communication component
    gamma_per_n: float = 0.0  # γ: coordination slope in n
    fixed: float = 0.0        # F
    theta_fn: Callable[[int], float] = staticmethod(lambda n_nodes: 0.0)

    def t_n(self, n: int, n_nodes: int = None) -> float:
        """Eq. 3.6 — predicted distributed time on n instances."""
        if n <= 1:
            return self.t1
        n_nodes = n if n_nodes is None else n_nodes
        comm = self.c_per_n * (n - 1) + self.c_size
        coord = self.gamma_per_n * (n - 1)
        theta = self.theta_fn(n_nodes)
        return (self.k * self.t1 / n + (1 - self.k) * self.t1 +
                self.s_cost + comm + coord + self.fixed - theta)

    def speedup(self, n: int) -> float:
        return self.t1 / self.t_n(n)                          # Eq. 3.7

    def efficiency(self, n: int) -> float:
        return self.speedup(n) / n                            # Eq. 3.8

    def improvement_pct(self, n: int) -> float:
        return (1.0 - 1.0 / self.speedup(n)) * 100.0          # Eq. 3.10

    def curve(self, ns: List[int]) -> List[float]:
        return [self.t_n(n) for n in ns]

    def regime(self, ns: List[int]) -> str:
        """Classify into the thesis's §5.1.1 scalability cases."""
        ts = self.curve(ns)
        diffs = [b - a for a, b in zip(ts, ts[1:])]
        signs = [d < 0 for d in diffs]
        if all(signs):
            return "positive"
        if not any(signs):
            return "negative"
        # count sign changes
        changes = sum(1 for a, b in zip(signs, signs[1:]) if a != b)
        if changes == 1 and signs[0]:
            return "positive-then-negative"
        return "complex"


def model_from_roofline(t1: float, k: float, *, coll_bytes_per_step: float,
                        link_bw: float = 50e9, hops: int = 1,
                        latency_per_hop: float = 1e-6, n_collectives: int = 0,
                        reshard_bytes: float = 0.0, hbm_bw: float = 819e9,
                        fixed: float = 50e-6,
                        working_set_bytes: float = 0.0,
                        hbm_per_node: float = 16 * 2 ** 30) -> SpeedupModel:
    """Wire Eq. 3.6's terms to dry-run measurables (DESIGN.md §2)."""
    def theta(n_nodes: int) -> float:
        # resource gain: once the working set fits in n·HBM, spill vanishes
        if working_set_bytes <= 0:
            return 0.0
        if n_nodes * hbm_per_node >= working_set_bytes:
            return 0.15 * t1      # spill/paging penalty recovered
        return 0.0

    return SpeedupModel(
        t1=t1, k=k,
        s_cost=reshard_bytes / hbm_bw,
        c_per_n=(coll_bytes_per_step / link_bw) * 0.05,
        c_size=coll_bytes_per_step / link_bw,
        gamma_per_n=latency_per_hop * max(n_collectives, 0) * hops,
        fixed=fixed, theta_fn=theta)
