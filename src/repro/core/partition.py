"""PartitionUtil — the paper's partitioning machinery (§4.1.3), TPU-adapted.

Cloud²Sim tracks each distributed data structure with per-instance ID ranges
computed from an instance *offset* (``getPartitionInit``/``getPartitionFinal``,
ported verbatim below), and hashes keys onto 271 virtual partitions
(``partitionOf(key) % 271``) that are re-balanced when instances join/leave.
Here the "instances" are mesh devices (or data-axis shards) and the virtual
partitions make elastic re-sharding cheap: when the shard count changes, only
the moved virtual partitions' data re-homes (consistent-hashing property).

Key hashing is DETERMINISTIC across processes: ``zlib.crc32`` for str/bytes
keys and plain modulo for ints, so a partition table built on one controller
reproduces bit-for-bit on any member regardless of ``PYTHONHASHSEED``
(Python's randomized str hash would silently re-home every string key between
runs — the classic split-brain the thesis's IAtomicLong guards against).
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import List, Tuple, Union

import numpy as np

DEFAULT_PARTITION_COUNT = 271  # Hazelcast's default, kept for fidelity


def get_partition_init(no_of_params: int, offset: int, n_instances: int) -> int:
    """Initial ID of instance ``offset``'s partition (paper §4.1.3; clipped so
    surplus members get empty partitions when members > items)."""
    return min(int(offset * math.ceil(no_of_params / float(n_instances))),
               no_of_params)


def get_partition_final(no_of_params: int, offset: int, n_instances: int) -> int:
    """Final (exclusive) ID of instance ``offset``'s partition (paper §4.1.3)."""
    temp = int((offset + 1) * math.ceil(no_of_params / float(n_instances)))
    return temp if temp < no_of_params else no_of_params


def partition_ranges(no_of_params: int, n_instances: int) -> List[Tuple[int, int]]:
    return [(get_partition_init(no_of_params, i, n_instances),
             get_partition_final(no_of_params, i, n_instances))
            for i in range(n_instances)]


def key_partition(key: Union[int, str, bytes],
                  partition_count: int = DEFAULT_PARTITION_COUNT) -> int:
    """key -> virtual partition, Hazelcast's data partition table.

    Process-independent: str/bytes keys go through ``zlib.crc32`` (stable,
    documented to be consistent across platforms and Python versions); int
    keys are taken modulo directly.  Never uses ``hash()``, whose str variant
    is salted by ``PYTHONHASHSEED``.
    """
    if isinstance(key, str):
        key = key.encode("utf-8")
    if isinstance(key, (bytes, bytearray)):
        return zlib.crc32(key) % partition_count
    return int(key) % partition_count


@dataclasses.dataclass
class PartitionTable:
    """Virtual-shard table: 271 partitions -> owner instance.

    ``rebalance(n)`` reassigns with minimal movement (partitions keep their
    owner when possible — the paper's "minimal reshuffling of objects when a
    new instance joins"): only partitions on departed members or on members
    above the balanced ceiling re-home.
    """
    partition_count: int = DEFAULT_PARTITION_COUNT
    n_instances: int = 1

    def __post_init__(self):
        self.owner = np.arange(self.partition_count) % self.n_instances

    def owner_of(self, key: Union[int, str, bytes]) -> int:
        return int(self.owner[key_partition(key, self.partition_count)])

    def owners_of_range(self, n_keys: int) -> np.ndarray:
        """Vectorized owner lookup for int keys 0..n_keys-1 — the VM→member
        map the elastic scan core ships to devices as a runtime operand."""
        parts = np.arange(n_keys, dtype=np.int64) % self.partition_count
        return self.owner[parts].astype(np.int32)

    def rebalance(self, n_instances: int, weights=None) -> int:
        """Returns the number of virtual partitions that moved (kept minimal:
        only partitions on departed or overfull members re-home).

        ``weights`` (optional, length ``partition_count``) makes the
        rebalance LOCALITY-AWARE: members level by total partition *weight*
        instead of partition count.  The dispatcher passes observed per-key
        load (e.g. the scan core's ``exchange_load``) through
        ``partition_weights_from_keys`` so a hot key's partition stops
        dragging a full share of cold partitions onto its member.  With
        ``weights=None`` the exact count-leveling behavior (and its minimal-
        movement bound) is unchanged."""
        if n_instances < 1:
            raise ValueError(f"n_instances must be >= 1, got {n_instances}")
        if weights is not None:
            return self._rebalance_weighted(n_instances, weights)
        counts = np.bincount(self.owner[self.owner < n_instances],
                             minlength=n_instances)
        moved = 0
        # 1) re-home partitions of departed members (forced moves)
        for p in range(self.partition_count):
            if self.owner[p] >= n_instances:
                new_o = int(np.argmin(counts))
                self.owner[p] = new_o
                counts[new_o] += 1
                moved += 1
        # 2) level: move from the fullest to the emptiest until balanced.
        # Each move comes off a member strictly above the final balanced
        # level, so the count of moves is exactly the surviving members'
        # excess over that level — no gratuitous shuffling.
        while counts.max() - counts.min() > 1:
            src, dst = int(np.argmax(counts)), int(np.argmin(counts))
            p = int(np.nonzero(self.owner == src)[0][0])
            self.owner[p] = dst
            counts[src] -= 1
            counts[dst] += 1
            moved += 1
        self.n_instances = n_instances
        return moved

    def _rebalance_weighted(self, n_instances: int, weights) -> int:
        """Weighted leveling: greedy longest-processing-time moves from the
        heaviest member to the lightest while the move strictly shrinks the
        load spread.  Zero-weight partitions carry a tiny uniform epsilon so
        they still spread out instead of piling anywhere for free."""
        w = np.maximum(np.asarray(weights, np.float64), 0.0)
        if w.shape != (self.partition_count,):
            raise ValueError(f"weights must have shape "
                             f"({self.partition_count},), got {w.shape}")
        w = w + max(w.sum(), 1.0) / (self.partition_count * 100.0)
        load = np.zeros(n_instances, np.float64)
        np.add.at(load, self.owner[self.owner < n_instances],
                  w[self.owner < n_instances])
        moved = 0
        # 1) forced: departed members' partitions, heaviest first, onto the
        # currently lightest member
        departed = np.nonzero(self.owner >= n_instances)[0]
        for p in departed[np.argsort(-w[departed])]:
            dst = int(np.argmin(load))
            self.owner[p] = dst
            load[dst] += w[p]
            moved += 1
        # 2) level by weight: fill the lightest member from the heaviest
        # source that can improve (a member whose only partition is an
        # irreducibly hot one is skipped, not a stopping point), picking the
        # partition whose weight best halves the src→dst gap; stop when no
        # move improves the spread
        for _ in range(4 * self.partition_count):
            dst = int(np.argmin(load))
            best = None
            for src in map(int, np.argsort(-load)):
                gap = load[src] - load[dst]
                if src == dst or gap <= 0:
                    break                  # no heavier source can improve
                cand = np.nonzero(self.owner == src)[0]
                ok = cand[w[cand] < gap]   # strictly reduces the spread
                if ok.size:
                    best = (src, int(ok[np.argmin(np.abs(gap - 2.0 * w[ok]))]))
                    break
            if best is None:
                break
            src, p = best
            self.owner[p] = dst
            load[src] -= w[p]
            load[dst] += w[p]
            moved += 1
        self.n_instances = n_instances
        return moved

    def load(self) -> np.ndarray:
        return np.bincount(self.owner, minlength=self.n_instances)

    # ------------------------------------------------- durable snapshot
    def snapshot(self) -> dict:
        """JSON-able image of the table — journaled per scale event so a
        coordinator restart can rebuild the exact ownership map instead of
        re-deriving placement (which a locality-aware rebalance would not
        reproduce: the observed key weights died with the coordinator)."""
        return {"partition_count": int(self.partition_count),
                "n_instances": int(self.n_instances),
                "owner": self.owner.tolist()}

    def restore(self, snap: dict) -> None:
        """Inverse of ``snapshot``.  Validates shape and owner range loudly
        (a snapshot from a different table layout must never be applied
        silently — the resume path turns the ValueError into a
        ``ResumeMismatchError``)."""
        owner = np.asarray(snap["owner"], dtype=self.owner.dtype)
        if int(snap["partition_count"]) != self.partition_count \
                or owner.shape != (self.partition_count,):
            raise ValueError(
                f"snapshot has partition_count {snap['partition_count']}, "
                f"table has {self.partition_count}")
        n = int(snap["n_instances"])
        if n < 1 or owner.min() < 0 or owner.max() >= n:
            raise ValueError("snapshot owners out of range for its "
                             f"n_instances={n}")
        self.n_instances = n
        self.owner = owner


def partition_weights_from_keys(key_weights,
                                partition_count: int = DEFAULT_PARTITION_COUNT
                                ) -> np.ndarray:
    """Aggregate observed per-key load (int keys 0..n-1, the VM ids of
    ``owners_of_range``) into per-virtual-partition weights for
    ``PartitionTable.rebalance(..., weights=...)``."""
    kw = np.asarray(key_weights, np.float64)
    out = np.zeros(partition_count, np.float64)
    np.add.at(out, np.arange(kw.shape[0]) % partition_count, kw)
    return out


def pad_to_shards(n: int, shards: int) -> int:
    """Global length padded so every shard holds an equal slice."""
    return ((n + shards - 1) // shards) * shards


# ---------------------------------------------- owner-keyed exchange capacity
#
# The distributed scan core re-homes each cloudlet to the member owning its
# VM with one padded all-to-all.  The exchange buffer is (n_shards, block)
# per source member: member s sends at most ``block`` cloudlets to each
# destination, so a destination receives at most ``n_shards * block``.  The
# helpers below size ``block`` — either heuristically from a slack factor
# over the balanced expectation, or exactly from the observed ownership map.

DEFAULT_EXCHANGE_SLACK = 2.0


def exchange_block_size(n_items: int, n_shards: int,
                        slack: float = DEFAULT_EXCHANGE_SLACK) -> int:
    """Per-(source, destination) block size for the owner-keyed all-to-all.

    Balanced ownership sends ``shard / n_shards`` items per (src, dst) pair;
    ``slack`` multiplies that expectation to absorb skew.  Clamped to the
    shard size (a source cannot send more than its whole shard to one
    destination, so ``slack >= n_shards`` always suffices)."""
    shard = pad_to_shards(max(n_items, 1), n_shards) // n_shards
    block = int(math.ceil(shard * slack / n_shards))
    return max(1, min(block, shard))


def exchange_load(vm_owner, vm_assign, valid, n_shards: int) -> np.ndarray:
    """Owner histogram of the exchange: (n_shards, n_shards) counts of valid
    cloudlets member ``src`` must send to member ``dst = vm_owner[assign]``.
    ``load.max()`` is the exact per-(src, dst) block size the all-to-all
    needs; ``load.sum(axis=0)`` is the per-member received (= scanned)
    cloudlet count."""
    owner = np.asarray(vm_owner)
    assign = np.asarray(vm_assign)
    valid = np.asarray(valid).astype(bool)
    shard = pad_to_shards(max(assign.shape[0], 1), n_shards) // n_shards
    src = np.arange(assign.shape[0]) // shard
    dst = owner[assign]
    load = np.zeros((n_shards, n_shards), np.int64)
    np.add.at(load, (src[valid], dst[valid]), 1)
    return load
