import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell against
ShapeDtypeStruct inputs (no allocation), record memory/cost analysis and the
optimized HLO for the roofline pass.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all [--mesh pod1|pod2|both] [--out DIR]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs
from repro.launch import mesh as mesh_lib
from repro.launch.specs import (cache_specs, decode_token_specs,
                                pick_microbatches, train_batch_specs)
from repro.models.model import build_model
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.optimizer import AdamWConfig
from repro.train.step import abstract_train_state, make_train_step


def lower_cell(arch: str, shape_name: str, mesh, *, moe_impl="sliced",
               extra=None):
    """Returns (lowered, meta) for one cell."""
    from repro.models.shard_ctx import sharding_rules
    cfg = get_config(arch)
    extra = extra or {}
    overrides = {}
    if moe_impl == "ep" and cfg.is_moe:
        tp = mesh.shape.get("model", 1)
        if cfg.n_experts % tp == 0:
            overrides = {"exp": "model", "moe_ff": None}
    extra = dict(extra, overrides=overrides)
    with sharding_rules(cfg.policy, mesh, fsdp_pod=extra.get("fsdp_pod", False),
                        **overrides):
        return _lower_cell_inner(arch, shape_name, mesh, moe_impl=moe_impl,
                                 extra=extra)


def _lower_cell_inner(arch: str, shape_name: str, mesh, *, moe_impl="sliced",
                      extra=None):
    import dataclasses as _dc
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    extra = extra or {}
    if extra.get("capacity_factor"):
        cfg = _dc.replace(cfg, capacity_factor=extra["capacity_factor"])
        import repro.configs.base as _b
        _b._REGISTRY[cfg.name] = cfg
    model = build_model(cfg, moe_impl=moe_impl,
                        remat=extra.get("remat", True),
                        opts=extra.get("opts"))
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "params": cfg.param_count(), "active_params": cfg.active_param_count(),
            "policy": cfg.policy, "moe_impl": moe_impl}

    if shape.kind == "train":
        import jax.numpy as _jnp
        nm = extra.get("n_microbatch") or pick_microbatches(cfg, shape, mesh)
        meta["n_microbatch"] = nm
        mdt = getattr(_jnp, extra.get("moments_dtype", "float32"))
        opt_cfg = AdamWConfig(moments_dtype=extra.get("moments_dtype", "float32"))
        gdt = getattr(_jnp, extra.get("grad_dtype", "float32") or "float32")
        step = make_train_step(model, opt_cfg, n_microbatch=nm, grad_dtype=gdt)
        state_sh = mesh_lib.state_shardings(
            model, mesh, fsdp_pod=extra.get("fsdp_pod", False),
            overrides=extra.get("overrides"))
        state_abs = abstract_train_state(model, moments_dtype=mdt)
        bspecs, bshard = train_batch_specs(cfg, shape, mesh)
        lowered = jax.jit(step, in_shardings=(state_sh, bshard),
                          out_shardings=(state_sh, None),
                          donate_argnums=(0,)).lower(state_abs, bspecs)
        return lowered, meta

    model_bf16 = build_model(cfg, moe_impl=moe_impl, remat=False,
                             opts=extra.get("opts"))
    param_sh = mesh_lib.param_shardings(model_bf16, mesh,
                                        overrides=extra.get("overrides"))
    from repro.models.param import abstract_params
    p_abs = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
        abstract_params(model_bf16.defs()))
    import jax.numpy as _j
    cache_dtype = getattr(_j, extra.get("cache_dtype", "bfloat16"))
    caches_abs, cache_sh = cache_specs(model_bf16, shape, mesh,
                                       dtype=cache_dtype)

    if shape.kind == "prefill":
        step = make_prefill_step(model_bf16)
        bspecs, bshard = train_batch_specs(cfg, shape, mesh)
        bspecs.pop("labels"), bspecs.pop("mask")
        bshard.pop("labels"), bshard.pop("mask")
        lowered = jax.jit(step, in_shardings=(param_sh, bshard, cache_sh),
                          out_shardings=(None, cache_sh),
                          donate_argnums=(2,)).lower(p_abs, bspecs, caches_abs)
        return lowered, meta

    # decode: one new token against a cache of seq_len
    step = make_decode_step(model_bf16)
    tok_abs, tok_sh = decode_token_specs(cfg, shape, mesh)
    len_abs = jax.ShapeDtypeStruct((), jnp.int32)
    len_sh = NamedSharding(mesh, P())
    lowered = jax.jit(step, in_shardings=(param_sh, cache_sh, tok_sh, len_sh),
                      out_shardings=(tok_sh, cache_sh),
                      donate_argnums=(1,)).lower(p_abs, caches_abs, tok_abs,
                                                 len_abs)
    return lowered, meta


def run_cell(arch, shape_name, mesh, mesh_name, out_dir=None, save_hlo=True,
             moe_impl="sliced", extra=None, tag=""):
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, mesh, moe_impl=moe_impl,
                               extra=extra)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    txt = compiled.as_text()
    colls = {k: txt.count(k + "(") + txt.count(k + "-start(")
             for k in ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute")}
    meta.update({
        "mesh": mesh_name, "tag": tag,
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "arg_bytes": int(ma.argument_size_in_bytes),
        "out_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "peak_gb": round((ma.argument_size_in_bytes + ma.temp_size_in_bytes +
                          ma.output_size_in_bytes -
                          ma.alias_size_in_bytes) / 2 ** 30, 3),
        "ca_flops_per_dev_while_once": ca.get("flops"),
        "ca_bytes_per_dev_while_once": ca.get("bytes accessed"),
        "collective_op_counts": colls,
    })
    if out_dir and save_hlo:
        import zstandard as zstd
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}_{shape_name}_{mesh_name}{('_' + tag) if tag else ''}"
        with open(os.path.join(out_dir, name + ".hlo.zst"), "wb") as f:
            f.write(zstd.ZstdCompressor(level=3).compress(txt.encode()))
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(meta, f, indent=1)
    return meta


def all_cells():
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in cfg.shapes():
            yield arch, shape.name


def pp_smoke(out_dir=None):
    """Pipeline-parallel dry-run: a llama3-8b-proportioned layer stack
    pipelined over mesh (4,8,16) = ("pipe","data","model") — 512 chips."""
    import jax.numpy as _jnp
    from repro.train.pipeline import pipelined_apply
    from repro.core.compat import AXIS_TYPE_AUTO, make_mesh
    mesh = make_mesh((4, 8, 16), ("pipe", "data", "model"),
                     axis_types=(AXIS_TYPE_AUTO,) * 3)
    L, B, S, D, F = 32, 64, 4096, 4096, 14336

    def layer_fn(p, h):
        hn = h * jax.lax.rsqrt(
            _jnp.mean(h * h, -1, keepdims=True) + 1e-6)
        up = _jnp.dot(hn, p["w_in"].astype(_jnp.bfloat16))
        return h + _jnp.dot(jax.nn.silu(up),
                            p["w_out"].astype(_jnp.bfloat16))

    params = {"w_in": jax.ShapeDtypeStruct((L, D, F), _jnp.bfloat16),
              "w_out": jax.ShapeDtypeStruct((L, F, D), _jnp.bfloat16)}
    x = jax.ShapeDtypeStruct((B, S, D), _jnp.bfloat16)

    def step(p, x_):
        return pipelined_apply(layer_fn, p, x_, mesh, n_microbatch=8)

    t0 = time.time()
    lowered = jax.jit(step).lower(params, x)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    peak = (ma.argument_size_in_bytes + ma.temp_size_in_bytes +
            ma.output_size_in_bytes - ma.alias_size_in_bytes) / 2 ** 30
    txt = compiled.as_text()
    cp = txt.count("collective-permute(") + txt.count("collective-permute-start(")
    meta = {"arch": "pp-smoke-llama-proportioned", "mesh": "pipe4_data8_model16",
            "compile_s": round(time.time() - t0, 1), "peak_gb": round(peak, 2),
            "collective_permutes": cp}
    print(f"[OK]   pp-smoke (4,8,16) compile={meta['compile_s']}s "
          f"peak={meta['peak_gb']}GB collective-permutes={cp}", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "pp_smoke.json"), "w") as f:
            json.dump(meta, f, indent=1)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-impl", default="sliced")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--pp-smoke", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--grad-dtype", default="float32")
    ap.add_argument("--scores-bf16", action="store_true")
    ap.add_argument("--no-attn-chunk-remat", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--moments-dtype", default="float32")
    ap.add_argument("--cache-dtype", default="bfloat16")
    ap.add_argument("--fsdp-pod", action="store_true")
    args = ap.parse_args()
    if args.pp_smoke:
        pp_smoke(out_dir=args.out)
        raise SystemExit(0)
    extra = {"moments_dtype": args.moments_dtype, "fsdp_pod": args.fsdp_pod,
             "cache_dtype": args.cache_dtype, "grad_dtype": args.grad_dtype,
             "capacity_factor": args.capacity_factor}
    opts = {}
    if args.scores_bf16:
        opts["scores_bf16"] = True
    if args.q_chunk:
        opts["q_chunk"] = args.q_chunk
    if args.no_attn_chunk_remat:
        opts["attn_chunk_remat"] = False
    if opts:
        extra["opts"] = opts
    if args.microbatch:
        extra["n_microbatch"] = args.microbatch

    meshes = []
    if args.mesh in ("pod1", "both"):
        meshes.append(("pod1", mesh_lib.make_production_mesh(multi_pod=False)))
    if args.mesh in ("pod2", "both"):
        meshes.append(("pod2", mesh_lib.make_production_mesh(multi_pod=True)))

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    failures = 0
    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            try:
                meta = run_cell(arch, shape, mesh, mesh_name, out_dir=args.out,
                                save_hlo=not args.no_hlo,
                                moe_impl=args.moe_impl, tag=args.tag,
                                extra=extra)
                print(f"[OK]   {arch:24s} {shape:12s} {mesh_name} "
                      f"compile={meta['compile_s']:7.1f}s "
                      f"peak={meta['peak_gb']:7.2f}GB "
                      f"colls={meta['collective_op_counts']}", flush=True)
            except Exception as e:
                failures += 1
                print(f"[FAIL] {arch:24s} {shape:12s} {mesh_name}: {e!r}",
                      flush=True)
                traceback.print_exc()
    # note skipped long_500k cells for full-attention archs
    for arch in list_archs():
        cfg = get_config(arch)
        for s in cfg.skipped_shapes():
            print(f"[SKIP] {arch:24s} {s.name:12s} (full-attention arch; "
                  "see DESIGN.md §4)", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
