"""``input_specs`` — ShapeDtypeStruct stand-ins for every model input of every
(arch × shape) cell, plus the matching shardings.  Weak-type-correct,
shardable, zero allocation (the dry-run lowers against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch import mesh as mesh_lib


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg, shape, mesh):
    B, S = shape.global_batch, shape.seq_len
    b = mesh_lib.batch_axes(mesh)
    sh = lambda ndim: NamedSharding(mesh, P(b, *([None] * (ndim - 1))))
    specs, shards = {}, {}
    text_S = S
    if cfg.frontend == "vision_stub":
        text_S = S - cfg.frontend_tokens
        specs["patches"] = _sds((B, cfg.frontend_tokens, cfg.frontend_dim),
                                jnp.float32)
        shards["patches"] = sh(3)
    if cfg.is_encdec:
        specs["frames"] = _sds((B, S, cfg.frontend_dim), jnp.float32)
        shards["frames"] = sh(3)
    specs.update({"tokens": _sds((B, text_S), jnp.int32),
                  "labels": _sds((B, S), jnp.int32),
                  "mask": _sds((B, S), jnp.float32)})
    shards.update({"tokens": sh(2), "labels": sh(2), "mask": sh(2)})
    return specs, shards


def cache_specs(model, shape, mesh, dtype=jnp.bfloat16):
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    cross = S if cfg.is_encdec else 0
    caches = jax.eval_shape(
        lambda: model.make_caches(B, max_len=S, cross_len=cross, dtype=dtype))
    shards = mesh_lib.cache_shardings(model, mesh, B, caches_tree=caches)
    return caches, shards


def decode_token_specs(cfg, shape, mesh):
    B = shape.global_batch
    shard_b = B >= mesh_lib.data_axis_size(mesh)
    sh = mesh_lib.batch_sharding(mesh, 2, shard_batch=shard_b)
    return _sds((B, 1), jnp.int32), sh


def input_specs(arch: str, shape_name: str, mesh, model=None):
    """All inputs for the cell's step: {"kind", "args": (specs...), "shardings"}."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        specs, shards = train_batch_specs(cfg, shape, mesh)
        return {"kind": "train", "batch": specs, "batch_shardings": shards}
    if shape.kind == "prefill":
        specs, shards = train_batch_specs(cfg, shape, mesh)
        specs.pop("labels"), specs.pop("mask")
        shards.pop("labels"), shards.pop("mask")
        return {"kind": "prefill", "batch": specs, "batch_shardings": shards}
    return {"kind": "decode"}


def pick_microbatches(cfg, shape, mesh, budget_bytes: float = 2.0e9) -> int:
    """Grad-accum factor bounding per-device saved activations (remat carries:
    ~n_layers × B_local/n × S × d_model × 2B)."""
    dp = mesh_lib.data_axis_size(mesh)
    b_local = max(shape.global_batch // dp, 1)
    per = cfg.n_layers * b_local * shape.seq_len * cfg.d_model * 2
    n = 1
    while per / n > budget_bytes and n < b_local:
        n *= 2
    return n
