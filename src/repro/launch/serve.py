"""Serving driver: continuous batching with the thesis's two brokers.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --requests 12 --slots 4 --policy matchmaking
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serve.scheduler import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--policy", default="matchmaking",
                    choices=["matchmaking", "round_robin"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    engine = ServeEngine(model, params, n_slots=args.slots,
                         max_len=args.max_len, policy=args.policy)
    for i in range(args.requests):
        plen = int(rng.integers(2, args.max_len // 4))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        engine.sched.submit(Request(req_id=i, prompt=prompt,
                                    max_new_tokens=int(rng.integers(2, 8))))
    t0 = time.time()
    out = engine.run(max_steps=256)
    wall = time.time() - t0
    print(f"policy={args.policy} completed={len(out['completed'])}/"
          f"{args.requests} steps={out['steps']} dropped={out['dropped']} "
          f"wall={wall:.1f}s")
    for r in out["completed"][:4]:
        print(f"  req {r.req_id}: prompt[{len(r.prompt)}] -> {r.output}")
    return out


if __name__ == "__main__":
    main()
