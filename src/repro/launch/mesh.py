"""Production meshes and sharding resolution.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (16,16) = ("data","model") — 256 chips.
Multi-pod: (2,16,16) = ("pod","data","model") — 512 chips; the "pod" axis is
pure data parallelism in the paper-faithful baseline (pods ≈ Cloud²Sim
clusters; cross-pod traffic limited to gradient reduction).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import AXIS_TYPE_AUTO, make_mesh
from repro.models.param import axis_rules, resolve_shardings, resolve_spec


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AXIS_TYPE_AUTO,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AXIS_TYPE_AUTO,) * 2)


# ------------------------------------------------------------- sharding trees

def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_axis_size(mesh: Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def param_shardings(model, mesh: Mesh, fsdp_pod: bool = False,
                    overrides: dict = None):
    return resolve_shardings(model.defs(), model.cfg.policy, mesh,
                             fsdp_pod=fsdp_pod, overrides=overrides)


def state_shardings(model, mesh: Mesh, fsdp_pod: bool = False,
                    overrides: dict = None):
    p = param_shardings(model, mesh, fsdp_pod=fsdp_pod, overrides=overrides)
    return {"params": p, "opt": {"m": p, "v": p},
            "step": NamedSharding(mesh, P())}


def batch_sharding(mesh: Mesh, ndim: int, *, shard_batch=True):
    b = batch_axes(mesh) if shard_batch else None
    return NamedSharding(mesh, P(b, *([None] * (ndim - 1))))


def cache_shardings(model, mesh: Mesh, batch: int, caches_tree=None):
    """Shardings for the stacked cache pytree.

    Large-batch decode: batch over (pod,data), heads/channels over model.
    Small-batch long-context (B < data extent): KV sequence over data (SP) —
    distributed flash-decode emerges from the SPMD partial-softmax reduction.
    """
    cfg = model.cfg
    seq_parallel = batch < data_axis_size(mesh)
    b_ax = None if seq_parallel else batch_axes(mesh)
    # KV sequence is ALWAYS sharded over "model" (distributed flash-decode:
    # the softmax over the sharded KV axis lowers to partial-sum+all-reduce);
    # long-context small-batch cells additionally take the "data" axis (SP).
    s_ax = ("data", "model") if seq_parallel else "model"

    def spec_for(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(n in ("k", "v") for n in names):        # (L,B,S,KV,hd)
            return P(None, b_ax, s_ax, None, None)
        if "state" in names:                           # (L,B,H,P,N)
            return P(None, b_ax, "model" if cfg.policy == "tp" else None,
                     None, None)
        if "conv_x" in names:                          # (L,B,w-1,C)
            return P(None, b_ax, None, "model" if cfg.policy == "tp" else None)
        if "conv_bc" in names:
            return P(None, b_ax, None, None)
        return P(*([None] * leaf.ndim))

    if caches_tree is None:   # structure template only
        caches_tree = jax.eval_shape(lambda: model.make_caches(batch, max_len=8))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for(path, leaf)),
        caches_tree)
