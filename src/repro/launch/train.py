"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 300 \
      --batch 8 --seq 512 [--reduced] [--elastic] [--ckpt DIR]

Runs the real loop on the local devices: data pipeline → jitted train step →
health monitor → (optional) adaptive scaling and checkpointing.  ``--reduced``
shrinks the arch to its smoke-test config (same family) for CPU runs.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.health import HealthConfig
from repro.data.pipeline import DataConfig
from repro.models.model import build_model
from repro.train.elastic_runner import run_elastic_training
from repro.train.optimizer import AdamWConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0,
                    help="override depth (0 = arch default)")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--elastic", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--target-step-time", type=float, default=1.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        over = {}
        if args.layers:
            over["n_layers"] = args.layers
        if args.d_model:
            over["d_model"] = args.d_model
            over["n_heads"] = max(args.d_model // 64, 1)
            over["n_kv_heads"] = max(args.d_model // 128, 1)
            over["head_dim"] = 64
            over["d_ff"] = args.d_model * 3
        if args.vocab:
            over["vocab_size"] = args.vocab
        cfg = reduced(cfg, **over)

    model = build_model(cfg, remat=True, xent_chunk=min(128, args.seq))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    health = HealthConfig(target_step_time=args.target_step_time)
    t0 = time.time()
    report = run_elastic_training(
        model, steps=args.steps, data_cfg=data_cfg,
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                            total_steps=args.steps),
        health_cfg=health,
        ckpt_dir=args.ckpt or None,
        start_instances=len(jax.devices()) if args.elastic else
        len(jax.devices()))
    wall = time.time() - t0

    n = args.log_every
    for i in range(0, len(report.losses), n):
        print(f"step {i:5d} loss {report.losses[i]:.4f}")
    print(f"final loss {report.losses[-1]:.4f} | {args.steps} steps in "
          f"{wall:.1f}s ({args.steps * args.batch * args.seq / wall:.0f} tok/s)"
          f" | params {cfg.param_count() / 1e6:.1f}M | "
          f"scale events {report.scale_events}")
    return report


if __name__ == "__main__":
    main()
