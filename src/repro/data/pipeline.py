"""Data pipeline: deterministic synthetic LM stream + MapReduce-backed corpus.

Determinism contract (fault tolerance): batch ``i`` depends only on
``(seed, i)`` via ``fold_in`` — a restarted or re-scaled job resumes from its
``data_cursor`` and sees byte-identical data regardless of the member count
(the thesis's "output consistent as if simulating in a single instance").

The word-count corpus path feeds the MapReduce engine (the paper's default
job) and doubles as a frequency-calibrated sampler: batches are drawn from
the empirical token distribution that MapReduce computed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234


def synthetic_batch(cfg_data: DataConfig, step: int, model_cfg=None) -> Dict:
    """Markov-ish synthetic tokens: learnable structure (loss can fall)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg_data.seed), step)
    B, S, V = cfg_data.global_batch, cfg_data.seq_len, cfg_data.vocab_size
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(k1, (B, S + 1), 0, V)
    # inject learnable bigram structure: every odd position copies prev+1
    pos = jnp.arange(S + 1)
    shifted = jnp.roll(base, 1, axis=1) + 1
    toks = jnp.where((pos % 2 == 1)[None, :], shifted % V, base)
    batch = {"tokens": toks[:, :-1].astype(jnp.int32),
             "labels": toks[:, 1:].astype(jnp.int32),
             "mask": jnp.ones((B, S), jnp.float32)}
    if model_cfg is not None:
        batch = adapt_batch_for_arch(batch, model_cfg, key=k2)
    return batch


def adapt_batch_for_arch(batch, cfg, key=None):
    """Attach frontend-stub inputs (patch/frame embeddings) per the arch."""
    B, S = batch["labels"].shape
    key = key if key is not None else jax.random.PRNGKey(0)
    if cfg.frontend == "vision_stub":
        n = cfg.frontend_tokens
        batch = dict(batch)
        batch["tokens"] = batch["tokens"][:, : S - n]
        batch["patches"] = jax.random.normal(key, (B, n, cfg.frontend_dim),
                                             jnp.float32)
        mask = batch["mask"].at[:, :n].set(0.0)   # no loss on patch positions
        batch["mask"] = mask
    elif cfg.is_encdec:
        batch = dict(batch)
        batch["frames"] = jax.random.normal(key, (B, S, cfg.frontend_dim),
                                            jnp.float32)
    return batch


class DataPipeline:
    """Cursor-addressable batch source with shard placement."""

    def __init__(self, cfg_data: DataConfig, model_cfg=None, sharding=None):
        self.cfg = cfg_data
        self.model_cfg = model_cfg
        self.sharding = sharding
        self.cursor = 0

    def at(self, step: int) -> Dict:
        b = synthetic_batch(self.cfg, step, self.model_cfg)
        if self.sharding is not None:
            b = {k: jax.device_put(v, self.sharding.get(k))
                 if self.sharding.get(k) is not None else v
                 for k, v in b.items()}
        return b

    def __iter__(self) -> Iterator[Dict]:
        while True:
            yield self.at(self.cursor)
            self.cursor += 1


def corpus_calibrated_stream(mesh, n_files=8, file_len=4096, vocab=1024,
                             backend="hazelcast", use_kernel=False):
    """Word-count-driven pipeline: MapReduce computes global token frequencies
    (the paper's default job), and the stream samples from that distribution."""
    from repro.core.mapreduce import (MapReduceEngine, make_corpus,
                                      word_count_job)
    corpus = make_corpus(n_files, file_len, vocab)
    eng = MapReduceEngine(mesh, backend=backend)
    counts = eng.run(word_count_job(vocab, use_kernel=use_kernel),
                     jnp.asarray(corpus))
    freq = np.asarray(counts, np.float64)
    freq = freq / freq.sum()

    def sample(key, shape):
        return jax.random.choice(key, vocab, shape=shape, p=jnp.asarray(freq))

    return sample, counts
