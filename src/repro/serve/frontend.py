"""Multi-tenant serve front end — admission control, weighted-fair
scheduling, overload shedding, and per-tenant fault isolation.

The thesis's conclusion pitches the elastic layer as "a general purpose
auto scaler middleware for a multi-tenanted deployment"; this module is
that front door.  Many concurrent tenants submit scenario grids and
MapReduce jobs as ``TenantRequest``s; the ``TenantFrontEnd`` turns them
into streams on ONE shared ``ElasticDispatcher`` so a single
``CompileCache`` amortizes compiles across tenants hitting the same
(geometry, signature).  The pipeline is the classic serving middleware
shape (net thread → admission queue → worker):

  admission   per-tenant token-bucket quotas + per-tenant and global
              backlog bounds.  Every refusal is a STRUCTURED
              ``AdmissionDecision`` with a reason code — journaled,
              counted in stats, never a silent drop.
  scheduling  deficit round-robin (DRR) over per-tenant queues: each
              rotation visit grants ``quantum × weight`` deficit and a
              queue head is served once its cost (chunk count) is
              covered.  Deficits persist while a tenant waits, so every
              admitted, feasible request is eventually served — the
              no-starvation property tests/test_frontend.py pins.
              Priorities also ride the WEIGHTED partition rebalance: a
              request carrying ``key_weights`` feeds
              ``observe_key_weights(weights × tenant.weight)`` so the
              next scale event levels partitions by tenant-weighted load.
  isolation   each stream runs under the submitting tenant's
              ``RetryPolicy`` budget and deadline, and is bound to the
              tenant for fault injection (``submit(tenant=...)``): a
              tenant-addressed fault fires ONLY inside that tenant's
              stream, the failure is a structured ``JobFailedError``
              (journal intact when the request checkpoints), the quota is
              debited, and every other tenant's results are bit-identical
              to isolated single-tenant runs.
  shedding    SLO-aware degradation: when the measured M/M/n load
              (``mmn_load`` over the admission-rate/service QueueSnapshot)
              passes ``HealthConfig.shed_utilization`` WITH the cluster
              already at ``max_instances``, queued (never in-flight)
              requests of the lowest-priority tenants are shed first —
              each shed is a journaled, RESUMABLE drain marker
              (``reclaim_shed`` re-queues the parked work), not lost work.
  scaling     the same QueueSnapshot feeds ``ElasticController.
              tick_queue`` between requests, so ``policy="mmn"`` scale
              events fire under live multi-tenant traffic.

See docs/serving.md for the tenancy model and guarantees.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dispatch import DispatchJob, ElasticDispatcher
from repro.core.faults import FaultInjector, JobFailedError, RetryPolicy
from repro.core.journal import CheckpointPolicy
from repro.core.stats import DispatchStats, QueueSnapshot, mmn_load

# admission / shedding reason codes (AdmissionDecision.reason)
REASONS = ("admitted", "unknown_tenant", "quota_exhausted", "backlog_full",
           "tenant_backlog_full", "deadline_expired", "shed_overload")


@dataclasses.dataclass
class TenantRequest:
    """One unit of tenant work: a ``DispatchJob`` plus its item pytree —
    exactly what ``ElasticDispatcher.submit`` consumes, so a request built
    by ``grid_request``/``mapreduce_request`` goes through the SAME job
    and operand normalization as a direct single-tenant run (the
    bit-identity guarantee rides on that).  ``key_weights`` (optional
    per-key load, e.g. a grid's per-VM exchange load) makes the next
    rebalance tenant-priority-aware; ``checkpoint`` journals the stream
    so a failed request's post-mortem survives."""
    tenant: str
    job: DispatchJob
    items: object
    chunk: Optional[int] = None
    replicated: tuple = ()
    key_weights: Optional[np.ndarray] = None
    checkpoint: Optional[CheckpointPolicy] = None
    deadline_s: Optional[float] = None      # overrides the tenant default
    tag: str = ""                           # caller-visible label
    # assigned at admission:
    req_id: int = -1
    t_admit: float = float("nan")

    @property
    def n_items(self) -> int:
        import jax
        leaves = jax.tree_util.tree_leaves(self.items)
        return int(leaves[0].shape[0]) if leaves else 0


@dataclasses.dataclass
class AdmissionDecision:
    """The structured outcome of one admission (or shedding) decision —
    the serve layer's contract that load is never silently dropped."""
    admitted: bool
    reason: str                              # one of REASONS
    tenant: str
    req_id: int = -1
    detail: str = ""
    retry_after_s: float = 0.0               # quota refill hint (0 = n/a)

    def __post_init__(self):
        if self.reason not in REASONS:
            raise ValueError(f"unknown reason {self.reason!r}")


class TokenBucket:
    """Per-tenant admission quota: ``rate`` tokens/s up to ``burst``.
    Clock-injected so tests are deterministic."""

    def __init__(self, rate: float, burst: float):
        if burst <= 0:
            raise ValueError("burst must be > 0")
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._t_last: Optional[float] = None

    def _refill(self, now: float) -> None:
        if self._t_last is not None and np.isfinite(self.rate):
            self.tokens = min(self.burst,
                              self.tokens + (now - self._t_last) * self.rate)
        elif self._t_last is not None:
            self.tokens = self.burst
        self._t_last = now

    def take(self, now: float, n: float = 1.0) -> bool:
        self._refill(now)
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False

    def debit(self, n: float) -> None:
        """Penalty charge (a failed job costs quota even though it never
        completed) — floors at zero, never goes negative."""
        self.tokens = max(0.0, self.tokens - n)

    def retry_after(self, n: float = 1.0) -> float:
        if self.rate <= 0 or not np.isfinite(self.rate):
            return 0.0
        return max(0.0, (n - self.tokens) / self.rate)


@dataclasses.dataclass
class TenantState:
    """Everything the front end tracks per tenant."""
    name: str
    weight: float = 1.0            # DRR bandwidth share (quantum multiplier)
    priority: int = 0              # shed order: LOWEST priority sheds first
    bucket: TokenBucket = None     # admission quota
    retry_policy: Optional[RetryPolicy] = None
    deadline_s: Optional[float] = None   # admit-to-dispatch deadline
    max_queue: Optional[int] = None      # per-tenant backlog bound
    queue: Deque[TenantRequest] = dataclasses.field(
        default_factory=collections.deque)
    deficit: float = 0.0
    results: Dict[int, object] = dataclasses.field(default_factory=dict)
    reports: Dict[int, object] = dataclasses.field(default_factory=dict)
    failures: List[dict] = dataclasses.field(default_factory=list)
    shed: List[TenantRequest] = dataclasses.field(default_factory=list)
    stats: DispatchStats = dataclasses.field(
        default_factory=lambda: DispatchStats(warmup=0, serialized=False))
    admitted: int = 0
    completed: int = 0
    rejected: int = 0

    def backlog_cost(self) -> int:
        return len(self.queue)


class TenantFrontEnd:
    """The request-serving loop over one shared ``ElasticDispatcher``.

    Single-threaded by design (JAX dispatch is already async under each
    stream): ``submit`` admits, ``step`` serves exactly one request
    through DRR, ``run`` drains until idle.  Callers interleave
    ``submit``/``step`` to model continuous load.
    """

    def __init__(self, dispatcher: Optional[ElasticDispatcher] = None, *,
                 devices=None, health_cfg=None, start_members: int = 1,
                 backlog_max: int = 64, quantum: float = 1.0,
                 shed_target: Optional[int] = None,
                 journal_root: Optional[str] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 clock: Callable[[], float] = time.monotonic):
        if dispatcher is None:
            from repro.core.health import HealthConfig
            dispatcher = ElasticDispatcher(
                devices=devices, start_members=start_members,
                health_cfg=health_cfg or HealthConfig(policy="mmn"))
        self.dispatcher = dispatcher
        self.clock = clock
        self.backlog_max = int(backlog_max)
        self.quantum = float(quantum)
        # shed drains the global backlog down to this level (queued work
        # only — in-flight streams always finish)
        self.shed_target = (max(0, self.backlog_max // 2)
                            if shed_target is None else int(shed_target))
        self.fault_injector = fault_injector
        self.tenants: Dict[str, TenantState] = collections.OrderedDict()
        self._order: List[str] = []          # DRR rotation order
        self._rr = 0                         # rotation cursor
        self._granted = False                # cursor tenant got its grant?
        self._seq = 0                        # global req_id counter
        # OPEN-system serve stats: enqueue = admission, dispatch = stream
        # start, retire = stream end; parallel-server semantics
        self.stats = DispatchStats(warmup=0, serialized=False)
        self.rejections: List[AdmissionDecision] = []
        self.journal_records: List[dict] = []
        self._journal_path = (None if journal_root is None else
                              os.path.join(journal_root, "frontend.jsonl"))
        self._admit_times: Deque[float] = collections.deque(maxlen=128)
        self._service_s: Deque[float] = collections.deque(maxlen=64)

    # ------------------------------------------------------------- tenancy
    def register_tenant(self, name: str, *, weight: float = 1.0,
                        priority: int = 0, rate: float = float("inf"),
                        burst: float = 8.0,
                        retry_policy: Optional[RetryPolicy] = None,
                        deadline_s: Optional[float] = None,
                        max_queue: Optional[int] = None) -> TenantState:
        """Register (or re-configure) a tenant.  ``weight`` scales its DRR
        quantum, ``priority`` orders shedding (lowest sheds first),
        ``rate``/``burst`` parameterize its admission token bucket."""
        if weight <= 0:
            raise ValueError("weight must be > 0")
        st = TenantState(name=name, weight=float(weight),
                         priority=int(priority),
                         bucket=TokenBucket(rate, burst),
                         retry_policy=retry_policy, deadline_s=deadline_s,
                         max_queue=max_queue)
        if name not in self.tenants:
            self._order.append(name)
        self.tenants[name] = st
        return st

    def backlog(self) -> int:
        return sum(len(s.queue) for s in self.tenants.values())

    # ----------------------------------------------------------- admission
    def submit(self, req: TenantRequest) -> AdmissionDecision:
        """Admission control: quota, per-tenant bound, global bound — in
        that order.  Admitted requests join their tenant's queue; every
        refusal is journaled, counted, and returned structured."""
        now = self.clock()
        st = self.tenants.get(req.tenant)
        if st is None:
            return self._reject(req, "unknown_tenant",
                                detail=f"tenant {req.tenant!r} not "
                                       "registered")
        if not st.bucket.take(now):
            return self._reject(
                req, "quota_exhausted", st,
                retry_after_s=st.bucket.retry_after(),
                detail=f"token bucket empty (rate={st.bucket.rate}/s)")
        if st.max_queue is not None and len(st.queue) >= st.max_queue:
            return self._reject(req, "tenant_backlog_full", st,
                                detail=f"{len(st.queue)} queued >= "
                                       f"max_queue={st.max_queue}")
        if self.backlog() >= self.backlog_max:
            return self._reject(req, "backlog_full", st,
                                detail=f"global backlog at "
                                       f"{self.backlog_max}")
        self._seq += 1
        req.req_id = self._seq
        req.t_admit = now
        st.queue.append(req)
        st.admitted += 1
        self._admit_times.append(now)
        return AdmissionDecision(admitted=True, reason="admitted",
                                 tenant=req.tenant, req_id=req.req_id)

    def _reject(self, req: TenantRequest, reason: str,
                st: Optional[TenantState] = None, *, detail: str = "",
                retry_after_s: float = 0.0) -> AdmissionDecision:
        dec = AdmissionDecision(admitted=False, reason=reason,
                                tenant=req.tenant, req_id=req.req_id,
                                detail=detail, retry_after_s=retry_after_s)
        self.rejections.append(dec)
        self.stats.record_rejection(reason)
        if st is not None:
            st.rejected += 1
            st.stats.record_rejection(reason)
        self._journal({"event": "reject", "tenant": req.tenant,
                       "req_id": req.req_id, "reason": reason,
                       "detail": detail})
        return dec

    # ---------------------------------------------------------------- DRR
    def _cost(self, req: TenantRequest) -> int:
        """A request's scheduling cost in CHUNKS — the dispatch-work unit,
        so weights are fair in device time, not request count."""
        b = max(req.n_items, 1)
        chunk = req.chunk or self.dispatcher.chunk_size or b
        return max(-(-b // max(int(chunk), 1)), 1)

    def _advance(self) -> None:
        self._rr += 1
        self._granted = False

    def _pick(self) -> Optional[Tuple[TenantState, TenantRequest]]:
        """Classic DRR, one request per call: a FRESH visit to a nonempty
        tenant grants ``quantum × weight`` deficit exactly once; the
        cursor stays on the tenant while its deficit covers queue heads
        (so a double-weight tenant serves twice as much work per rotation)
        and advances when the deficit runs out.  Deficits persist across
        rotations while a tenant waits — starvation-freedom for feasible
        requests of any cost — and reset when its queue empties so idle
        tenants can't bank credit."""
        if not any(s.queue for s in self.tenants.values()):
            return None
        n = len(self._order)
        for _ in range(2 * n + 1):
            name = self._order[self._rr % n]
            st = self.tenants[name]
            if not st.queue:
                st.deficit = 0.0
                self._advance()
                continue
            if not self._granted:
                st.deficit += self.quantum * st.weight
                self._granted = True
            if st.deficit >= self._cost(st.queue[0]):
                req = st.queue.popleft()
                st.deficit -= self._cost(req)
                if not st.queue:
                    st.deficit = 0.0
                    self._advance()
                return st, req
            self._advance()
        # a full rotation of grants covered no head (every queued request
        # costs many quanta): top up the tenant at the cursor until its
        # head is covered — progress beats exact proportionality here
        while True:
            st = self.tenants[self._order[self._rr % n]]
            if st.queue:
                break
            self._advance()
        while st.deficit < self._cost(st.queue[0]):
            st.deficit += self.quantum * st.weight
        req = st.queue.popleft()
        st.deficit -= self._cost(req)
        if not st.queue:
            st.deficit = 0.0
            self._advance()
        return st, req

    # --------------------------------------------------------------- serve
    def step(self) -> Optional[dict]:
        """Serve exactly ONE queued request end to end (or return None when
        idle): DRR pick → deadline check → tenant-scoped dispatch →
        stats + scaling feed → shed check.  A tenant's ``JobFailedError``
        is contained here: recorded, quota-debited, journaled — the loop
        (and every other tenant) continues."""
        while True:
            picked = self._pick()
            if picked is None:
                return None
            st, req = picked
            deadline = (req.deadline_s if req.deadline_s is not None
                        else st.deadline_s)
            t0 = self.clock()
            if deadline is not None and t0 - req.t_admit > deadline:
                self._reject(req, "deadline_expired", st,
                             detail=f"waited {t0 - req.t_admit:.3f}s > "
                                    f"deadline {deadline}s")
                continue
            return self._serve(st, req, t0)

    def _serve(self, st: TenantState, req: TenantRequest,
               t0: float) -> dict:
        d = self.dispatcher
        if req.key_weights is not None:
            # tenant priority rides the weighted rebalance: hot keys of a
            # heavier tenant pull proportionally more placement correction
            d.observe_key_weights(np.asarray(req.key_weights, np.float64)
                                  * st.weight)
        outcome = {"tenant": st.name, "req_id": req.req_id, "tag": req.tag,
                   "ok": False, "error": None}
        try:
            out, report = d.submit(
                req.job, req.items, replicated=req.replicated,
                chunk=req.chunk, retry_policy=st.retry_policy,
                fault_injector=self.fault_injector,
                checkpoint=req.checkpoint, tenant=st.name)
            t1 = self.clock()
            st.results[req.req_id] = out
            st.reports[req.req_id] = report
            st.completed += 1
            outcome.update(ok=True, wall_s=t1 - t0)
        except JobFailedError as e:
            # per-tenant fault containment: structured failure record, the
            # report (journal already written by the dispatcher when the
            # request checkpoints), a quota penalty — and the loop lives on
            t1 = self.clock()
            st.bucket.debit(1.0)
            failure = {"req_id": req.req_id, "tenant": st.name,
                       "error": e, "report": e.report,
                       "journal_path": e.report.journal_path}
            st.failures.append(failure)
            self._journal({"event": "fail", "tenant": st.name,
                           "req_id": req.req_id, "detail": str(e),
                           "journal_path": e.report.journal_path})
            outcome.update(error=e, wall_s=t1 - t0)
        # latency stamping (admission → start → end) for both views
        for coll in (self.stats, st.stats):
            coll.record(req.req_id, t_enqueue=req.t_admit, t_dispatch=t0,
                        t_retire=t1)
        self._service_s.append(max(t1 - t0, 1e-9))
        # the queue-aware feed (scale events under live traffic) and the
        # SLO shedding knee are mmn-policy features: the ema policy has no
        # arrival/service model to judge the measured snapshot against
        if d.health_cfg.policy == "mmn":
            snap = self._queue_snapshot()
            if snap is not None:
                d.controller.tick_queue(snap)   # mmn scale under live load
                self._maybe_shed(snap)
        return outcome

    def run(self, max_requests: Optional[int] = None) -> List[dict]:
        """Drain the queues: ``step`` until idle (or ``max_requests``)."""
        outcomes = []
        while max_requests is None or len(outcomes) < max_requests:
            o = self.step()
            if o is None:
                break
            outcomes.append(o)
        return outcomes

    # ------------------------------------------------------------ shedding
    def _queue_snapshot(self) -> Optional[QueueSnapshot]:
        if len(self._admit_times) < 2 or not self._service_s:
            return None
        span = self._admit_times[-1] - self._admit_times[0]
        if span <= 0:
            return None
        lam = (len(self._admit_times) - 1) / span
        s_n = float(np.mean(self._service_s))   # cluster service time/req
        n = max(self.dispatcher.n_members, 1)
        mu1 = 1.0 / (s_n * n)                   # per-member rate (linear)
        return QueueSnapshot(arrival_rate=lam, service_rate=mu1,
                             n_members=n, queue_length=float(self.backlog()))

    def _maybe_shed(self, snap: QueueSnapshot) -> List[AdmissionDecision]:
        """SLO-aware degradation: past the knee AND already at max scale,
        park queued requests of the lowest-priority tenants (newest first
        within a tenant) until the backlog reaches ``shed_target``.  Every
        shed is a journaled, resumable drain marker — ``reclaim_shed``
        re-queues the work; nothing is lost."""
        cfg = self.dispatcher.health_cfg
        knee = getattr(cfg, "shed_utilization", 1.0)
        if knee >= 1.0:
            return []
        load = mmn_load(snap, cfg.max_threshold, cfg.mmn_queue_cap)
        at_max = self.dispatcher.n_members >= cfg.max_instances
        if load < knee or not at_max:
            return []
        shed: List[AdmissionDecision] = []
        order = sorted(self.tenants.values(), key=lambda s: s.priority)
        for st in order:
            while st.queue and self.backlog() > self.shed_target:
                req = st.queue.pop()             # newest first: oldest work
                st.shed.append(req)              # survives for reclaim
                dec = self._reject(
                    req, "shed_overload", st,
                    detail=f"mmn load {load:.2f} >= knee {knee} at "
                           f"max_instances={cfg.max_instances}; parked "
                           f"resumable (reclaim_shed)")
                self._journal({"event": "shed_marker", "tenant": st.name,
                               "req_id": req.req_id, "resumable": True})
                shed.append(dec)
            if self.backlog() <= self.shed_target:
                break
        return shed

    def reclaim_shed(self, tenant: str) -> int:
        """Resume a tenant's parked drain markers: shed requests rejoin the
        FRONT of its queue in original admission order, free of quota (they
        were already paid for).  Returns how many were re-queued."""
        st = self.tenants[tenant]
        parked, st.shed = st.shed, []
        for req in sorted(parked, key=lambda r: r.req_id, reverse=True):
            st.queue.appendleft(req)
            self._journal({"event": "reclaim", "tenant": tenant,
                           "req_id": req.req_id})
        return len(parked)

    # --------------------------------------------------------- observability
    def _journal(self, record: dict) -> None:
        record = {"t": self.clock(), **record}
        self.journal_records.append(record)
        if self._journal_path is None:
            return
        os.makedirs(os.path.dirname(self._journal_path), exist_ok=True)
        with open(self._journal_path, "a") as f:
            f.write(json.dumps(record, default=str) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def summary(self) -> dict:
        """The serve-level SLO view: global + per-tenant admission,
        latency, failure, and shed accounting, plus the shared-cluster
        amortization counters (one CompileCache across tenants)."""
        d = self.dispatcher
        per_tenant = {}
        for name, st in self.tenants.items():
            s = st.stats.summary(n_servers=max(d.n_members, 1))
            per_tenant[name] = {
                "admitted": st.admitted, "completed": st.completed,
                "rejected": st.rejected, "failed": len(st.failures),
                "shed": len(st.shed), "queued": len(st.queue),
                "priority": st.priority, "weight": st.weight,
                "sojourn_p50": s["sojourn"].get("hist_p50"),
                "sojourn_p99": s["sojourn"].get("hist_p99"),
                "rejections": dict(st.stats.rejections),
            }
        return {
            "backlog": self.backlog(),
            "n_members": d.n_members,
            "scale_events": len(d.scale_events),
            "cache": {"hits": d.cache.hits, "builds": d.cache.builds},
            "tenants": per_tenant,
            "stats": self.stats.summary(n_servers=max(d.n_members, 1)),
        }


# ------------------------------------------------------------ request builders

def grid_request(tenant: str, cfg, grid, **kw) -> TenantRequest:
    """A scenario-grid request: goes through the SAME ``grid_batch_args``
    job/operand normalization as ``run_scenario_grid``, so a tenant's
    multi-tenant results are bit-identical to its isolated run."""
    from repro.core.des_scan import grid_batch_args
    args, job, _ = grid_batch_args(cfg, grid)
    return TenantRequest(tenant=tenant, job=job, items=args, **kw)


def mapreduce_request(tenant: str, job, files, *,
                      backend: str = "hazelcast", **kw) -> TenantRequest:
    """A MapReduce request via the module-level ``dispatch_job_for`` —
    tenants sharing one ``MapReduceJob`` object share one executable in
    the front end's CompileCache."""
    from repro.core.mapreduce import dispatch_job_for
    return TenantRequest(tenant=tenant,
                         job=dispatch_job_for(job, backend), items=files,
                         **kw)
