"""Serving layer: the continuous-batching engine (``scheduler``) and the
multi-tenant front end (``frontend``) — see docs/serving.md."""
from repro.serve.frontend import (AdmissionDecision, TenantFrontEnd,
                                  TenantRequest, TokenBucket, grid_request,
                                  mapreduce_request)

__all__ = ["AdmissionDecision", "TenantFrontEnd", "TenantRequest",
           "TokenBucket", "grid_request", "mapreduce_request"]
