"""Serving steps: prefill and single-token decode (continuous-batching inner
loops).  ``serve_step`` here is what the decode_* / long_* dry-run cells lower:
one new token against a KV/SSM cache of the cell's seq_len."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _mask_pad(logits, vocab):
    ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(ids < vocab, logits, -jnp.inf)


def sample_tokens(logits, key, *, temperature: float = 0.0, top_k: int = 0,
                  top_p: float = 1.0):
    """Sample next tokens from (B, V) logits.

    temperature == 0 -> greedy.  top_k: keep the k best; top_p: nucleus
    sampling (smallest set with cumulative probability >= top_p).
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep tokens until cumulative prob reaches top_p (always >= 1 token)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def make_prefill_step(model):
    def prefill_step(params, batch, caches):
        logits, caches = model.prefill(params, batch, caches)
        logits = _mask_pad(logits, model.cfg.vocab_size)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, caches
    return prefill_step


def make_decode_step(model, sample: str = "greedy"):
    def decode_step(params, caches, tokens, cache_len):
        logits, caches = model.decode(params, tokens, caches, cache_len)
        logits = _mask_pad(logits, model.cfg.vocab_size)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], caches
    return decode_step


def make_sampling_decode_step(model, *, temperature: float = 1.0,
                              top_k: int = 0, top_p: float = 1.0):
    """Decode step with temperature/top-k/nucleus sampling (serving mode)."""
    def decode_step(params, caches, tokens, cache_len, key):
        logits, caches = model.decode(params, tokens, caches, cache_len)
        logits = _mask_pad(logits, model.cfg.vocab_size)
        nxt = sample_tokens(logits[:, -1], key, temperature=temperature,
                            top_k=top_k, top_p=top_p)
        return nxt[:, None], caches
    return decode_step
