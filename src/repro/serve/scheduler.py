"""Continuous-batching request schedulers — the thesis's two brokers, serving.

Requests ≙ cloudlets, KV-cache slots ≙ VMs.  The scheduler binds queued
requests to free slots:

  * ``round_robin``  — next free slot in order (§5.1.1's RR broker).
  * ``matchmaking``  — slots live in size buckets (max context length); a
    request binds to the *smallest adequate* bucket, round-robining within the
    candidates so large slots aren't monopolized (§5.1.2's fair matchmaking).

The decode loop is a single jitted step over the whole slot batch; finished
slots are refilled between steps (continuous batching).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stats import DispatchStats


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray           # (prompt_len,) int32
    max_new_tokens: int
    arrived_step: int = 0
    # filled by the engine:
    slot: int = -1
    placed_step: int = -1        # decode step the broker bound the slot
    output: Optional[List[int]] = None
    done: bool = False
    # set when the scheduler refuses the request (e.g. over max_len): the
    # structured record {req_id, reason, ...} — never a silent drop
    rejection: Optional[dict] = None


@dataclasses.dataclass
class SlotState:
    length: int = 0              # valid cache length
    budget: int = 0              # remaining new tokens
    req: Optional[Request] = None

    @property
    def free(self) -> bool:
        return self.req is None


class Scheduler:
    def __init__(self, n_slots: int, max_len: int, policy: str = "matchmaking",
                 bucket_lens: Optional[List[int]] = None):
        assert policy in ("round_robin", "matchmaking")
        self.policy = policy
        self.max_len = max_len
        self.slots = [SlotState() for _ in range(n_slots)]
        # matchmaking buckets: slot i serves contexts up to bucket_lens[i]
        if bucket_lens is None:
            bucket_lens = [max_len // 4] * (n_slots // 2) + \
                          [max_len] * (n_slots - n_slots // 2)
        self.bucket_lens = bucket_lens
        self.queue: Deque[Request] = deque()
        self._rr_cursor = 0
        self._mm_counter = 0
        # structured rejections: one {req_id, reason, need, max_len} per
        # refused request (reason "over_max_len" for the infeasible drop)
        self.rejected: List[dict] = []

    @property
    def dropped(self) -> int:
        """Back-compat count of refused requests (len of ``rejected``)."""
        return len(self.rejected)

    def submit(self, req: Request):
        self.queue.append(req)

    # ----------------------------------------------------------- brokers
    def _assign_round_robin(self, req) -> int:
        n = len(self.slots)
        for off in range(n):
            i = (self._rr_cursor + off) % n
            if self.slots[i].free and self.bucket_lens[i] >= self._need(req):
                self._rr_cursor = (i + 1) % n
                return i
        return -1

    def _assign_matchmaking(self, req) -> int:
        need = self._need(req)
        # adequate free slots, smallest bucket first (best fit)
        cand = sorted((self.bucket_lens[i], i) for i, s in enumerate(self.slots)
                      if s.free and self.bucket_lens[i] >= need)
        if not cand:
            return -1
        # fairness: round-robin among equally-best candidates
        best_len = cand[0][0]
        ties = [i for l, i in cand if l == best_len]
        pick = ties[self._mm_counter % len(ties)]
        self._mm_counter += 1
        return pick

    def _need(self, req) -> int:
        return len(req.prompt) + req.max_new_tokens

    def schedule(self) -> List[Request]:
        """Bind queued requests to free slots; returns newly placed requests."""
        placed = []
        pending = len(self.queue)
        for _ in range(pending):
            req = self.queue.popleft()
            need = self._need(req)
            if need > self.max_len:
                req.rejection = {"req_id": req.req_id,
                                 "reason": "over_max_len",
                                 "need": need, "max_len": self.max_len}
                self.rejected.append(req.rejection)
                continue
            slot = (self._assign_round_robin(req) if self.policy == "round_robin"
                    else self._assign_matchmaking(req))
            if slot < 0:
                self.queue.append(req)       # stay queued (waiting queue)
                continue
            req.slot = slot
            req.output = []
            self.slots[slot] = SlotState(length=0, budget=req.max_new_tokens,
                                         req=req)
            placed.append(req)
        return placed

    def release(self, slot: int):
        self.slots[slot] = SlotState()

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def utilization(self) -> float:
        return 1.0 - sum(s.free for s in self.slots) / len(self.slots)


class ServeEngine:
    """Continuous batching over a fixed slot batch with one jitted decode."""

    def __init__(self, model, params, n_slots: int, max_len: int,
                 policy: str = "matchmaking"):
        from repro.serve.step import make_decode_step
        self.model = model
        self.params = params
        self.sched = Scheduler(n_slots, max_len, policy)
        self.caches = model.make_caches(n_slots, max_len)
        self.lengths = np.zeros(n_slots, np.int32)
        self.tokens = np.zeros((n_slots, 1), np.int32)
        self._decode = jax.jit(make_decode_step(model))
        self.steps = 0
        # OPEN-stream queueing stats in decode-STEP units (slots are the
        # parallel servers, so service is placement-to-completion verbatim):
        # enqueue = arrived_step, dispatch = placed_step, retire/validate =
        # completion step.  No warm-up trim — request streams are short and
        # every sojourn is a real, user-visible latency.
        self.stats = DispatchStats(warmup=0, serialized=False)

    def _prefill_one(self, req: Request):
        """Prefill a single request into its slot (per-slot cache update)."""
        toks = jnp.asarray(req.prompt, jnp.int32).reshape(1, -1)
        nxt = None
        for t in range(toks.shape[1]):
            nxt, self.caches = self._decode(
                self.params, self.caches,
                jnp.full((len(self.sched.slots), 1), 0, jnp.int32).at[
                    req.slot, 0].set(int(req.prompt[t])),
                jnp.int32(t))
        self.lengths[req.slot] = len(req.prompt)
        # empty prompt: nothing to condition on, so decode starts from a
        # zero token (the BOS analogue) instead of reading an unbound `nxt`
        self.tokens[req.slot, 0] = (0 if nxt is None
                                    else int(np.asarray(nxt)[req.slot, 0]))

    def run(self, max_steps: int = 64) -> Dict:
        done: List[Request] = []
        n_rej_seen = len(self.sched.rejected)
        while self.steps < max_steps:
            for req in self.sched.schedule():
                req.placed_step = self.steps
                self._prefill_one(req)
            # surface this round's refusals in the SLO stats immediately
            for rej in self.sched.rejected[n_rej_seen:]:
                self.stats.record_rejection(rej["reason"])
            n_rej_seen = len(self.sched.rejected)
            if not self.sched.active_slots():
                if not self.sched.queue:
                    break
                self.steps += 1
                continue
            cache_len = int(self.lengths.max())
            nxt, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(self.tokens),
                jnp.int32(cache_len))
            nxt = np.asarray(nxt)
            self.steps += 1
            for i in self.sched.active_slots():
                s = self.sched.slots[i]
                s.req.output.append(int(nxt[i, 0]))
                self.tokens[i, 0] = nxt[i, 0]
                self.lengths[i] += 1
                s.budget -= 1
                if s.budget <= 0:
                    s.req.done = True
                    self.stats.record(
                        s.req.req_id, t_enqueue=float(s.req.arrived_step),
                        t_dispatch=float(max(s.req.placed_step,
                                             s.req.arrived_step)),
                        t_retire=float(self.steps))
                    done.append(s.req)
                    self.sched.release(i)
        return {"completed": done, "steps": self.steps,
                "dropped": self.sched.dropped,
                "rejected": list(self.sched.rejected),
                "utilization": self.sched.utilization(),
                "stats": self.stats.summary(
                    n_servers=len(self.sched.slots))}
