"""Layer-stack assembly: scan-over-layers decoders (uniform, hybrid-period),
encoders, and encoder-decoder stacks.

All stacks scan over stacked per-layer params (compile-once bodies — essential
for the 80-cell dry-run on this 1-core container).  Heterogeneous archs:

  * gemma3 local/global — uniform param structure; a per-layer ``is_global``
    flag selects between two statically-shaped attention variants via
    ``lax.cond`` inside the scan body.
  * jamba 1:7 attn:mamba + alternating MoE — period-8 "super-block" scan; the
    8 slots are unrolled inside the body (their kinds are consistent across
    periods since kind(i) depends only on i mod 8 / i mod 2).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp, mlp_defs, rmsnorm, rmsnorm_defs
from repro.models.param import ParamDef, stack_defs


# --------------------------------------------------------------- single layer

def layer_defs(cfg, kind: str, mlp_kind: str, cross: bool = False):
    d = cfg.d_model
    defs: Dict[str, Any] = {"norm1": rmsnorm_defs(d)}
    if kind.startswith("attn"):
        defs["attn"] = attn_mod.attention_defs(cfg)
    else:
        defs["ssm"] = ssm_mod.ssm_defs(cfg)
    if cross:
        defs["norm_x"] = rmsnorm_defs(d)
        defs["cross"] = attn_mod.cross_attention_defs(cfg)
    if mlp_kind == "dense":
        defs["norm2"] = rmsnorm_defs(d)
        defs["mlp"] = mlp_defs(d, cfg.d_ff)
    elif mlp_kind == "moe":
        defs["norm2"] = rmsnorm_defs(d)
        defs["moe"] = moe_mod.moe_defs(cfg)
    return defs


def apply_layer(params, x, cfg, kind: str, mlp_kind: str, *, window: int = 0,
                causal: bool = True, cache=None, cache_len=None, enc_out=None,
                mode: str = "train", impl: str = "xla", moe_impl: str = "sliced",
                compute_dtype=jnp.bfloat16, opts=None):
    """One block. cache: per-layer cache slice (attn {k,v} or ssm state)."""
    from repro.models.shard_ctx import constrain
    eps = cfg.norm_eps
    new_cache = {}
    x = constrain(x, ("batch", "act_seq", None))
    h = rmsnorm(params["norm1"], x, eps)
    if kind.startswith("attn"):
        out, kv = attn_mod.multihead_attention(
            params["attn"], h, cfg, causal=causal, window=window,
            kv_cache=None if cache is None else cache.get("kv"),
            cache_len=cache_len, impl=impl, compute_dtype=compute_dtype,
            opts=opts)
        if kv is not None:
            new_cache["kv"] = kv
    else:
        out, st = ssm_mod.ssm_block(
            params["ssm"], h, cfg,
            ssm_cache=None if cache is None else cache.get("ssm"),
            compute_dtype=compute_dtype)
        if st is not None:
            new_cache["ssm"] = st
    x = x + out

    if "cross" in params:
        h = rmsnorm(params["norm_x"], x, eps)
        if mode == "decode":
            out, _ = attn_mod.multihead_attention(
                params["cross"], h, cfg, causal=False,
                kv_cache=cache["cross"], cache_len=cache_len,
                static_cache=True, impl=impl, compute_dtype=compute_dtype)
            new_cache["cross"] = cache["cross"]
        else:
            # build the cross K/V cache from encoder output
            out, ck = attn_mod.multihead_attention(
                params["cross"], h, cfg, causal=False, kv_override=enc_out,
                kv_cache={"k": jnp.zeros_like(enc_out, shape=(
                    enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads,
                    cfg.head_dim)), "v": jnp.zeros_like(enc_out, shape=(
                        enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads,
                        cfg.head_dim))},
                cache_len=0, impl=impl, compute_dtype=compute_dtype)
            new_cache["cross"] = ck
        x = x + out

    if mlp_kind == "dense":
        x = x + mlp(params["mlp"], rmsnorm(params["norm2"], x, eps),
                    compute_dtype)
    elif mlp_kind == "moe":
        x = x + moe_mod.moe_block(params["moe"], rmsnorm(params["norm2"], x, eps),
                                  cfg, compute_dtype=compute_dtype,
                                  moe_impl=moe_impl)
    return x, (new_cache or None)


# ------------------------------------------------------------- cache builders

def make_layer_cache(cfg, kind: str, batch: int, max_len: int, cross_len: int = 0,
                     dtype=jnp.bfloat16):
    c = {}
    if kind.startswith("attn"):
        c["kv"] = {
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype)}
    else:
        c["ssm"] = ssm_mod.make_ssm_cache(cfg, batch, dtype)
    if cross_len:
        c["cross"] = {
            "k": jnp.zeros((batch, cross_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, cross_len, cfg.n_kv_heads, cfg.head_dim), dtype)}
    return c


# ----------------------------------------------------------- uniform decoder

def uniform_stack_defs(cfg, cross: bool = False):
    kinds, mlps = cfg.layer_kinds(), cfg.mlp_kinds()
    base = layer_defs(cfg, kinds[0], mlps[0], cross=cross)
    return stack_defs(base, cfg.n_layers)


def _is_uniform(cfg) -> bool:
    return not cfg.is_hybrid


def apply_uniform_stack(params, x, cfg, *, caches=None, cache_len=None,
                        enc_out=None, mode="train", impl="xla",
                        moe_impl="sliced", remat=True,
                        compute_dtype=jnp.bfloat16, opts=None):
    """Scan over n_layers with stacked params. caches: stacked layer caches."""
    kinds, mlps = cfg.layer_kinds(), cfg.mlp_kinds()
    kind0, mlp0 = kinds[0], mlps[0]
    has_global_mix = len(set(kinds)) > 1          # gemma3 local/global
    is_global = jnp.asarray(
        np.array([k == "attn_global" for k in kinds], dtype=bool))

    def body(h, xs):
        p, cache_i, glob_i = xs
        kw = dict(cache=cache_i, cache_len=cache_len, enc_out=enc_out,
                  mode=mode, impl=impl, moe_impl=moe_impl,
                  compute_dtype=compute_dtype, opts=opts)
        if has_global_mix:
            h2, nc = jax.lax.cond(
                glob_i,
                lambda hh: apply_layer(p, hh, cfg, "attn", mlp0, window=0, **kw),
                lambda hh: apply_layer(p, hh, cfg, "attn", mlp0,
                                       window=cfg.sliding_window, **kw),
                h)
        else:
            win = cfg.sliding_window if kind0 == "attn_local" else 0
            h2, nc = apply_layer(p, h, cfg, kind0, mlp0, window=win, **kw)
        return h2, nc

    wrapped = jax.checkpoint(body, prevent_cse=False) if remat else body
    xs = (params, caches, is_global)
    x, new_caches = jax.lax.scan(wrapped, x, xs)
    return x, new_caches


# ----------------------------------------------------------- hybrid (period)

def hybrid_stack_defs(cfg):
    kinds, mlps = cfg.layer_kinds(), cfg.mlp_kinds()
    period = cfg.attn_interval
    assert cfg.n_layers % period == 0, "hybrid depth must be multiple of period"
    n_periods = cfg.n_layers // period
    slot_defs = {f"slot_{s}": layer_defs(cfg, kinds[s], mlps[s])
                 for s in range(period)}
    return stack_defs(slot_defs, n_periods)


def apply_hybrid_stack(params, x, cfg, *, caches=None, cache_len=None,
                       mode="train", impl="xla", moe_impl="sliced", remat=True,
                       compute_dtype=jnp.bfloat16, opts=None):
    kinds, mlps = cfg.layer_kinds(), cfg.mlp_kinds()
    period = cfg.attn_interval

    def body(h, xs):
        p, cache_p = xs
        new_caches = {}
        for s in range(period):
            ci = None if cache_p is None else cache_p.get(f"slot_{s}")
            h, nc = apply_layer(
                p[f"slot_{s}"], h, cfg, kinds[s], mlps[s],
                cache=ci, cache_len=cache_len, mode=mode, impl=impl,
                moe_impl=moe_impl, compute_dtype=compute_dtype, opts=opts)
            if nc is not None:
                new_caches[f"slot_{s}"] = nc
        return h, (new_caches or None)

    wrapped = jax.checkpoint(body, prevent_cse=False) if remat else body
    x, new_caches = jax.lax.scan(wrapped, x, (params, caches))
    return x, new_caches


# ------------------------------------------------------------------- encoder

def encoder_stack_defs(cfg):
    base = {"norm1": rmsnorm_defs(cfg.d_model),
            "attn": attn_mod.attention_defs(cfg),
            "norm2": rmsnorm_defs(cfg.d_model),
            "mlp": mlp_defs(cfg.d_model, cfg.d_ff)}
    return stack_defs(base, cfg.encoder_layers)


def apply_encoder_stack(params, x, cfg, *, impl="xla", remat=True,
                        compute_dtype=jnp.bfloat16):
    def body(h, p):
        a, _ = attn_mod.multihead_attention(
            p["attn"], rmsnorm(p["norm1"], h, cfg.norm_eps), cfg,
            causal=False, impl=impl, compute_dtype=compute_dtype)
        h = h + a
        h = h + mlp(p["mlp"], rmsnorm(p["norm2"], h, cfg.norm_eps),
                    compute_dtype)
        return h, None

    wrapped = jax.checkpoint(body, prevent_cse=False) if remat else body
    x, _ = jax.lax.scan(wrapped, x, params)
    return x


def stack_defs_for(cfg):
    if cfg.is_hybrid:
        return hybrid_stack_defs(cfg)
    return uniform_stack_defs(cfg, cross=cfg.is_encdec)


def apply_stack(params, x, cfg, **kw):
    if cfg.is_hybrid:
        kw.pop("enc_out", None)
        return apply_hybrid_stack(params, x, cfg, **kw)
    return apply_uniform_stack(params, x, cfg, **kw)


def make_stack_caches(cfg, batch: int, max_len: int, cross_len: int = 0,
                      dtype=jnp.bfloat16):
    """Stacked caches matching the scan layout."""
    kinds = cfg.layer_kinds()
    if cfg.is_hybrid:
        period = cfg.attn_interval
        n_periods = cfg.n_layers // period
        one = {f"slot_{s}": make_layer_cache(cfg, kinds[s], batch, max_len,
                                             dtype=dtype)
               for s in range(period)}
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (n_periods,) + a.shape).copy(),
            one)
    one = make_layer_cache(cfg, kinds[0], batch, max_len, cross_len, dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(),
        one)
