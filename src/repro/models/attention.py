"""Attention: GQA with RoPE, sliding-window/global variants, prefill/decode.

Two implementations share one signature:
  * ``impl="xla"``   — pure-jnp, query-chunked (bounded score memory); used by the
                       dry-run/roofline path (CPU container) and as the oracle.
  * ``impl="pallas"``— flash kernel from ``repro.kernels.flash_attention`` (TPU
                       target; validated in interpret mode by the kernel tests).

Sliding-window layers slice K/V to the window span per query chunk, so local
attention is genuinely sub-quadratic in compute (not just masked out).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef
from repro.models.layers import rope


def attention_defs(cfg):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": ParamDef((d, H * hd), ("fsdp", "tp")),
        "wk": ParamDef((d, KV * hd), ("fsdp", "tp")),
        "wv": ParamDef((d, KV * hd), ("fsdp", "tp")),
        "wo": ParamDef((H * hd, d), ("tp", "fsdp")),
    }


def cross_attention_defs(cfg):
    defs = attention_defs(cfg)
    defs["wk"] = ParamDef((cfg.d_model, cfg.n_kv_heads * cfg.head_dim), ("fsdp", "tp"))
    return defs


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    B, S, KV, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KV, n_rep, hd)).reshape(
        B, S, KV * n_rep, hd)


def _chunked_attn(q, k, v, *, causal: bool, window: int, q_offset,
                  kv_len: Optional[jax.Array], q_chunk: int,
                  scores_bf16: bool = False, chunk_remat: bool = True):
    """q: (B,Sq,H,hd); k,v: (B,Skv,H,hd) (already GQA-repeated).

    q_offset: starting absolute position of q (int or traced scalar).
    kv_len:   optional valid KV length (decode with a partially filled cache).
    window:   0 = full; >0 = sliding window (query i sees keys in (i-window, i]).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    scale = hd ** -0.5
    q_chunk = min(q_chunk, Sq)
    n_chunks = max(Sq // q_chunk, 1)
    assert Sq % q_chunk == 0, (Sq, q_chunk)

    kv_pos = jnp.arange(Skv)

    def one_chunk(qc, qs):
        # qc: (B,c,H,hd); qs: absolute start position of the chunk
        q_pos = qs + jnp.arange(q_chunk)
        if window > 0 and Skv > window + q_chunk:
            # slice KV to the reachable span: [qs - window + 1, qs + q_chunk)
            start = jnp.clip(qs - window + 1, 0, Skv - (window + q_chunk))
            ks = jax.lax.dynamic_slice_in_dim(k, start, window + q_chunk, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, window + q_chunk, axis=1)
            kp = start + jnp.arange(window + q_chunk)
        else:
            ks, vs, kp = k, v, kv_pos
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, ks,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((q_chunk, kp.shape[0]), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= kp[None, :]
        if window > 0:
            mask &= q_pos[:, None] - kp[None, :] < window
        if kv_len is not None:
            mask &= (kp[None, :] < kv_len)
        s = jnp.where(mask[None, None], s, -1e30)
        if scores_bf16:
            # hillclimb lever: halve score-chain HBM traffic (softmax performs
            # its own max-shift; iteration 1 showed an explicit pre-shift only
            # ADDS a materialized buffer — refuted, removed)
            s = s.astype(jnp.bfloat16)
        p = jax.nn.softmax(s, axis=-1).astype(qc.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vs)

    if n_chunks == 1:
        return one_chunk(q, q_offset)

    qr = q.reshape(B, n_chunks, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)

    # remat: backward recomputes the chunk's scores instead of stacking all
    # (n_chunks, B, H, c, Skv) score tensors (flash-attention memory shape).
    # chunk_remat=False instead saves the (bf16) probability tensors — one
    # fewer score recompute per chunk at a bounded memory cost (§Perf h5).
    chunk_fn = (jax.checkpoint(lambda qc, qs: one_chunk(qc, qs),
                               prevent_cse=False)
                if chunk_remat else (lambda qc, qs: one_chunk(qc, qs)))

    def body(_, inp):
        qc, i = inp
        return None, chunk_fn(qc, q_offset + i * q_chunk)

    _, out = jax.lax.scan(body, None, (qr, jnp.arange(n_chunks)))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def multihead_attention(params, x, cfg, *, causal=True, window=0, positions=None,
                        kv_cache=None, cache_len=None, kv_override=None,
                        static_cache=False, impl="xla", q_chunk=256,
                        compute_dtype=jnp.bfloat16, opts=None):
    opts = opts or {}
    q_chunk = opts.get("q_chunk", q_chunk)
    """Full GQA attention.

    kv_cache: optional dict {"k","v"} of (B, S_max, KV, hd) — decode/step mode;
              new K/V written at ``cache_len`` and attention runs over the cache.
    kv_override: source activations for cross-attention K/V.
    static_cache: attend over the cache as-is (cross-attention at decode);
              nothing is projected or written, ``cache_len`` = valid length.
    Returns (out, new_cache).
    """
    B, Sq, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    wq = params["wq"].astype(compute_dtype)
    wo = params["wo"].astype(compute_dtype)

    if positions is None:
        base = cache_len if (kv_cache is not None and not static_cache) else 0
        positions = base + jnp.arange(Sq)[None, :]

    q = (x @ wq).reshape(B, Sq, H, hd)

    if static_cache:
        k = kv_cache["k"].astype(compute_dtype)
        v = kv_cache["v"].astype(compute_dtype)
        k = _repeat_kv(k, H // KV)
        v = _repeat_kv(v, H // KV)
        out = _chunked_attn(q, k, v, causal=False, window=0, q_offset=0,
                            kv_len=cache_len, q_chunk=q_chunk)
        return out.reshape(B, Sq, H * hd) @ wo, None

    wk = params["wk"].astype(compute_dtype)
    wv = params["wv"].astype(compute_dtype)
    xkv = x if kv_override is None else kv_override
    k = (xkv @ wk).reshape(B, xkv.shape[1], KV, hd)
    v = (xkv @ wv).reshape(B, xkv.shape[1], KV, hd)

    use_rope = kv_override is None  # no RoPE on cross-attention
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_len, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck.astype(compute_dtype), cv.astype(compute_dtype)
        kv_len = cache_len + Sq
        q_offset = cache_len
    else:
        kv_len = None
        q_offset = 0

    k = _repeat_kv(k, H // KV)
    v = _repeat_kv(v, H // KV)

    if impl == "pallas" and kv_cache is None:
        # train/prefill self-attention; cached stepping (traced offsets,
        # gather-bound) stays on the XLA path
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                     q_offset=q_offset, kv_len=kv_len)
    else:
        out = _chunked_attn(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, kv_len=kv_len, q_chunk=q_chunk,
                            scores_bf16=opts.get("scores_bf16", False),
                            chunk_remat=opts.get("attn_chunk_remat", True))

    out = out.reshape(B, Sq, H * hd) @ wo
    return out, new_cache
