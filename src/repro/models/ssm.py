"""Mamba2 SSD (state-space duality) mixer: chunked quadratic-intra +
recurrent-inter scan for train/prefill, O(1)-state step for decode.

TPU adaptation (DESIGN.md §2): the CUDA mamba2 kernel's warp-level segmented
scan becomes a chunked formulation — intra-chunk terms are MXU-friendly
batched matmuls (the "duality" attention form), inter-chunk recurrence is a
``lax.scan`` over chunk states.  Heads are sharded over the TP axis; the
chunk scan is local to every shard (no collectives inside the mixer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef


def ssm_defs(cfg):
    d = cfg.d_model
    di = cfg.d_ssm_inner
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    w = cfg.ssm_conv
    return {
        "wz": ParamDef((d, di), ("fsdp", "tp")),
        "wx": ParamDef((d, di), ("fsdp", "tp")),
        "wbc": ParamDef((d, 2 * G * N), ("fsdp", None)),
        "wdt": ParamDef((d, H), ("fsdp", "tp")),
        "conv_x": ParamDef((w, di), (None, "tp"), scale=w ** -0.5),
        "conv_bc": ParamDef((w, 2 * G * N), (None, None), scale=w ** -0.5),
        "dt_bias": ParamDef((H,), ("tp",), init="zeros"),
        "a_log": ParamDef((H,), ("tp",), init="ones"),
        "d_skip": ParamDef((H,), ("tp",), init="ones"),
        "norm": ParamDef((di,), ("tp",), init="ones"),
        "w_out": ParamDef((di, d), ("tp", "fsdp")),
    }


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv. x: (B,S,C); w: (width,C); tail: (B,width-1,C)."""
    width = w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out, xp[:, -(width - 1):, :]


def _group_to_heads(t, H):
    """(B,...,G,N) -> (B,...,H,N) by repeating groups across their heads."""
    G = t.shape[-2]
    rep = H // G
    return jnp.repeat(t, rep, axis=-2) if rep > 1 else t


def ssd_chunked(x, dt, A, B_, C_, chunk: int):
    """SSD over a full sequence.

    x: (B,S,H,P)  dt: (B,S,H) (post-softplus)  A: (H,) (negative)
    B_,C_: (B,S,H,N) (already group-broadcast).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bb, S, H, Pd = x.shape
    N = B_.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def r(t):  # (B,S,...) -> (nc, B, chunk, ...)
        return t.reshape(Bb, nc, chunk, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    xc, dtc, Bc, Cc = r(x), r(dt), r(B_), r(C_)
    dA = dtc * A[None, None, None, :]               # (nc,B,c,H) negative
    seg = jnp.cumsum(dA, axis=2)                    # within-chunk cumulative
    seg_total = seg[:, :, -1, :]                    # (nc,B,H)

    dtx = xc * dtc[..., None]                       # (nc,B,c,H,P)

    # chunk states: sum_s B_s (dt x)_s exp(seg_last - seg_s)
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - seg)        # (nc,B,c,H)
    states = jnp.einsum("nbchk,nbchp,nbch->nbhpk", Bc, dtx, decay_to_end)

    def scan_body(carry, inp):
        st, tot = inp                                # (B,H,P,N), (B,H)
        new = carry * jnp.exp(tot)[:, :, None, None] + st
        return new, carry                            # emit state BEFORE chunk

    init = jnp.zeros_like(states[0])
    final, prev_states = jax.lax.scan(scan_body, init, (states, seg_total))

    # inter-chunk: y_l += C_l . prev_state * exp(seg_l)
    y_inter = jnp.einsum("nbchk,nbhpk,nbch->nbchp", Cc, prev_states,
                         jnp.exp(seg))

    # intra-chunk: masked attention-like term
    cb = jnp.einsum("nbchk,nbshk->nbhcs", Cc, Bc)    # (nc,B,H,c,c)
    seg_l = seg.transpose(0, 1, 3, 2)                # (nc,B,H,c)
    decay = jnp.exp(seg_l[..., :, None] - seg_l[..., None, :])   # (nc,B,H,c,c)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    m = jnp.where(mask[None, None, None], cb * decay, 0.0)
    y_intra = jnp.einsum("nbhcs,nbshp->nbchp", m, dtx)

    y = (y_inter + y_intra).transpose(1, 0, 2, 3, 4).reshape(Bb, S, H, Pd)
    return y, final


def ssm_block(params, x, cfg, *, ssm_cache=None, compute_dtype=jnp.bfloat16,
              chunk: int = 256):
    """Full mamba2 mixer.  x: (B,S,D).  Returns (y (B,S,D), new_cache)."""
    Bb, S, D = x.shape
    H, Pd, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    di = cfg.d_ssm_inner

    z = x @ params["wz"].astype(compute_dtype)                  # (B,S,di)
    xr = x @ params["wx"].astype(compute_dtype)                 # (B,S,di)
    bc = x @ params["wbc"].astype(compute_dtype)                # (B,S,2GN)
    dt_raw = x @ params["wdt"].astype(compute_dtype)            # (B,S,H)

    tail_x = tail_bc = None
    if ssm_cache is not None:
        tail_x, tail_bc = ssm_cache["conv_x"], ssm_cache["conv_bc"]
    xr, new_tail_x = _causal_conv(xr, params["conv_x"].astype(compute_dtype),
                                  tail_x)
    bc, new_tail_bc = _causal_conv(bc, params["conv_bc"].astype(compute_dtype),
                                   tail_bc)
    xr, bc = jax.nn.silu(xr), jax.nn.silu(bc)

    B_, C_ = jnp.split(bc, 2, axis=-1)
    B_ = _group_to_heads(B_.reshape(Bb, S, G, N), H)
    C_ = _group_to_heads(C_.reshape(Bb, S, G, N), H)
    xh = xr.reshape(Bb, S, H, Pd)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["a_log"].astype(jnp.float32))           # (H,) < 0

    if ssm_cache is not None and S == 1:
        # ---- decode: O(1) recurrent update
        st = ssm_cache["state"]                                 # (B,H,P,N)
        dt1 = dt[:, 0]                                          # (B,H)
        decay = jnp.exp(dt1 * A[None, :])
        upd = jnp.einsum("bhk,bhp,bh->bhpk", B_[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32), dt1)
        st = st * decay[:, :, None, None] + upd
        y = jnp.einsum("bhk,bhpk->bhp", C_[:, 0].astype(jnp.float32), st)
        y = y[:, None].astype(compute_dtype)                    # (B,1,H,P)
        new_state = st
    else:
        prev = None if ssm_cache is None else ssm_cache["state"]
        y, new_state = ssd_chunked(xh.astype(jnp.float32), dt, A,
                                   B_.astype(jnp.float32),
                                   C_.astype(jnp.float32), chunk)
        if prev is not None:
            # fold a pre-existing state into the first chunk contribution:
            # y += C_l . prev * exp(cumsum dA); state' includes decayed prev.
            seg_all = jnp.cumsum(dt * A[None, None, :], axis=1)  # (B,S,H)
            y = y + jnp.einsum("bshk,bhpk,bsh->bshp", C_.astype(jnp.float32),
                               prev, jnp.exp(seg_all))
            new_state = new_state + prev * jnp.exp(
                seg_all[:, -1])[:, :, None, None]
        y = y.astype(compute_dtype)

    y = y + xh * params["d_skip"].astype(compute_dtype)[None, None, :, None]
    y = y.reshape(Bb, S, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    g = y * jax.nn.silu(z)
    g32 = g.astype(jnp.float32)
    g = (g32 * jax.lax.rsqrt(jnp.mean(g32 * g32, -1, keepdims=True) + 1e-6))
    g = (g * params["norm"].astype(jnp.float32)).astype(compute_dtype)
    out = g @ params["w_out"].astype(compute_dtype)

    new_cache = None
    if ssm_cache is not None:
        new_cache = {"state": new_state, "conv_x": new_tail_x,
                     "conv_bc": new_tail_bc}
    return out, new_cache


def make_ssm_cache(cfg, batch: int, dtype=jnp.float32):
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    w = cfg.ssm_conv
    return {
        "state": jnp.zeros((batch, H, Pd, N), jnp.float32),
        "conv_x": jnp.zeros((batch, w - 1, cfg.d_ssm_inner), dtype),
        "conv_bc": jnp.zeros((batch, w - 1, 2 * cfg.ssm_groups * N), dtype),
    }
