"""Core layers: RMSNorm, RoPE, SwiGLU MLP, embeddings, chunked cross-entropy."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef


# ------------------------------------------------------------------ RMSNorm

def rmsnorm_defs(d: int):
    return {"scale": ParamDef((d,), (None,), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------ RoPE

def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (...,S,1,half)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ------------------------------------------------------------------ MLP

def mlp_defs(d: int, f: int):
    return {
        "w_gate": ParamDef((d, f), ("fsdp", "tp")),
        "w_in": ParamDef((d, f), ("fsdp", "tp")),
        "w_out": ParamDef((f, d), ("tp", "fsdp")),
    }


def mlp(params, x, compute_dtype=jnp.bfloat16):
    wg = params["w_gate"].astype(compute_dtype)
    wi = params["w_in"].astype(compute_dtype)
    wo = params["w_out"].astype(compute_dtype)
    h = jax.nn.silu(x @ wg) * (x @ wi)
    return h @ wo


# ------------------------------------------------------------------ Embedding

def embedding_defs(vocab: int, d: int, tie: bool):
    defs = {"table": ParamDef((vocab, d), ("vocab", "embed"), scale=d ** -0.5)}
    if not tie:
        defs["unembed"] = ParamDef((d, vocab), ("embed", "vocab"))
    return defs


def embed(params, tokens, compute_dtype=jnp.bfloat16):
    return jnp.take(params["table"].astype(compute_dtype), tokens, axis=0)


def unembed_matrix(params, compute_dtype=jnp.bfloat16):
    if "unembed" in params:
        return params["unembed"].astype(compute_dtype)
    return params["table"].astype(compute_dtype).T


# ------------------------------------------------------- chunked cross-entropy

def chunked_xent(x, unemb, labels, mask, chunk: int = 512):
    """Cross-entropy without materializing full (B,S,V) logits.

    x: (B,S,D) activations; unemb: (D,V); labels/mask: (B,S).
    Scans over sequence chunks; V stays sharded over "model" so the logsumexp
    reduction is a partial-sum + all-reduce under SPMD.
    Returns (sum_loss, sum_mask).
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    n = S // chunk
    assert S % chunk == 0, (S, chunk)
    xc = x.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        xi, li, mi = inp
        logits = (xi @ unemb).astype(jnp.float32)            # (B,c,V) V sharded
        lse = jax.nn.logsumexp(logits, axis=-1)              # partial+all-reduce
        picked = jnp.sum(
            logits * jax.nn.one_hot(li, logits.shape[-1],
                                    dtype=jnp.bfloat16).astype(jnp.float32),
            axis=-1)
        nll = (lse - picked) * mi
        loss, cnt = carry
        return (loss + nll.sum(), cnt + mi.sum()), None

    # remat: backward recomputes per-chunk logits instead of saving them all.
    body = jax.checkpoint(body, prevent_cse=False)
    (loss, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                  (xc, lc, mc))
    return loss, cnt
