"""Parameter declaration framework.

Layers declare parameters once as ``ParamDef`` trees (shape + *logical* sharding
axes + init).  From one declaration we derive: materialized params, abstract
shapes for the dry-run (no allocation), and ``PartitionSpec`` trees resolved
against a concrete mesh and the arch's sharding policy.

Logical axes
------------
  "fsdp"   weight dim sharded over the FSDP axis (ZeRO-style)
  "tp"     weight dim sharded over tensor-parallel axis
  "vocab"  vocabulary dim           "embed"  embedding dim
  "exp"    MoE expert dim           None     replicated dim
  "layer"  stacked-scan leading dim (never sharded)

Policy resolution (see DESIGN.md §5)
  policy="tp":    fsdp->data   tp->model
  policy="fsdp":  fsdp->(data,model)  tp->None     (small archs: 2-D DP/FSDP)
Both: vocab->model, embed->data, exp->None, layer->None.  The "pod" axis only
shards the batch (pure DP across pods) in the paper-faithful baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    spec: tuple            # logical axis name (or None) per dim
    init: str = "normal"   # normal | zeros | ones
    scale: Optional[float] = None
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.spec), (self.shape, self.spec)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn, defs):
    return jax.tree_util.tree_map(fn, defs, is_leaf=_is_def)


def init_params(defs, key):
    """Materialize a ParamDef tree into an array pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, d.dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
            scale = d.scale if d.scale is not None else fan_in ** -0.5
            out.append((jax.random.normal(k, d.shape) * scale).astype(d.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(defs):
    """ShapeDtypeStruct tree (dry-run: shapes only, no allocation)."""
    return tree_map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


def logical_specs(defs):
    return tree_map_defs(lambda d: d.spec, defs)


# --------------------------------------------------------------------- resolve

def axis_rules(policy: str, mesh: Mesh, fsdp_pod: bool = False,
               overrides: dict = None) -> dict:
    has_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if has_pod else ("data",)
    fsdp = ("pod", "data") if (fsdp_pod and has_pod) else ("data",)
    if policy == "tp":
        rules = {"fsdp": fsdp, "tp": "model", "act_seq": None}
    elif policy == "fsdp":
        # small archs (heads don't divide tp): params FSDP over data, compute
        # sequence-parallel over "model" (activations seq-sharded).
        rules = {"fsdp": fsdp, "tp": None, "act_seq": "model"}
    else:
        raise ValueError(f"unknown policy {policy!r}")
    rules["moe_ff"] = rules["tp"]
    rules.update({"vocab": "model", "embed": "data", "exp": None, "layer": None,
                  "batch": batch, None: None})
    if overrides:
        rules.update(overrides)

    # drop mesh axes this mesh does not have (e.g. a 1-D ("data",) test mesh,
    # or a single-pod mesh without "pod")
    def _filter(v):
        if v is None:
            return None
        axes = v if isinstance(v, tuple) else (v,)
        kept = tuple(a for a in axes if a in mesh.axis_names)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    return {k: _filter(v) for k, v in rules.items()}


def resolve_spec(logical: tuple, rules: dict) -> P:
    return P(*[rules.get(ax, None) for ax in logical])


def resolve_shardings(defs_or_specs, policy: str, mesh: Mesh, logical: bool = False,
                      fsdp_pod: bool = False, overrides: dict = None):
    """ParamDef tree (or logical-spec tree) -> NamedSharding tree."""
    rules = axis_rules(policy, mesh, fsdp_pod=fsdp_pod, overrides=overrides)
    if not logical:
        defs_or_specs = logical_specs(defs_or_specs)

    def _one(spec):
        return NamedSharding(mesh, resolve_spec(spec, rules))
    return jax.tree_util.tree_map(_one, defs_or_specs,
                                  is_leaf=lambda x: isinstance(x, tuple))


def stack_defs(defs, n: int):
    """Prepend a stacked-scan 'layer' dim of extent n to every ParamDef."""
    return tree_map_defs(
        lambda d: ParamDef((n,) + d.shape, ("layer",) + d.spec, d.init, d.scale,
                           d.dtype), defs)


def count_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=_is_def)
    total = 0
    for d in leaves:
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total
