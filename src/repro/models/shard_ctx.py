"""Activation-sharding context.

Model code calls ``constrain(x, logical_axes)`` at key points; when a launcher
has installed a (mesh, policy) context this becomes a
``with_sharding_constraint`` pinning activations to the intended layout
(stopping the SPMD partitioner from inventing bad reshards).  Outside a
context (unit tests, single device) it is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.param import axis_rules, resolve_spec

_CTX: contextvars.ContextVar = contextvars.ContextVar("shard_ctx", default=None)


@contextlib.contextmanager
def sharding_rules(policy: str, mesh: Mesh, fsdp_pod: bool = False, **overrides):
    rules = axis_rules(policy, mesh, fsdp_pod=fsdp_pod)
    rules.update(overrides)
    tok = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(tok)


def constrain(x, logical: Tuple):
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_spec(logical, rules)
    # drop mesh axes that do not divide the corresponding dim (e.g. a seq dim
    # of 1 at decode, or a small remainder batch)
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(ax if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


def current_rules():
    return _CTX.get()
