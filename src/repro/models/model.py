"""Top-level model: embeddings + frontend stubs + stack + chunked loss.

``build_model(cfg)`` returns a functional bundle:
  defs()                         ParamDef tree (shapes + logical shardings)
  init(key)                      materialized params
  loss_fn(params, batch)         -> (loss, metrics)          [train]
  prefill(params, batch, caches) -> (last_logits, caches)    [serve]
  decode(params, tokens, caches, cache_len) -> (logits, caches)

Batches (all integer arrays unless noted):
  train:   {"tokens": (B,S), "labels": (B,S), "mask": (B,S) f32}
           + vlm: {"patches": (B,n_patch,frontend_dim) f32}  (tokens: (B,S-n_patch))
           + audio/enc-dec: {"frames": (B,S,frontend_dim) f32} (encoder side)
  decode:  tokens (B,1)
"""
from __future__ import annotations

import functools
from types import SimpleNamespace

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.layers import (chunked_xent, embed, embedding_defs, rmsnorm,
                                 rmsnorm_defs, unembed_matrix)
from repro.models.param import ParamDef, init_params


def model_defs(cfg):
    defs = {
        "embedding": embedding_defs(cfg.padded_vocab, cfg.d_model,
                                    cfg.tie_embeddings),
        "final_norm": rmsnorm_defs(cfg.d_model),
        "stack": tfm.stack_defs_for(cfg),
    }
    if cfg.is_encdec:
        defs["encoder"] = tfm.encoder_stack_defs(cfg)
        defs["enc_norm"] = rmsnorm_defs(cfg.d_model)
    if cfg.frontend == "vision_stub":
        defs["projector"] = {
            "w1": ParamDef((cfg.frontend_dim, cfg.d_model), (None, "fsdp")),
            "w2": ParamDef((cfg.d_model, cfg.d_model), ("fsdp", None)),
        }
    if cfg.frontend == "audio_stub":
        defs["frontend_proj"] = {
            "w": ParamDef((cfg.frontend_dim, cfg.d_model), (None, "fsdp"))}
    return defs


def _frontend_embed(params, batch, cfg, compute_dtype):
    """Returns (x (B,S,D), encoder input or None)."""
    if cfg.frontend == "vision_stub":
        tok_x = embed(params["embedding"], batch["tokens"], compute_dtype)
        p = batch["patches"].astype(compute_dtype)
        p = jax.nn.gelu(p @ params["projector"]["w1"].astype(compute_dtype))
        p = p @ params["projector"]["w2"].astype(compute_dtype)
        return jnp.concatenate([p, tok_x], axis=1), None
    if cfg.is_encdec:
        enc_in = batch["frames"].astype(compute_dtype) @ params[
            "frontend_proj"]["w"].astype(compute_dtype)
        return embed(params["embedding"], batch["tokens"], compute_dtype), enc_in
    return embed(params["embedding"], batch["tokens"], compute_dtype), None


def build_model(cfg, *, impl="xla", moe_impl="sliced", remat=True,
                compute_dtype=jnp.bfloat16, xent_chunk=512, opts=None):
    defs = model_defs(cfg)

    def init(key):
        return init_params(defs, key)

    def _encode(params, enc_in):
        h = tfm.apply_encoder_stack(params["encoder"], enc_in, cfg, impl=impl,
                                    remat=remat, compute_dtype=compute_dtype)
        return rmsnorm(params["enc_norm"], h, cfg.norm_eps)

    def _backbone(params, x, *, caches=None, cache_len=None, enc_out=None,
                  mode="train"):
        x, new_caches = tfm.apply_stack(
            params["stack"], x, cfg, caches=caches, cache_len=cache_len,
            enc_out=enc_out, mode=mode, impl=impl, moe_impl=moe_impl,
            remat=remat and mode == "train", compute_dtype=compute_dtype,
            opts=opts)
        return rmsnorm(params["final_norm"], x, cfg.norm_eps), new_caches

    # ------------------------------------------------------------- training
    def loss_fn(params, batch):
        x, enc_in = _frontend_embed(params, batch, cfg, compute_dtype)
        enc_out = _encode(params, enc_in) if cfg.is_encdec else None
        if cfg.is_encdec:
            # uniform stack needs per-layer cross caches in train mode too:
            # build zeros so scan carries a consistent ys pytree.
            x, _ = _backbone(params, x, enc_out=enc_out, mode="train")
        else:
            x, _ = _backbone(params, x, mode="train")
        unemb = unembed_matrix(params["embedding"], compute_dtype)
        loss_sum, cnt = chunked_xent(x, unemb, batch["labels"], batch["mask"],
                                     chunk=xent_chunk)
        loss = loss_sum / jnp.maximum(cnt, 1.0)
        return loss, {"loss": loss, "tokens": cnt}

    # -------------------------------------------------------------- serving
    def prefill(params, batch, caches):
        x, enc_in = _frontend_embed(params, batch, cfg, compute_dtype)
        enc_out = _encode(params, enc_in) if cfg.is_encdec else None
        x, caches = _backbone(params, x, caches=caches, cache_len=0,
                              enc_out=enc_out, mode="prefill")
        unemb = unembed_matrix(params["embedding"], compute_dtype)
        logits = x[:, -1:] @ unemb
        return logits.astype(jnp.float32), caches

    def decode(params, tokens, caches, cache_len):
        x = embed(params["embedding"], tokens, compute_dtype)
        x, caches = _backbone(params, x, caches=caches, cache_len=cache_len,
                              mode="decode")
        unemb = unembed_matrix(params["embedding"], compute_dtype)
        logits = x @ unemb
        return logits.astype(jnp.float32), caches

    def make_caches(batch: int, max_len: int, cross_len: int = 0,
                    dtype=jnp.bfloat16):
        return tfm.make_stack_caches(cfg, batch, max_len,
                                     cross_len=cross_len, dtype=dtype)

    return SimpleNamespace(cfg=cfg, defs=lambda: defs, init=init,
                           loss_fn=loss_fn, prefill=prefill, decode=decode,
                           make_caches=make_caches)
