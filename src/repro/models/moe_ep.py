"""Expert-parallel MoE via shard_map — the beyond-paper optimized path.

The baseline ``sliced`` implementation (moe.py) is written against *global*
arrays: its dispatch gathers tokens across the data axis (XLA inserts a token
all-gather) and its expert einsum is replicated over the data axis — the
roofline parser shows ~dp× redundant compute and a collective-bound step.

This implementation applies the paper's own principle (``executeOnKeyOwner``:
ship logic to the data) explicitly with shard_map:

  * tokens NEVER move: each (pod, data) shard routes and dispatches its own
    tokens (the matchmaking broker runs member-locally, §3.1.1's
    "partition-aware" execution);
  * expert weights are sharded over the model axis — on the expert dim when
    E % tp == 0 (olmoe 64, jamba 16), else on the FFN dim (grok 8 < 16);
  * each model shard computes its share and the combine is one ``psum`` over
    the model axis (the only collective the layer needs besides the usual
    FSDP weight gather).

Per-device FLOPs drop dp× vs the baseline; the token all-gather disappears.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compat import shard_map

from repro.models.moe import matchmaking_route
from repro.models.shard_ctx import current_rules


def ep_weight_layout(cfg, tp: int) -> str:
    """'expert' — shard E over model; 'ffn' — shard d_ff_expert over model."""
    return "expert" if cfg.n_experts % max(tp, 1) == 0 else "ffn"


def moe_block_ep(params, x, cfg, *, compute_dtype=jnp.bfloat16):
    """Drop-in for moe_block. Requires an active sharding context (mesh)."""
    ctx = current_rules()
    if ctx is None:
        from repro.models.moe import moe_block
        return moe_block(params, x, cfg, compute_dtype=compute_dtype,
                         moe_impl="sliced")
    mesh, rules = ctx
    tp = mesh.shape.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    layout = ep_weight_layout(cfg, tp)
    E, K = cfg.n_experts, cfg.n_experts_active

    if layout == "expert":
        w_spec = P("model", "data", None)      # (E, d, f): E over model
        wo_spec = P("model", None, "data")     # (E, f, d)
    else:
        w_spec = P(None, "data", "model")      # (E, d, f): f over model
        wo_spec = P(None, "model", "data")
    x_spec = P(dp_axes, None, None)
    r_spec = P("data", None)                   # router (d, E): FSDP over d

    def body(xl, wr, wg, wi, wo):
        # gather the FSDP (data-axis) weight shards — per-layer, bf16
        wr = jax.lax.all_gather(wr, "data", axis=0, tiled=True)
        wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
        wi = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        wr = wr.astype(compute_dtype)
        wg = wg.astype(compute_dtype)
        wi = wi.astype(compute_dtype)
        wo = wo.astype(compute_dtype)

        Bl, Sl, D = xl.shape
        T = Bl * Sl
        xf = xl.reshape(T, D)
        logits = xf @ wr                                     # (T, E)
        capacity = max(8, min(int(cfg.capacity_factor * T * K / E), T))
        probs, ids, keep, pos = matchmaking_route(logits, K, capacity)

        flat_ids = ids.reshape(-1)
        flat_pos = pos.reshape(-1)
        flat_keep = keep.reshape(-1)
        slot = jnp.where(flat_keep, flat_ids * capacity + flat_pos,
                         E * capacity)
        slot_tok = jnp.zeros(E * capacity + 1, jnp.int32).at[slot].set(
            jnp.arange(T * K, dtype=jnp.int32) // K, mode="drop")
        slot_used = jnp.zeros(E * capacity + 1, jnp.bool_).at[slot].set(
            True, mode="drop")
        slot_tok, slot_used = slot_tok[:-1], slot_used[:-1]
        x_disp = jnp.take(xf, slot_tok, axis=0) * slot_used[:, None].astype(
            xf.dtype)
        E_loc = wg.shape[0]                                   # E or E/tp
        x_disp = x_disp.reshape(E, capacity, D)
        if layout == "expert":
            # this model shard owns experts [e0, e0+E_loc)
            eidx = jax.lax.axis_index("model") * E_loc
            x_disp = jax.lax.dynamic_slice_in_dim(x_disp, eidx, E_loc, 0)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_disp, wg)) * jnp.einsum(
            "ecd,edf->ecf", x_disp, wi)
        y_disp = jnp.einsum("ecf,efd->ecd", h, wo)

        if layout == "expert":
            # re-embed this shard's expert slices at their global offsets so
            # the local gather-combine sees zeros for unowned experts
            y_all = jnp.zeros((E, capacity, D), y_disp.dtype)
            y_all = jax.lax.dynamic_update_slice_in_dim(y_all, y_disp, eidx, 0)
        else:
            y_all = y_disp                                     # f-partial sums

        # local combine, then ONE psum of token-sized activations (model axis)
        gather_slot = jnp.where(flat_keep, flat_ids * capacity + flat_pos, 0)
        y_tok = jnp.take(y_all.reshape(E * capacity, D), gather_slot, axis=0)
        w = (probs.reshape(-1) * flat_keep).astype(compute_dtype)
        y = (y_tok * w[:, None]).reshape(T, K, D).sum(axis=1)
        y = jax.lax.psum(y, "model")
        return y.reshape(Bl, Sl, D)

    f = shard_map(body, mesh=mesh,
                  in_specs=(x_spec, r_spec, w_spec, w_spec, wo_spec),
                  out_specs=x_spec, check_vma=False)
    return f(x, params["w_router"], params["we_gate"], params["we_in"],
             params["we_out"])
