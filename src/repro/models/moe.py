"""Mixture-of-Experts with the paper's *matchmaking broker* as the router.

Cloud²Sim's fair matchmaking scheduler binds each cloudlet to the best-fitting
VM subject to a fairness/capacity constraint (§5.1.2).  The MoE router is the
same algorithm: each token (cloudlet) is matched to its top-k experts (VMs),
subject to per-expert capacity; overflow tokens fall through to the residual
path (the paper's "waiting queue").

Implementations
---------------
  * ``moe_impl="sliced"``  (default): capacity-sliced grouped matmul.  Tokens are
    sorted by expert id; each expert computes one static ``(capacity, D)`` slice.
    Expert weights are laid out ``(E, D, F)`` with FSDP over D and TP over F, so
    every device runs *its own tokens* through *its F-slice of all experts* —
    zero token exchange (the paper's data-locality principle:
    ``executeOnKeyOwner``).  Works for any (E, tp) combination (grok has E=8 <
    tp=16, which forbids expert-dim sharding).
  * ``moe_impl="dense"``: every expert computes every token (weighted by the
    combine probabilities, zeros for unrouted).  Exponentially wasteful — used
    only as the correctness oracle for property tests.
  * ``moe_impl="ep"``: shard_map expert-parallel with all_to_all dispatch —
    the beyond-paper optimized path (see repro/models/moe_ep.py), valid when
    E % tp == 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef


def moe_defs(cfg):
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    return {
        "w_router": ParamDef((d, e), ("fsdp", None)),
        "we_gate": ParamDef((e, d, f), ("exp", "fsdp", "moe_ff")),
        "we_in": ParamDef((e, d, f), ("exp", "fsdp", "moe_ff")),
        "we_out": ParamDef((e, f, d), ("exp", "moe_ff", "fsdp")),
    }


def matchmaking_route(router_logits, k: int, capacity: int):
    """Fair matchmaking: top-k expert choice with per-expert capacity.

    Returns (probs (T,k), expert_ids (T,k), keep (T,k) bool).
    Position-in-expert is priority-ordered by token index (the paper's
    round-robin fairness among equally matched bids).
    """
    T, E = router_logits.shape
    probs_full = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    probs, ids = jax.lax.top_k(probs_full, k)                   # (T,k)
    flat_ids = ids.reshape(-1)                                  # (T*k,)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)       # (T*k,E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1)            # running count
    pos = jnp.take_along_axis(pos_in_expert, flat_ids[:, None], axis=1)[:, 0]
    keep = (pos < capacity).reshape(T, k)
    return probs, ids, keep, pos.reshape(T, k)


def moe_block(params, x, cfg, *, compute_dtype=jnp.bfloat16, moe_impl="sliced"):
    """x: (B,S,D) -> (B,S,D)."""
    if moe_impl == "ep":
        from repro.models.moe_ep import moe_block_ep
        return moe_block_ep(params, x, cfg, compute_dtype=compute_dtype)
    B, S, D = x.shape
    E, K, F = cfg.n_experts, cfg.n_experts_active, cfg.d_ff_expert
    T = B * S
    xf = x.reshape(T, D)
    logits = xf @ params["w_router"].astype(compute_dtype)      # (T,E)

    if moe_impl == "dense":
        return _moe_dense(params, xf, logits, cfg, compute_dtype).reshape(B, S, D)

    capacity = int(cfg.capacity_factor * T * K / E)
    capacity = max(8, min(capacity, T))
    probs, ids, keep, pos = matchmaking_route(logits, K, capacity)

    # ---- dispatch: sort token copies by expert, take static capacity slices
    flat_ids = ids.reshape(-1)
    flat_pos = pos.reshape(-1)
    flat_keep = keep.reshape(-1)
    # slot index within the (E * capacity) dispatch buffer; dropped -> sentinel
    slot = jnp.where(flat_keep, flat_ids * capacity + flat_pos, E * capacity)
    # token id owning each slot (scatter; sentinel row collects drops)
    slot_tok = jnp.zeros(E * capacity + 1, dtype=jnp.int32).at[slot].set(
        jnp.arange(T * K, dtype=jnp.int32) // K, mode="drop")
    slot_used = jnp.zeros(E * capacity + 1, dtype=jnp.bool_).at[slot].set(
        True, mode="drop")
    slot_tok, slot_used = slot_tok[:-1], slot_used[:-1]

    x_disp = jnp.take(xf, slot_tok, axis=0) * slot_used[:, None].astype(xf.dtype)
    x_disp = x_disp.reshape(E, capacity, D)

    wg = params["we_gate"].astype(compute_dtype)
    wi = params["we_in"].astype(compute_dtype)
    wo = params["we_out"].astype(compute_dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x_disp, wg)) * jnp.einsum(
        "ecd,edf->ecf", x_disp, wi)
    y_disp = jnp.einsum("ecf,efd->ecd", h, wo).reshape(E * capacity, D)

    # ---- combine: gather each token-copy's slot output, weight, sum over k
    gather_slot = jnp.where(flat_keep, flat_ids * capacity + flat_pos, 0)
    y_tok = jnp.take(y_disp, gather_slot, axis=0)               # (T*k, D)
    w = (probs.reshape(-1) * flat_keep).astype(compute_dtype)
    y = (y_tok * w[:, None]).reshape(T, K, D).sum(axis=1)
    return y.reshape(B, S, D)


def _moe_dense(params, xf, logits, cfg, compute_dtype):
    """Oracle: all experts on all tokens, combine by (top-k-masked) probs."""
    E, K = cfg.n_experts, cfg.n_experts_active
    probs_full = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, _ = jax.lax.top_k(probs_full, K)
    mask = probs_full >= topv[:, -1:]
    cw = (probs_full * mask).astype(compute_dtype)              # (T,E)
    wg = params["we_gate"].astype(compute_dtype)
    wi = params["we_in"].astype(compute_dtype)
    wo = params["we_out"].astype(compute_dtype)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", xf, wg)) * jnp.einsum(
        "td,edf->etf", xf, wi)
    y = jnp.einsum("etf,efd->etd", h, wo)
    return jnp.einsum("etd,te->td", y, cw)


def aux_load_balance_loss(router_logits, k: int):
    """Switch-style load-balancing auxiliary loss (fairness metric)."""
    T, E = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    _, ids = jax.lax.top_k(probs, k)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(axis=1), axis=0)
    frac_probs = probs.mean(axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)
