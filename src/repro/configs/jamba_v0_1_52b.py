"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig, register

JAMBA_V0_1_52B = register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536, rope_theta=10000.0,
    n_experts=16, n_experts_active=2, d_ff_expert=14336, moe_interval=2,
    attn_interval=8,                       # 1 attention : 7 mamba
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    tie_embeddings=False,
    policy="tp",
    supports_long_context=True,            # SSM-dominant hybrid
    source="arXiv:2403.19887; hf",
))
