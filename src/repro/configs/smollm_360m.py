"""smollm-360m — llama-arch small dense LM. [hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ModelConfig, register

SMOLLM_360M = register(ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49152, rope_theta=10000.0,
    tie_embeddings=True,
    policy="fsdp",           # 15 heads do not divide tp=16 -> 2-D DP/FSDP policy
    supports_long_context=False,  # pure full attention -> long_500k skipped
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
))
