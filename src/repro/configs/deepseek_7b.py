"""deepseek-7b — llama-arch dense (MHA: kv == heads). [arXiv:2401.02954; hf]"""
from repro.configs.base import ModelConfig, register

DEEPSEEK_7B = register(ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400, rope_theta=10000.0,
    tie_embeddings=False,
    policy="tp",
    supports_long_context=False,
    source="arXiv:2401.02954; hf",
))
