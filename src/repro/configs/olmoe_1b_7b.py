"""olmoe-1b-7b — MoE 64 experts top-8. [arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig, register

OLMOE_1B_7B = register(ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304, rope_theta=10000.0,
    n_experts=64, n_experts_active=8, d_ff_expert=1024, moe_interval=1,
    tie_embeddings=False,
    policy="tp",
    supports_long_context=False,
    source="arXiv:2409.02060; hf",
))
