"""llama3-8b — dense GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ModelConfig, register

LLAMA3_8B = register(ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
    tie_embeddings=False,
    policy="tp",
    supports_long_context=False,
    source="arXiv:2407.21783; unverified",
))
