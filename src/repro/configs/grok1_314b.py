"""grok-1-314b — MoE 8 experts top-2, 64 layers. [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ModelConfig, register

GROK1_314B = register(ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072, rope_theta=10000.0,
    n_experts=8, n_experts_active=2, d_ff_expert=32768, moe_interval=1,
    tie_embeddings=False,
    policy="tp",
    supports_long_context=False,
    source="hf:xai-org/grok-1; unverified",
))
