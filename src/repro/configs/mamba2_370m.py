"""mamba2-370m — pure SSM (SSD / state-space duality), attention-free.
[arXiv:2405.21060; unverified]

Arch-applicability (DESIGN.md): the paper's KV/attention-grid machinery is
inapplicable; the arch runs under the generic partitioned runtime.
"""
from repro.configs.base import ModelConfig, register

MAMBA2_370M = register(ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    attn_interval=-1,                      # attention-free
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    tie_embeddings=True,
    policy="tp",
    supports_long_context=True,
    source="arXiv:2405.21060; unverified",
))
