from repro.configs.base import (ModelConfig, ShapeSpec, SHAPES, TRAIN_4K, PREFILL_32K,
                                DECODE_32K, LONG_500K, get_config, list_archs, register,
                                reduced)
