"""seamless-m4t-medium — encoder-decoder multimodal backbone; audio frontend stub.

``input_specs()`` supplies precomputed frame embeddings for the encoder side.
[arXiv:2308.11596; hf]
"""
from repro.configs.base import ModelConfig, register

SEAMLESS_M4T_MEDIUM = register(ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206, rope_theta=10000.0,
    encoder_layers=12,
    tie_embeddings=True,
    frontend="audio_stub", frontend_dim=1024,
    policy="tp",
    supports_long_context=False,   # speech enc-dec: 500k-token decode not meaningful
    source="arXiv:2308.11596; hf",
))
