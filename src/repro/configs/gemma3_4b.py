"""gemma3-4b — dense, 5:1 local:global sliding-window, 128k. [hf:google/gemma-3-1b-pt]"""
from repro.configs.base import ModelConfig, register

GEMMA3_4B = register(ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262144, rope_theta=1000000.0,
    sliding_window=1024, global_interval=6,   # 5 local : 1 global
    tie_embeddings=True,
    policy="fsdp",           # 8 heads do not divide tp=16
    supports_long_context=True,   # sliding-window local layers are sub-quadratic
    source="hf:google/gemma-3-1b-pt; unverified",
))
