"""Configuration system: model configs, shape specs, and the arch registry.

Every assigned architecture is a ``ModelConfig`` built in its own module under
``repro.configs``; ``get_config(arch_id)`` resolves it.  A ``ShapeSpec`` names one
(seq_len, global_batch, step-kind) cell of the assigned input-shape set.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

# ---------------------------------------------------------------------------
# Shape specs (shared by every LM-family arch per the assignment)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention pattern -------------------------------------------------
    sliding_window: int = 0          # >0: local attention window for local layers
    global_interval: int = 0         # every Nth layer is global (gemma3: 6 => 5 local:1 global)

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_experts_active: int = 0
    d_ff_expert: int = 0
    moe_interval: int = 1            # MoE replaces MLP every Nth layer (1 = all layers MoE)
    capacity_factor: float = 1.25

    # --- SSM / hybrid -------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    attn_interval: int = 0           # 0: all layers attention; k>0: 1 attention per k layers
                                     # -1: attention-free (pure SSM)
    ssm_groups: int = 1

    # --- encoder-decoder ----------------------------------------------------
    encoder_layers: int = 0          # >0 => enc-dec; n_layers is the decoder depth

    # --- modality frontend stubs (assignment: precomputed embeddings) -------
    frontend: Optional[str] = None   # "vision_stub" | "audio_stub"
    frontend_tokens: int = 0         # prompt positions consumed by the frontend stub
    frontend_dim: int = 0            # embedding dim produced by the (stubbed) encoder

    # --- misc ---------------------------------------------------------------
    norm_eps: float = 1e-6
    rope_theta: float = 500000.0
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # --- sharding policy ----------------------------------------------------
    # "tp": megatron TP over "model" + FSDP over ("pod","data")  (needs n_heads % tp == 0)
    # "fsdp": 2-D DP/FSDP; "model" axis used for sequence/vocab instead of heads
    policy: str = "tp"
    # long_500k applicability (sub-quadratic archs only, per the assignment)
    supports_long_context: bool = False
    # source provenance tag from the assignment table
    source: str = ""

    # ------------------------------------------------------------------ api
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 256 tile so the table shards evenly (the
        padded logit columns are ordinary trained-but-never-targeted ids)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.attn_interval == -1

    @property
    def is_hybrid(self) -> bool:
        return self.attn_interval > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def d_ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_ssm_inner // self.ssm_head_dim

    def layer_kinds(self) -> list:
        """Per-decoder-layer mixer kind: 'attn' | 'attn_local' | 'attn_global' | 'ssm'."""
        kinds = []
        for i in range(self.n_layers):
            if self.attn_interval == -1:
                kinds.append("ssm")
            elif self.attn_interval > 0:
                # one attention layer per `attn_interval` (jamba: index attn_interval//2)
                kinds.append("attn" if i % self.attn_interval == self.attn_interval // 2
                             else "ssm")
            elif self.global_interval > 0:
                kinds.append("attn_global" if (i + 1) % self.global_interval == 0
                             else "attn_local")
            else:
                kinds.append("attn")
        return kinds

    def mlp_kinds(self) -> list:
        """Per-decoder-layer MLP kind: 'dense' | 'moe' | 'none'."""
        out = []
        for i in range(self.n_layers):
            if self.is_moe and i % self.moe_interval == self.moe_interval - 1:
                out.append("moe")
            elif self.d_ff > 0 and not self.is_ssm:
                out.append("dense")
            else:
                out.append("none")
        return out

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer), for 6ND cross-checks."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        kinds, mlps = self.layer_kinds(), self.mlp_kinds()
        for k, m in zip(kinds, mlps):
            if k.startswith("attn"):
                total += d * self.n_heads * self.head_dim          # q
                total += 2 * d * self.n_kv_heads * self.head_dim   # k, v
                total += self.n_heads * self.head_dim * d          # o
            else:
                di, n = self.d_ssm_inner, self.ssm_state
                total += d * (2 * di + 2 * self.ssm_groups * n + self.ssm_heads)
                total += di * d + self.ssm_heads * 2 + di * self.ssm_conv
            if m == "moe":
                total += self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
            elif m == "dense":
                total += 3 * d * f
            total += 2 * d  # norms
        if self.is_encdec:
            for _ in range(self.encoder_layers):
                total += 4 * d * self.n_heads * self.head_dim + 3 * d * f + 2 * d
            total += self.n_layers * (2 * d * self.n_heads * self.head_dim +
                                      2 * d * self.n_kv_heads * self.head_dim + d)
        if self.frontend:
            total += self.frontend_dim * d + d * d  # 2-layer projector
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts only routed experts."""
        if not self.is_moe:
            return self.param_count()
        dead = 0
        for m in self.mlp_kinds():
            if m == "moe":
                dead += (self.n_experts - self.n_experts_active) * 3 * self.d_model * self.d_ff_expert
        return self.param_count() - dead

    def shapes(self) -> list:
        """The shape cells applicable to this arch (assignment skips noted)."""
        out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
        if self.supports_long_context:
            out.append(LONG_500K)
        return out

    def skipped_shapes(self) -> list:
        return [] if self.supports_long_context else [LONG_500K]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    _load_all()
    return sorted(_REGISTRY)


_ARCH_MODULES = [
    "smollm_360m", "gemma3_4b", "llama3_8b", "deepseek_7b", "olmoe_1b_7b",
    "grok1_314b", "llava_next_mistral_7b", "seamless_m4t_medium",
    "jamba_v0_1_52b", "mamba2_370m",
]


def _load_all() -> None:
    import importlib
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized config of the same family (small layers/width/experts)."""
    base = dict(
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.is_moe:
        base.update(n_experts=4, n_experts_active=2, d_ff_expert=64)
    if cfg.ssm_state:
        base.update(ssm_state=16, ssm_head_dim=16, ssm_expand=2)
    if cfg.is_hybrid:
        base.update(n_layers=cfg.attn_interval, attn_interval=cfg.attn_interval)
    if cfg.global_interval:
        base.update(n_layers=max(cfg.global_interval, 4), sliding_window=8)
    if cfg.is_encdec:
        base.update(encoder_layers=2, n_layers=2)
    if cfg.frontend:
        base.update(frontend_tokens=4, frontend_dim=32)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
