"""llava-next-mistral-7b — VLM: mistral backbone + anyres-tile patch-embedding stub.

The assignment specifies the transformer BACKBONE only; the vision frontend is a
STUB — ``input_specs()`` supplies precomputed patch embeddings (anyres tiling:
5 tiles x 576 patches). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.configs.base import ModelConfig, register

LLAVA_NEXT_MISTRAL_7B = register(ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, rope_theta=1000000.0,
    tie_embeddings=False,
    frontend="vision_stub", frontend_tokens=2880, frontend_dim=1024,
    policy="tp",
    supports_long_context=False,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
))
