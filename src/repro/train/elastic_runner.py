"""Elastic training runner: the IntelligentAdaptiveScaler driving a real
training loop with checkpoint → re-mesh → re-shard restore at scale events.

This is the thesis's Fig 3.6/3.7 deployment as a training runtime: the
controller watches health (load ≙ step-time/target), flags scale-out/in with
hysteresis, and the runner rebuilds the data mesh over more/fewer devices
without losing a step (synchronous-backup equivalent: the checkpoint).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.elastic import Decision, ElasticController
from repro.core.health import HealthConfig, HealthSample
from repro.data.pipeline import DataConfig, DataPipeline
from repro.train import checkpoint as ck
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_train_state, make_train_step


@dataclasses.dataclass
class ElasticRunReport:
    losses: List[float]
    scale_events: List
    steps: int
    final_n_instances: int
    restarts: int


def _mesh_of(n: int) -> Mesh:
    devs = jax.devices()[:n]
    return Mesh(np.array(devs), ("data",))


def _shardings_for(model, mesh):
    from repro.launch.mesh import state_shardings
    return state_shardings(model, mesh)


def run_elastic_training(model, *, steps: int, data_cfg: DataConfig,
                         opt_cfg: Optional[AdamWConfig] = None,
                         health_cfg: Optional[HealthConfig] = None,
                         ckpt_dir: Optional[str] = None,
                         start_instances: int = 1,
                         inject_failure_at: Optional[int] = None,
                         seed: int = 0) -> ElasticRunReport:
    """Train with elastic data-parallel width over the local device pool.

    inject_failure_at: simulate a member crash at that step — the runner
    restores from the latest checkpoint (fault-tolerance path).
    """
    opt_cfg = opt_cfg or AdamWConfig(warmup_steps=5, total_steps=steps)
    health_cfg = health_cfg or HealthConfig()
    max_n = len(jax.devices())
    health_cfg = dataclasses.replace(
        health_cfg, max_instances=min(health_cfg.max_instances, max_n))
    n = min(start_instances, max_n)

    mesh = _mesh_of(n)
    state = init_train_state(model, jax.random.PRNGKey(seed))
    step_fn = make_train_step(model, opt_cfg)
    shard = _shardings_for(model, mesh)
    state = jax.device_put(state, shard)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    pipe = DataPipeline(data_cfg, model.cfg)

    losses, restarts = [], 0
    controller_holder = {}

    def remesh(new_n: int):
        nonlocal mesh, state, jit_step, n
        new_n = max(1, min(new_n, max_n))
        if new_n == n:
            return
        # checkpoint -> rebuild mesh -> re-shard restore (step-boundary elastic)
        if ckpt_dir:
            ck.save(ckpt_dir, state, int(jax.device_get(state["step"])),
                    data_cursor=pipe.cursor)
        host_state = jax.device_get(state)
        n = new_n
        mesh = _mesh_of(n)
        new_shard = _shardings_for(model, mesh)
        state = jax.device_put(host_state, new_shard)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

    controller = ElasticController(health_cfg, n, remesh_fn=remesh)
    controller_holder["c"] = controller

    i = 0
    while i < steps:
        if inject_failure_at is not None and i == inject_failure_at and ckpt_dir:
            # simulated member crash: recover from the last checkpoint
            latest = ck.latest_step(ckpt_dir)
            if latest is not None:
                r = ck.restore(ckpt_dir, state, shardings=_shardings_for(
                    model, mesh))
                state = r["state"]
                pipe.cursor = r["data_cursor"]
                i = r["step"]
                restarts += 1
            inject_failure_at = None
            continue

        batch = pipe.at(pipe.cursor)
        pipe.cursor += 1
        t0 = time.perf_counter()
        state, metrics = jit_step(state, batch)
        loss = float(jax.device_get(metrics["loss"]))
        dt = time.perf_counter() - t0
        losses.append(loss)
        controller.on_step(HealthSample(
            step=i, step_time=dt, loss=loss,
            grad_norm=float(jax.device_get(metrics.get("grad_norm", 0.0)))))
        if ckpt_dir and (i + 1) % 10 == 0:
            ck.save(ckpt_dir, state, i + 1, data_cursor=pipe.cursor)
        i += 1

    return ElasticRunReport(losses=losses,
                            scale_events=controller.ias.state.history,
                            steps=i, final_n_instances=controller.n_instances,
                            restarts=restarts)
