"""Jittable train step: microbatched gradient accumulation + AdamW.

The step is written against *global* (pjit-logical) arrays; the SPMD
partitioner inserts the gradient all-reduce/reduce-scatter collectives implied
by the parameter/batch shardings (the "Hazelcast does the distribution"
principle — logic is written once, placement follows the data grid).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def init_train_state(model, key, moments_dtype=jnp.float32):
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, moments_dtype),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model, moments_dtype=jnp.float32):
    from repro.models.param import abstract_params
    defs = model.defs()
    p = abstract_params(defs)
    zer = lambda s: jax.ShapeDtypeStruct(s.shape, moments_dtype)
    return {"params": p,
            "opt": {"m": jax.tree_util.tree_map(zer, p),
                    "v": jax.tree_util.tree_map(zer, p)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def make_train_step(model, opt_cfg: AdamWConfig, n_microbatch: int = 1,
                    constrain_grads: bool = True, grad_dtype=jnp.float32):
    """Returns step(state, batch) -> (state, metrics).

    batch leaves have leading global-batch dim B; with n_microbatch > 1 the
    batch is split (B = n * b) and gradients are accumulated in a scan —
    bounding live activation memory (remat keeps only per-layer carries).

    constrain_grads: pin per-microbatch gradients to the parameter sharding —
    the SPMD partitioner then lowers the FSDP all-gather transpose to a
    reduce-scatter instead of a full all-reduce (§Perf iteration h3).
    """

    def loss_for_grad(params, mb):
        loss, metrics = model.loss_fn(params, mb)
        return loss, metrics

    grad_fn_raw = jax.value_and_grad(loss_for_grad, has_aux=True)

    def grad_fn(params, mb):
        out, grads = grad_fn_raw(params, mb)
        if constrain_grads:
            from repro.models.param import logical_specs
            from repro.models.shard_ctx import constrain, current_rules
            if current_rules() is not None:
                specs = logical_specs(model.defs())
                grads = jax.tree_util.tree_map(
                    lambda g, sp: constrain(g, sp), grads, specs,
                    is_leaf=lambda x: isinstance(x, tuple) and all(
                        isinstance(e, (str, type(None))) for e in x))
        return out, grads

    def step(state, batch):
        params = state["params"]

        if n_microbatch == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0] // n_microbatch
                return x.reshape(n_microbatch, b, *x.shape[1:])
            mbs = jax.tree_util.tree_map(split, batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, grad_dtype), params)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(grad_dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            (grads, loss_sum), _ = jax.lax.scan(accum, (zero_g, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_microbatch, grads)
            loss = loss_sum / n_microbatch
            metrics = {"loss": loss, "tokens": jnp.float32(0)}

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, state["opt"], state["step"])
        metrics = dict(metrics, **opt_metrics)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return step
