"""Pipeline parallelism (GPipe) over a "pipe" mesh axis.

Stages own contiguous layer blocks (the stacked layer dim is sharded over
"pipe"); microbatches stream through a (n_micro + n_stages − 1)-tick schedule
inside ``shard_map``, with stage-to-stage activation transfer via
``ppermute`` — the TPU-idiomatic point-to-point.  ``jax.grad`` through the
schedule yields the reverse (backward) pipeline automatically; remat of the
stage body keeps activation memory at GPipe's O(n_micro) boundary tensors.

This composes with the data axis (DP inside each stage) and is exercised by
``tests/test_pipeline.py`` (pipe=2 × data=2: identical loss/grads vs the
non-pipelined reference) plus a 512-device dry-run smoke
(mesh (4,8,16) = ("pipe","data","model") — see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map


def pipelined_apply(layer_fn: Callable, stacked_params, x, mesh: Mesh, *,
                    n_microbatch: int, data_axes=("data",)):
    """Run ``layer_fn(params_i, h) -> h`` over stacked layers, pipelined.

    stacked_params: pytree with leading layer dim L (L % n_stages == 0),
                    sharded over "pipe".
    x: (B, S, D) activations (B % n_microbatch == 0), sharded over data axes.
    Returns y: (B, S, D).
    """
    n_stages = mesh.shape["pipe"]
    fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(params_local, x_local):
        # params_local leaves: (L/n_stages, ...); x_local: (b, S, D)
        idx = jax.lax.axis_index("pipe")
        b = x_local.shape[0]
        mb = b // n_microbatch
        xs = x_local.reshape(n_microbatch, mb, *x_local.shape[1:])
        n_ticks = n_microbatch + n_stages - 1

        def stage_block(h):
            def scan_body(c, p):
                return layer_fn(p, c), None
            h, _ = jax.lax.scan(jax.checkpoint(scan_body, prevent_cse=False),
                                h, params_local)
            return h

        def tick(carry, t):
            buf, ys = carry                       # buf: activation entering
            feed_idx = jnp.clip(t, 0, n_microbatch - 1)
            fresh = jax.lax.dynamic_index_in_dim(xs, feed_idx, 0,
                                                 keepdims=False)
            inp = jnp.where(idx == 0, fresh, buf)
            out = stage_block(inp)
            # last stage emits microbatch (t - n_stages + 1) when valid
            emit_t = t - (n_stages - 1)
            valid = jnp.logical_and(idx == n_stages - 1, emit_t >= 0)
            ys = jax.lax.cond(
                valid,
                lambda ys_: jax.lax.dynamic_update_index_in_dim(
                    ys_, out, jnp.clip(emit_t, 0, n_microbatch - 1), 0),
                lambda ys_: ys_, ys)
            buf = jax.lax.ppermute(out, "pipe", fwd)
            return (buf, ys), None

        buf0 = jnp.zeros_like(xs[0])
        ys0 = jnp.zeros_like(xs)
        (_, ys), _ = jax.lax.scan(tick, (buf0, ys0), jnp.arange(n_ticks))
        # only the last stage holds real outputs; replicate via masked psum
        ys = jax.lax.psum(
            jnp.where(idx == n_stages - 1, ys, jnp.zeros_like(ys)), "pipe")
        return ys.reshape(b, *x_local.shape[1:])

    p_spec = jax.tree_util.tree_map(
        lambda l: P("pipe", *([None] * (l.ndim - 1))), stacked_params)
    x_spec = P(data_axes, None, None)
    f = shard_map(body, mesh=mesh, in_specs=(p_spec, x_spec),
                  out_specs=x_spec, check_vma=False)
    return f(stacked_params, x)
