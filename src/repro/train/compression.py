"""Distributed-optimization tricks: int8 error-feedback gradient compression
and an explicit ring reduce-scatter (compute/comm overlap building block).

Cloud²Sim §4.1.2 lowers its wire cost with BINARY serialization of distributed
objects; the training-runtime analogue is compressing the gradient collective:
  * quantize each gradient leaf to int8 with a per-leaf scale (the "custom
    serializer"),
  * keep the quantization error as residual feedback added to the next step's
    gradient (convergence-safe, Seide et al. / Karimireddy et al.),
  * all-reduce the int8 payload (4× fewer wire bytes than f32; 2× vs bf16).

``ring_reduce_scatter`` is the shard_map/ppermute building block that a real
TPU deployment uses to overlap gradient reduction with the backward pass.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map


# ----------------------------------------------------- int8 error feedback

def init_residuals(grads):
    return jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32),
                                  grads)


def compress(g, residual):
    """f32 grad + residual -> (int8 payload, scale, new residual)."""
    g = g.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g - deq


def decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_grads(grads, residuals):
    """Tree-wise error-feedback compression. Returns (deq_grads, new_res,
    wire_bytes_saved_fraction)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    deq, res = [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = compress(g, r)
        deq.append(decompress(q, s))
        res.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, deq),
            jax.tree_util.tree_unflatten(treedef, res), 0.75)


# ----------------------------------------------------- ring reduce-scatter

def ring_reduce_scatter(x, mesh: Mesh, axis: str = "data"):
    """Explicit (N−1)-step ring reduce-scatter via ``ppermute``: the chunked
    schedule a TPU deployment interleaves with producer compute (each chunk's
    hop can overlap the next chunk's local reduction).

    x: (n_members, payload) — row m is member m's local contribution
    (payload % n_members == 0).  Returns the reduced scatter: member j ends
    with sum_m x[m, chunk_j]; the shard_map output is (n, payload // n).

    Schedule: buf_j(0) = c_j[(j−1) mod n]; each step sends j→j+1 and the
    receiver adds its local copy of the chunk the buffer now represents
    (idx(j,s) = (j−1−s) mod n, so after n−1 steps member j holds chunk j).
    """
    n = mesh.shape[axis]

    def body(xl):
        row = xl[0]                                   # (payload,)
        chunks = row.reshape(n, -1)                   # (n, k)
        idx = jax.lax.axis_index(axis)
        perm = [(j, (j + 1) % n) for j in range(n)]
        buf = jnp.take(chunks, (idx - 1) % n, axis=0)

        def step(s, buf):
            buf = jax.lax.ppermute(buf, axis, perm)
            mine = jnp.take(chunks, (idx - 1 - s) % n, axis=0)
            return buf + mine

        buf = jax.lax.fori_loop(1, n, step, buf)
        return buf[None]                              # (1, k) per member

    return shard_map(body, mesh=mesh, in_specs=(P(axis),),
                     out_specs=P(axis), check_vma=False)(x)
