"""AdamW with sharded (ZeRO-1) state, schedules, and global-norm clipping.

Optimizer moments inherit the parameter shardings, so the optimizer state is
fully partitioned over the mesh (the in-memory-data-grid principle applied to
optimizer state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moments_dtype: str = "float32"   # "bfloat16" halves optimizer-state HBM


def schedule(c: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(c.warmup_steps, 1)
    prog = jnp.clip((step - c.warmup_steps) /
                    jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = c.min_lr_ratio + (1 - c.min_lr_ratio) * cos
    return c.lr * jnp.where(step < c.warmup_steps, warm, decayed)


def init_opt_state(params, moments_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, moments_dtype)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params)}


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(c: AdamWConfig, params, grads, opt_state, step):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(c, step)
    b1, b2 = c.b1, c.b2
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p.astype(
            jnp.float32)
        return ((p.astype(jnp.float32) - lr * step_).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}, {"grad_norm": gnorm, "lr": lr}
