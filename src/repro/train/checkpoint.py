"""Checkpoint/restart with elastic re-sharding.

Format: one ``.npz`` of flattened arrays + a msgpack manifest (step, config
fingerprint, data cursor, tree paths).  ``restore`` re-shards onto whatever
mesh the restore-time runner built — THE mechanism behind both fault tolerance
(node failure → restart from step N) and elastic scaling (the
IntelligentAdaptiveScaler's scale-out is checkpoint → bigger mesh → restore).

``keep`` rotates old checkpoints; ``save`` writes atomically (tmp + rename) so
a mid-write crash never corrupts the latest good state — the paper's
"synchronous backup" guarantee at the job level.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any, Dict, Optional

import jax
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "name", p)))
                      for p in path) for path, _ in leaves]
    return paths, [l for _, l in leaves], treedef


def config_fingerprint(cfg) -> str:
    import dataclasses
    return hashlib.sha256(
        json.dumps(dataclasses.asdict(cfg), sort_keys=True,
                   default=str).encode()).hexdigest()[:16]


def save(ckpt_dir: str, state, step: int, *, data_cursor: int = 0,
         fingerprint: str = "", keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    paths, leaves, _ = _flatten(state)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    manifest = {"step": int(step), "paths": paths, "data_cursor": int(data_cursor),
                "fingerprint": fingerprint, "time": time.time(),
                "dtypes": [str(np.asarray(l).dtype) for l in leaves]}
    final = os.path.join(ckpt_dir, f"step_{int(step):08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _rotate(ckpt_dir, keep)
    return final


def _rotate(ckpt_dir: str, keep: int):
    ckpts = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for old in ckpts[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, old))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    ckpts = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    return int(ckpts[-1].split("_")[1]) if ckpts else None


def restore(ckpt_dir: str, state_template, *, shardings=None,
            step: Optional[int] = None) -> Dict[str, Any]:
    """Restore into ``state_template``'s structure, placing each leaf with the
    (possibly different-mesh) ``shardings`` tree — elastic re-sharding."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{int(step):08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(d, "arrays.npz"))
    paths, leaves, treedef = _flatten(state_template)
    assert paths == manifest["paths"], "checkpoint/model structure mismatch"
    arrays = [data[f"a{i}"] for i in range(len(leaves))]
    if shardings is not None:
        _, shard_leaves, _ = _flatten(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    else:
        arrays = [jax.numpy.asarray(a) for a in arrays]
    state = jax.tree_util.tree_unflatten(treedef.treedef if hasattr(
        treedef, "treedef") else treedef, arrays)
    return {"state": state, "step": manifest["step"],
            "data_cursor": manifest["data_cursor"],
            "fingerprint": manifest["fingerprint"]}
