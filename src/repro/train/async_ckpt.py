"""Asynchronous checkpointing: the training loop never blocks on disk.

At scale, synchronous checkpoint writes stall every chip for seconds; the
standard production pattern is: snapshot device state to host (fast,
device->host copy only), hand the host buffers to a writer thread, and keep
training.  ``wait()`` joins the writer (called before restore / at exit).
A failed in-flight write never corrupts the latest checkpoint (the underlying
``checkpoint.save`` is atomic: tmp dir + rename).
"""
from __future__ import annotations

import queue
import threading
from typing import Optional

import jax

from repro.train import checkpoint as ck


class AsyncCheckpointer:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._err: Optional[BaseException] = None
        self._pending = 0
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            host_state, step, cursor = item
            try:
                ck.save(self.ckpt_dir, host_state, step, data_cursor=cursor,
                        keep=self.keep)
            except BaseException as e:          # surfaced on next save/wait
                self._err = e
            finally:
                with self._lock:
                    self._pending -= 1
                self._q.task_done()

    def save(self, state, step: int, data_cursor: int = 0):
        """Device->host snapshot now; disk write in the background."""
        if self._err:
            raise RuntimeError("async checkpoint writer failed") from self._err
        host_state = jax.device_get(state)       # snapshot (blocks on compute
        with self._lock:                         # only, not on disk)
            self._pending += 1
        self._q.put((host_state, int(step), int(data_cursor)))

    @property
    def in_flight(self) -> int:
        with self._lock:
            return self._pending

    def wait(self):
        self._q.join()
        if self._err:
            raise RuntimeError("async checkpoint writer failed") from self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=10)
