"""Roofline analysis over dry-run artifacts.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI, 16 GiB HBM.  Terms per (arch × shape × mesh) cell:

  t_comp = parsed_FLOPs_per_device / PEAK_FLOPS
  t_mem  = parsed_HBM_bytes_per_device / HBM_BW
  t_coll = parsed_collective_bytes_per_device / LINK_BW

The bottleneck is the max term; roofline fraction = t_comp / max(terms)
(the share of the step the MXUs could actually be busy).  MODEL_FLOPS
(6·N·D or 6·N_active·D) cross-checks the parsed FLOPs — the ratio catches
remat/redundancy waste in the compiled module.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, List, Optional, Tuple

from repro.configs import SHAPES, get_config
from repro.roofline.hlo_parse import analyze

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI, conservative single link)
HBM_CAP = 16 * 2 ** 30

# Host-CPU fallbacks (per core, conservative): used by the seg-scan
# autotuner so rankings computed off-TPU still carry meaningful bottleneck
# labels.  Rankings only compare candidates against each other, so only the
# flops:bandwidth RATIO matters for the chosen chunk.
CPU_PEAK_FLOPS = 5e10
CPU_MEM_BW = 2e10
CPU_LINK_BW = 1e10


def hw_constants(backend: Optional[str] = None) -> Tuple[float, float, float]:
    """(peak_flops, mem_bw, link_bw) for a backend name ('tpu' or host)."""
    if backend == "tpu":
        return PEAK_FLOPS, HBM_BW, LINK_BW
    return CPU_PEAK_FLOPS, CPU_MEM_BW, CPU_LINK_BW


def roofline_terms(costs, backend: Optional[str] = None
                   ) -> Tuple[float, float, float, str]:
    """(t_comp, t_mem, t_coll, bottleneck) for a ``hlo_parse.Costs`` — the
    same max-term model ``analyze_cell`` applies to dry-run artifacts,
    reusable on directly-parsed (or analytically-modelled) costs.  This is
    what the seg-scan chunk autotuner ranks candidates with."""
    peak, mem_bw, link_bw = hw_constants(backend)
    t_comp = costs.flops / peak
    t_mem = costs.hbm_bytes / mem_bw
    t_coll = costs.coll_bytes / link_bw
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    return t_comp, t_mem, t_coll, max(terms, key=terms.get)


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    tag: str
    n_devices: int
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_by_kind: Dict[str, float]
    t_comp: float
    t_mem: float
    t_coll: float
    bottleneck: str
    roofline_fraction: float
    model_flops: float
    useful_ratio: float        # MODEL_FLOPS / (parsed_flops × devices)
    peak_gb: float
    fits_hbm: bool
    meta: Dict

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh}"
                f"{('/' + self.tag) if self.tag else ''} | "
                f"{self.t_comp * 1e3:.2f} | {self.t_mem * 1e3:.2f} | "
                f"{self.t_coll * 1e3:.2f} | {self.bottleneck} | "
                f"{self.roofline_fraction * 100:.0f}% | "
                f"{self.useful_ratio * 100:.0f}% | {self.peak_gb:.1f} | "
                f"{'✓' if self.fits_hbm else '✗'} |")


def model_flops_for(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def branch_weights_for(arch: str) -> Optional[List[float]]:
    cfg = get_config(arch)
    if cfg.global_interval > 0:
        kinds = cfg.layer_kinds()
        g = sum(k == "attn_global" for k in kinds) / len(kinds)
        # jax.lax.cond lowers pred branches as (false, true)
        return [1.0 - g, g]
    return None


def analyze_cell(json_path: str) -> CellRoofline:
    import zstandard as zstd    # optional dep: only dry-run artifacts use it

    meta = json.load(open(json_path))
    hlo_path = json_path.replace(".json", ".hlo.zst")
    txt = zstd.ZstdDecompressor().decompress(
        open(hlo_path, "rb").read()).decode()
    arch, shape, mesh = meta["arch"], meta["shape"], meta["mesh"]
    costs = analyze(txt, branch_weights=branch_weights_for(arch))
    n_dev = 512 if mesh == "pod2" else 256

    t_comp = costs.flops / PEAK_FLOPS
    t_mem = costs.hbm_bytes / HBM_BW
    t_coll = costs.coll_bytes / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    t_max = max(terms.values()) or 1e-30

    mf = model_flops_for(arch, shape)
    parsed_total = costs.flops * n_dev
    return CellRoofline(
        arch=arch, shape=shape, mesh=mesh, tag=meta.get("tag", ""),
        n_devices=n_dev,
        flops_per_dev=costs.flops, hbm_bytes_per_dev=costs.hbm_bytes,
        coll_bytes_per_dev=costs.coll_bytes,
        coll_by_kind=dict(costs.coll_by_kind),
        t_comp=t_comp, t_mem=t_mem, t_coll=t_coll, bottleneck=bottleneck,
        roofline_fraction=t_comp / t_max,
        model_flops=mf, useful_ratio=mf / parsed_total if parsed_total else 0.0,
        peak_gb=meta.get("peak_gb", 0.0),
        fits_hbm=meta.get("peak_gb", 0.0) <= HBM_CAP / 2 ** 30,
        meta=meta)


HEADER = ("| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
          "bottleneck | roofline | useful | GB/dev | fits |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def analyze_dir(dry_dir: str, mesh: str = "pod1", tag: str = "") -> List[CellRoofline]:
    cells = []
    for jp in sorted(glob.glob(os.path.join(dry_dir, f"*_{mesh}"
                                            f"{('_' + tag) if tag else ''}"
                                            ".json"))):
        try:
            cells.append(analyze_cell(jp))
        except Exception as e:              # pragma: no cover
            print(f"[roofline] failed {jp}: {e!r}")
    return cells


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    cells = analyze_dir(args.dir, args.mesh, args.tag)
    print(HEADER)
    for c in cells:
        print(c.row())


if __name__ == "__main__":
    main()
