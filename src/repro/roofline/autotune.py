"""Roofline-driven autotuning of the seg-scan hot path.

The ROADMAP's on-hardware-tuning item: ``chunk`` (the seg-scan kernel's
in-chunk level split) and the exchange ``block`` capacity were hand-picked
constants.  This module activates ``roofline/hlo_parse`` + ``analysis`` to
pick them:

  1. **Anchor** — compile the lax ``_segmented_cumsum`` at a small proxy
     size and parse its optimized HLO (``hlo_parse.analyze``) into measured
     bytes/FLOPs; scaling by the element·step ratio extrapolates the real
     compiled traffic to the target size (``lax_scan_costs``).
  2. **Model** — per-candidate ``chunk``, build analytic ``Costs`` for the
     chunked kernels (``kernel_costs``): the v2 kernel runs ``log2 L``
     levels on-chip in one HBM pass plus ``log2(pow2_ceil(C)) − log2 L``
     jnp tail passes, so larger L trades VMEM scratch for fewer full-array
     round trips; the v1 matmul kernel pays 2·C·L MXU FLOPs instead.
  3. **Rank** — ``analysis.roofline_terms`` turns each candidate's costs
     into max(t_comp, t_mem) seconds for the backend; the analytic winner
     is the lowest (``rank_chunks``).
  4. **Confirm** — ``tuned_chunk(measure=True)`` microbenchmarks the top
     analytic candidates PLUS the hand-picked default and keeps the argmin,
     so the tuned choice is never slower than the default on the harness
     (the default is always in the measured set).

Choices persist per (backend, kind, pow2 size bucket) in a ``CompileCache``
(``TUNE_CACHE``), so the in-library resolution des_scan performs at trace
time (``tuned_chunk(C)`` with ``measure=False``) is a pure cache lookup or
closed-form ranking — it never compiles or times anything inside a trace.
``benchmarks/kernel_tuning.py`` runs the measured pass and reports all four
paths (lax / v1 / v2-fused / v2-autotuned) into ``BENCH_kernel.json``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import CompileCache
from repro.roofline import analysis
from repro.roofline.hlo_parse import Costs, analyze

DEFAULT_CHUNK = 128          # the hand-picked pre-autotuner constant
_F32 = 4                     # bytes
_PROXY_C = 4096              # HLO-parse anchor size (compiles in ~100 ms)

# (backend, kind, pow2_ceil(C)) -> TuningChoice.  A CompileCache for the
# LRU + stats plumbing; entries are metadata, so puts use count_build=False.
TUNE_CACHE = CompileCache(max_entries=64)


def _pow2_ceil(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def _n_steps(C: int) -> int:
    """|{2^j : 2^j < C}| — the lax scan's (and v2's total) step count."""
    return max(int(C) - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class ChunkScore:
    chunk: int
    t_model: float           # analytic roofline seconds (max term)
    bottleneck: str
    flops: float
    hbm_bytes: float


@dataclasses.dataclass
class TuningChoice:
    chunk: int
    kind: str                # "v1" | "v2"
    backend: str
    source: str              # "analytic" | "measured"
    scores: Tuple[ChunkScore, ...]        # analytic ranking, best first
    measured_s: Dict[int, float]          # chunk -> best-of-N seconds


def candidate_chunks(C: int, lo: int = 64, hi: int = 1024) -> Tuple[int, ...]:
    """Power-of-two candidates, clamped to the problem size and always
    containing the hand-picked default."""
    cap = _pow2_ceil(max(int(C), 1))
    out = {min(DEFAULT_CHUNK, cap)}
    c = lo
    while c <= min(hi, cap):
        out.add(c)
        c *= 2
    return tuple(sorted(out))


# --------------------------------------------------- measured HLO anchor

def lax_scan_costs(C: int, proxy: int = _PROXY_C) -> Costs:
    """Parse the COMPILED lax scan's optimized HLO at a proxy size and
    extrapolate to ``C`` by the element·step ratio — the measured anchor
    the analytic kernel models are judged against.  This is the activation
    path for ``hlo_parse``: real compiled bytes, not hand-waved ones."""
    from repro.core.des_scan import _segmented_cumsum

    Cp = min(int(C), proxy)

    def run(term, start):
        return _segmented_cumsum(term, start)

    term = jax.ShapeDtypeStruct((Cp,), jnp.float32)
    start = jax.ShapeDtypeStruct((Cp,), jnp.bool_)
    txt = jax.jit(run).lower(term, start).compile().as_text()
    costs = analyze(txt)
    denom = Cp * max(_n_steps(Cp), 1)
    scale = (int(C) * max(_n_steps(int(C)), 1)) / denom
    return costs.scaled(scale)


# --------------------------------------------------- analytic kernel model

def kernel_costs(C: int, chunk: int, kind: str = "v2") -> Costs:
    """Analytic per-candidate costs for the chunked kernels at size ``C``.

    v2: one HBM pass through (term, pos, out) covers all in-chunk levels
    (carry state lives in VMEM scratch), each tail step d >= L is a full
    gated-add pass (read x + pos, write x), and the fused epilogue scatter
    is one more read+write pass.  v1: same single-pass traffic shape but
    the in-chunk combine is an (L×L) masked matmul — 2·C·L FLOPs.
    """
    C, L = int(C), min(int(chunk), _pow2_ceil(int(C)))
    steps = _n_steps(C)
    in_chunk = min(max(L - 1, 0).bit_length(), steps)
    n_tail = steps - in_chunk
    costs = Costs()
    if kind == "v2":
        costs.flops = float(C * steps)                  # one gated add/step
        costs.hbm_bytes = float(
            C * 3 * _F32                                # term + pos -> out
            + n_tail * C * 3 * _F32                     # x + pos -> x per tail
            + C * 2 * _F32)                             # fused scatter pass
    elif kind == "v1":
        costs.flops = float(2 * C * L + C)              # masked matmul + carry
        costs.hbm_bytes = float(C * 3 * _F32)           # term + reset -> out
    else:
        raise ValueError(f"unknown kernel kind {kind!r}")
    return costs


def rank_chunks(C: int, kind: str = "v2", backend: Optional[str] = None,
                candidates: Optional[Iterable[int]] = None
                ) -> Tuple[ChunkScore, ...]:
    """Candidates scored by the analytic roofline, fastest first (ties to
    the smaller chunk — less VMEM scratch for the same modelled time)."""
    backend = backend or jax.default_backend()
    scores = []
    for c in (candidates or candidate_chunks(C)):
        costs = kernel_costs(C, c, kind)
        t_comp, t_mem, _, bottleneck = analysis.roofline_terms(
            costs, backend=backend)
        scores.append(ChunkScore(chunk=int(c), t_model=max(t_comp, t_mem),
                                 bottleneck=bottleneck, flops=costs.flops,
                                 hbm_bytes=costs.hbm_bytes))
    return tuple(sorted(scores, key=lambda s: (s.t_model, s.chunk)))


# --------------------------------------------------- microbench confirm

def _default_bench(C: int, kind: str) -> Callable[[int], float]:
    """Best-of-3 seconds for one chunk candidate on synthetic scan inputs.
    Runs whatever the backend actually executes (compiled kernel on TPU,
    the emulation/interpreter fallback elsewhere) — the same path des_scan
    will take, which is the honest thing to confirm against."""
    rng = np.random.default_rng(0)
    # v1 runs under the Pallas interpreter off-TPU: cap the bench size so a
    # tuning pass stays sub-second per candidate
    Cb = int(C) if (kind == "v2" or jax.default_backend() == "tpu") \
        else min(int(C), 1 << 14)
    term = jnp.asarray(rng.uniform(0.0, 5.0, Cb).astype(np.float32))
    start = jnp.asarray(rng.uniform(size=Cb) < 0.1)

    def bench(chunk: int) -> float:
        if kind == "v1":
            from repro.core.compat import pallas_interpret_default
            from repro.kernels.seg_scan.kernel import seg_cumsum
            fn = jax.jit(lambda t, s: seg_cumsum(
                t, s.astype(jnp.float32), chunk=chunk,
                interpret=pallas_interpret_default()))
        else:
            from repro.kernels.seg_scan.v2 import seg_cumsum_v2
            fn = jax.jit(lambda t, s: seg_cumsum_v2(t, s, chunk=chunk))
        jax.block_until_ready(fn(term, start))          # compile + warm
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(term, start))
            best = min(best, time.perf_counter() - t0)
        return best

    return bench


def tuned_chunk(C: int, *, kind: str = "v2", backend: Optional[str] = None,
                measure: bool = False,
                bench: Optional[Callable[[int], float]] = None,
                candidates: Optional[Sequence[int]] = None,
                top_k: int = 2) -> int:
    """The tuned ``chunk`` for a size-``C`` seg-scan.

    ``measure=False`` (the in-library default — des_scan calls this at
    TRACE time) returns the persisted choice for the (backend, kind, pow2
    size bucket), falling back to the analytic roofline winner; nothing is
    compiled or timed.  ``measure=True`` confirms the top ``top_k``
    analytic candidates + the hand-picked default on the microbench and
    persists the argmin — since the default is always measured, the tuned
    choice can never be slower than it on the harness."""
    backend = backend or jax.default_backend()
    key = (backend, kind, _pow2_ceil(max(int(C), 1)))
    hit = TUNE_CACHE.get(key)
    if hit is not None and (hit.source == "measured" or not measure):
        return hit.chunk

    scores = rank_chunks(C, kind, backend, candidates)
    choice = TuningChoice(chunk=scores[0].chunk, kind=kind, backend=backend,
                          source="analytic", scores=scores, measured_s={})
    if measure:
        bench = bench or _default_bench(C, kind)
        probe = list(dict.fromkeys(
            [s.chunk for s in scores[:top_k]]
            + [min(DEFAULT_CHUNK, _pow2_ceil(max(int(C), 1)))]))
        timed = {c: bench(c) for c in probe}
        # argmin with ties to the default, then to the smaller chunk
        best = min(timed, key=lambda c: (timed[c], c != DEFAULT_CHUNK, c))
        choice = dataclasses.replace(choice, chunk=best, source="measured",
                                     measured_s=timed)
    TUNE_CACHE.put(key, choice, count_build=False)
    return choice.chunk


def tuning_report(C: int, kind: str = "v2",
                  backend: Optional[str] = None) -> Optional[TuningChoice]:
    """Peek the persisted choice for a size bucket without ranking."""
    backend = backend or jax.default_backend()
    return TUNE_CACHE.get((backend, kind, _pow2_ceil(max(int(C), 1))))


# --------------------------------------------------- exchange block policy

def tuned_exchange_block(C: int, n_members: int, *, slack: float = 1.25,
                         backend: Optional[str] = None) -> int:
    """Analytic exchange ``block`` (per-(src, dst) all-to-all capacity) for
    the distributed core: the expected balanced load is C/M² entries, the
    slack absorbs ownership skew, and the result is pow2-rounded so the
    compile-cache key space stays tiny.  Clamped to the C/M shard — a block
    can never exceed what one member holds.  (The runtime auto-capacity in
    ``simulate_completion_distributed`` MEASURES the exact requirement;
    this is the static pre-pick for callers that must fix ``block`` before
    seeing data, e.g. ahead-of-time compile farms.)"""
    C, M = max(int(C), 1), max(int(n_members), 1)
    shard = max(C // M, 1)
    expected = C / (M * M)
    block = _pow2_ceil(max(int(np.ceil(expected * slack)), 1))
    return max(1, min(block, shard))


def exchange_roofline(C: int, n_members: int, block: int,
                      backend: Optional[str] = None) -> Tuple[float, str]:
    """Modelled (seconds, bottleneck) of one exchange at a given block:
    the padded all-to-all ships M·block triples of 16 bytes per member and
    the local scan covers ~C/M elements — the roofline view of why
    oversized blocks waste link time on padding."""
    M = max(int(n_members), 1)
    costs = Costs()
    costs.coll_bytes = float(M * int(block) * 16)
    local = max(int(C) // M, 1)
    costs.flops = float(local * _n_steps(local))
    costs.hbm_bytes = float(local * 3 * _F32 * max(_n_steps(local), 1))
    t_comp, t_mem, t_coll, bottleneck = analysis.roofline_terms(
        costs, backend=backend or jax.default_backend())
    return max(t_comp, t_mem, t_coll), bottleneck
