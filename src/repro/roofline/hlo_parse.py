"""Optimized-HLO parser for roofline terms.

``compiled.cost_analysis()`` counts ``while`` bodies ONCE (verified in this
container), so scan-over-layers modules under-report by ~n_layers.  This
parser rebuilds per-device costs from ``compiled.as_text()``:

  * per-computation direct costs: dot FLOPs (2·|out|·|contracted|),
    collective payload bytes (operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), and an HBM-traffic
    estimate (operand+result bytes of materializing ops),
  * a call-graph walk multiplying ``while`` bodies by their
    ``known_trip_count`` backend-config annotation (fallback: caller hint),
    and weighting ``conditional`` branches (gemma3's local/global mix).

Shapes in post-SPMD HLO are per-device, so all results are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "token": 0,
                "u4": 1, "s4": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operands/results move through HBM.  Optimized HLO fuses nearly
# all elementwise/layout work, so traffic is counted ONLY at fusion / dot /
# copy / collective boundaries (layout ops like reshape/convert outside
# fusions are usually bitcasts).
_MATERIALIZING = {"fusion", "dot", "convolution", "copy", "all-gather",
                  "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "dynamic-slice",
                  "dynamic-update-slice", "gather", "scatter", "sort"}


@dataclasses.dataclass
class Instr:
    name: str
    shapes: List[Tuple[str, List[int]]]     # result (dtype, dims) list
    opcode: str
    operands: List[str]
    attrs: str


def _parse_shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in re.finditer(r"(\w+)\[([0-9,]*)\]", type_str):
        dims = [int(x) for x in m.group(2).split(",") if x]
        out.append((m.group(1), dims))
    if not out and type_str.strip().startswith(("f", "s", "u", "pred")):
        out.append((type_str.strip().split("{")[0], []))  # scalar
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\]\{\},\/]+)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*(?:\(|\{)")


def parse_module(txt: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur = None
    for line in txt.splitlines():
        if line and not line.startswith((" ", "}")):
            # computation headers start at indent 0 with %name or ENTRY
            # (and may wrap over several lines — only the first names it)
            m = _COMP_RE.match(line.strip())
            if m and (line.startswith("%") or line.startswith("ENTRY")):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.startswith("}"):
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # operands: %names inside the top-level parens
        depth, i0, ops = 1, 0, []
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    i0 = i
                    break
        arg_str = rest[:i0] if depth == 0 else rest
        ops = re.findall(r"%[\w\.\-]+", arg_str)
        attrs = rest[i0 + 1:] if depth == 0 else ""
        comps[cur].append(Instr(name, _parse_shapes(type_str), opcode, ops,
                                attrs))
    return comps


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    hbm_bytes: float = 0.0

    def __iadd__(self, o):
        self.flops += o.flops
        self.coll_bytes += o.coll_bytes
        self.hbm_bytes += o.hbm_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] += v
        return self

    def scaled(self, f: float) -> "Costs":
        c = Costs(self.flops * f, self.coll_bytes * f,
                  defaultdict(float, {k: v * f
                                      for k, v in self.coll_by_kind.items()}),
                  self.hbm_bytes * f)
        return c


def _trip_count(attrs: str) -> Optional[int]:
    m = re.search(r'known_trip_count[\\"]*:?\{[\\"]*n[\\"]*:[\\"]*(\d+)', attrs)
    return int(m.group(1)) if m else None


def analyze(txt: str, *, branch_weights: Optional[List[float]] = None,
            default_trip: int = 1) -> Costs:
    comps = parse_module(txt)
    # symbol table: name -> shapes (global; HLO result names are unique)
    sym: Dict[str, list] = {}
    for instrs in comps.values():
        for ins in instrs:
            sym[ins.name] = ins.shapes

    # fusions whose root is an in-place dynamic-update-slice only touch the
    # update region (the buffer is aliased); map callee -> update bytes.
    dus_root_update_bytes: Dict[str, int] = {}
    for cname, instrs in comps.items():
        if instrs and instrs[-1].opcode == "dynamic-update-slice":
            root = instrs[-1]
            if len(root.operands) > 1:
                dus_root_update_bytes[cname] = _bytes_of(
                    sym.get(root.operands[1], []))
    # also parameters declared in computation headers are not parsed; operand
    # lookups fall back to 0 bytes for unknowns (rare: params inside fusions).

    entry = None
    for name, instrs in comps.items():
        if any(i.opcode == "while" for i in instrs) or entry is None:
            pass
    # ENTRY is the computation named in the header with ENTRY; parse_module
    # loses that marker, so detect: the computation nobody calls.
    called = set()
    for instrs in comps.values():
        for ins in instrs:
            for key in ("to_apply=", "calls=", "body=", "condition=",
                        "true_computation=", "false_computation="):
                for m in re.finditer(re.escape(key) + r"(%[\w\.\-]+)",
                                     ins.attrs):
                    called.add(m.group(1))
            m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
            if m:
                called.update(re.findall(r"%[\w\.\-]+", m.group(1)))
    roots = [n for n in comps if n not in called]
    memo: Dict[str, Costs] = {}

    def comp_cost(cname: str) -> Costs:
        if cname in memo:
            return memo[cname]
        total = Costs()
        memo[cname] = total      # guard cycles
        for ins in comps.get(cname, ()):
            op = ins.opcode
            out_bytes = _bytes_of(ins.shapes)
            if op == "dot":
                lhs = sym.get(ins.operands[0] if ins.operands else "", [])
                lhs_dims = lhs[0][1] if lhs else []
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                                  ins.attrs)
                csize = 1
                if cdims and lhs_dims:
                    for d in cdims.group(1).split(","):
                        if d:
                            csize *= lhs_dims[int(d)]
                n_out = 1
                for _, dims in ins.shapes:
                    for d in dims:
                        n_out *= d
                total.flops += 2.0 * n_out * csize
            if op in COLLECTIVES:
                ob = sum(_bytes_of(sym.get(o, [])) for o in ins.operands)
                total.coll_bytes += ob
                total.coll_by_kind[op] += ob
            if op in _MATERIALIZING:
                # traffic model: bytes written + an equal read charge (the
                # producer-side read was counted when the producer wrote).
                # Slice-like ops touch only the slice; in-place
                # dynamic-update-slice touches only the update region.
                if op in ("dynamic-update-slice", "scatter"):
                    upd = (_bytes_of(sym.get(ins.operands[1], []))
                           if len(ins.operands) > 1 else out_bytes)
                    total.hbm_bytes += 2 * upd
                elif op == "fusion":
                    callee_m = re.search(r"calls=(%[\w\.\-]+)", ins.attrs)
                    cn = callee_m.group(1) if callee_m else None
                    if cn in dus_root_update_bytes:
                        total.hbm_bytes += 2 * dus_root_update_bytes[cn]
                    else:
                        total.hbm_bytes += 2 * out_bytes
                else:
                    total.hbm_bytes += 2 * out_bytes
            # ---- calls
            if op == "while":
                body = re.search(r"body=(%[\w\.\-]+)", ins.attrs)
                trip = _trip_count(ins.attrs) or default_trip
                if body:
                    total += comp_cost(body.group(1)).scaled(trip)
            elif op == "fusion":
                callee = re.search(r"calls=(%[\w\.\-]+)", ins.attrs)
                if callee:
                    sub = comp_cost(callee.group(1))
                    total.flops += sub.flops       # traffic counted at callsite
                    total.coll_bytes += sub.coll_bytes
                    for k, v in sub.coll_by_kind.items():
                        total.coll_by_kind[k] += v
            elif op == "conditional":
                branches = re.findall(
                    r"(?:true_computation|false_computation)=(%[\w\.\-]+)",
                    ins.attrs)
                if not branches:
                    m = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
                    branches = re.findall(r"%[\w\.\-]+", m.group(1)) if m else []
                if branches:
                    w = branch_weights
                    if not w or len(w) != len(branches):
                        w = [1.0 / len(branches)] * len(branches)
                        # default: expected cost under uniform branch choice;
                        # callers pass the true mix (e.g. gemma3 5:1)
                    for b, wi in zip(branches, w):
                        total += comp_cost(b).scaled(wi)
            elif op in ("call", "custom-call"):
                callee = re.search(r"(?:to_apply|calls)=(%[\w\.\-]+)", ins.attrs)
                if callee:
                    total += comp_cost(callee.group(1))
        memo[cname] = total
        return total

    grand = Costs()
    for r in roots:
        grand += comp_cost(r)
    return grand
