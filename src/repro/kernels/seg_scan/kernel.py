"""Segmented prefix-sum — Pallas TPU kernel (the DES scan core's hot loop).

Same chunked-scan idiom as ``ssd_scan``: within a chunk the segmented cumsum
is an (L×L) masked matmul (MXU-friendly), across chunks a single running
value is carried in scratch — the carry only survives into a chunk until its
first segment boundary.  Grid = (chunks,) sequential, so the carry lives on
chip for the whole array.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import CompilerParams


def _seg_cumsum_kernel(term_ref, reset_ref, out_ref, carry_ref):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    term = term_ref[0].astype(jnp.float32)        # (L,)
    reset = reset_ref[0].astype(jnp.float32)      # (L,) 1.0 at segment starts
    L = term.shape[0]

    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)   # row i (output pos)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)   # col j (input pos)
    rj = reset[None, :] > 0.5                              # (1, L)

    # last segment start at-or-before i (0 if the segment spans the chunk edge)
    start_i = jnp.max(jnp.where((si <= li) & rj, si, 0), axis=1)   # (L,)
    # does ANY reset occur at-or-before i?  (kills the inter-chunk carry)
    has_reset = jnp.max(jnp.where((si <= li) & rj, 1, 0), axis=1)  # (L,)

    mask = ((si <= li) & (si >= start_i[:, None])).astype(jnp.float32)
    f_local = jax.lax.dot_general(
        mask, term[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[:, 0]          # (L,)

    carry = carry_ref[0, 0]
    f = f_local + carry * (1.0 - has_reset.astype(jnp.float32))
    out_ref[0] = f.astype(out_ref.dtype)
    carry_ref[0, 0] = f[L - 1]


def seg_cumsum(term, reset, *, chunk: int = 128, interpret: bool = False):
    """Segmented inclusive prefix sum of ``term`` (1D), restarting wherever
    ``reset`` is nonzero.  term: (C,) f32; reset: (C,) f32 -> (C,) f32."""
    C = term.shape[0]
    chunk = min(chunk, max(C, 1))
    pad = (-C) % chunk
    if pad:
        # padded tail: term 0 / no reset — extends the last segment harmlessly
        term = jnp.pad(term, (0, pad))
        reset = jnp.pad(reset, (0, pad))
    nc = (C + pad) // chunk
    tr = term.reshape(nc, chunk).astype(jnp.float32)
    rr = reset.reshape(nc, chunk).astype(jnp.float32)

    out = pl.pallas_call(
        _seg_cumsum_kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda c: (c, 0)),
            pl.BlockSpec((1, chunk), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, chunk), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(tr, rr)
    return out.reshape(-1)[:C]
