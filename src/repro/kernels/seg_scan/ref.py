"""Pure-jnp oracle: segmented inclusive cumsum via global cumsum re-basing."""
import jax
import jax.numpy as jnp


def seg_cumsum_ref(term, reset):
    """term: (C,) f32; reset: (C,) nonzero at segment starts -> (C,) f32.

    cumsum over everything, then subtract the running total just before each
    element's segment start (found with a cummax over start positions).
    """
    term = term.astype(jnp.float32)
    C = term.shape[0]
    idx = jnp.arange(C, dtype=jnp.int32)
    start_pos = jax.lax.cummax(jnp.where(reset > 0, idx, 0))
    cs = jnp.cumsum(term)
    base = cs[start_pos] - term[start_pos]
    return cs - base
