"""Jitted wrappers for the segmented-cumsum kernels (interpret off-TPU).

The interpret default is the ONE in ``core/compat.py``
(``resolve_kernel_interpret``) — the same helper des_scan's entry points
use, so all three former copies of ``jax.default_backend() != "tpu"``
resolve identically.
"""
import functools

import jax

from repro.core.compat import resolve_kernel_interpret
from repro.kernels.seg_scan.kernel import seg_cumsum
from repro.kernels.seg_scan.ref import seg_cumsum_ref
from repro.kernels.seg_scan.v2 import scatter_finish_v2, seg_cumsum_v2


@functools.partial(jax.jit, static_argnames=("chunk",))
def segmented_cumsum(term, reset, *, chunk: int = 128):
    """The legacy v1 kernel: tolerance-equivalent chunked matmul scan."""
    return seg_cumsum(term, reset, chunk=chunk,
                      interpret=resolve_kernel_interpret(None, warn=False))


@functools.partial(jax.jit, static_argnames=("chunk",))
def segmented_cumsum_v2(term, start, *, chunk: int = 128):
    """The v2 position-gated kernel: BIT-identical to
    ``des_scan._segmented_cumsum(term, start)`` on every backend."""
    return seg_cumsum_v2(term, start, chunk=chunk,
                         interpret=resolve_kernel_interpret(None, warn=False))
