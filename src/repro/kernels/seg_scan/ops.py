"""Jitted wrapper for the segmented-cumsum kernel (interpret off-TPU)."""
import functools

import jax

from repro.kernels.seg_scan.kernel import seg_cumsum
from repro.kernels.seg_scan.ref import seg_cumsum_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def segmented_cumsum(term, reset, *, chunk: int = 128):
    return seg_cumsum(term, reset, chunk=chunk, interpret=not _on_tpu())
