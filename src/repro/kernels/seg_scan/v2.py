"""Position-gated segmented cumsum v2 — bit-identical to the lax scan core.

The v1 kernel (``kernel.py``) computes each chunk with an (L×L) masked
matmul and rebases on an inter-chunk carry.  That is fast but reassociates
the per-segment sum, so it is only TOLERANCE-equivalent to des_scan's
``_segmented_cumsum`` — and every bit-identity guarantee (elastic replay,
journal resume, deterministic reduce) therefore pins ``use_kernel=False``.

v2 reproduces ``_segmented_cumsum``'s EXACT addition tree.  The lax core is
a position-gated Hillis–Steele doubling scan over the whole array:

    x_0       = term
    x_{j+1}(p) = x_j(p) + [pos(p) >= d] * x_j(p - d),   d = 2^j,  d < C

where ``pos`` is the element's in-segment position.  v2 splits the SAME
step set at the chunk length L (a power of two):

  * steps ``d < L`` run inside a Pallas kernel, one grid step per chunk.
    The operand ``x_j(p - d)`` crosses the chunk edge only into the
    previous chunk's last ``d`` lanes, so the kernel carries each level's
    full before-state in a ``(log2 L, L)`` VMEM scratch: at level ``j`` it
    reads the previous chunk's saved ``x_j``, saves its own, then applies
    the gated add.  The grid is sequential, so the carry never leaves chip.
  * steps ``d >= L`` (all multiples of L) run as plain jnp shifts on the
    flat result — a shift by a multiple of L preserves chunk-local offsets,
    so these are ordinary global Hillis–Steele steps.

The union of both step sets is exactly ``{2^j : 2^j < C}`` — the lax step
set — because ``L = min(chunk, pow2_ceil(C))`` and, for a power of two P,
``P < pow2_ceil(C)  <=>  P < C``.  Every gated-off step adds an exact 0 of
the operand dtype, so the floating-point result is BIT-identical to
``_segmented_cumsum`` for any chunk size, array length, or layout.

Execution modes (``interpret`` resolved by ``compat.resolve_kernel_interpret``):

  * compiled (TPU)          — the Pallas kernel above + jnp tail steps.
  * interpret fallback      — bit-exact jnp EMULATION: the verbatim
    ``_segmented_cumsum`` op sequence.  Off-TPU the Pallas interpreter
    pays per-grid-step Python overhead (~seconds at C=1M); the emulation
    is the same math at lax speed, so CPU runs keep the bit-identity
    contract without the interpreter tax.
  * ``force_pallas=True``   — run the REAL kernel under the Pallas
    interpreter regardless of backend; the parity suite uses this to pin
    the kernel logic itself (small C only — the interpreter unrolls the
    grid).

``scatter_finish_v2`` is the fused epilogue: sentinel masking + the
scatter back to pre-sort row order in one kernel (one pass over the
result instead of a masked select materialized between two XLA ops).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import CompilerParams, resolve_kernel_interpret


def _pow2_ceil(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def _in_segment_pos(start):
    """In-segment position — the VERBATIM op sequence ``_segmented_cumsum``
    uses (exact int scan), so the gate values are bit-identical."""
    C = start.shape[0]
    idx = jnp.arange(C, dtype=jnp.int32)
    seg_start = jax.lax.cummax(jnp.where(start, idx, 0))
    return idx - seg_start


def _emulate(term, pos):
    """The lax doubling scan, gated on a precomputed ``pos`` — op-for-op the
    body of ``des_scan._segmented_cumsum`` (the parity suite pins this)."""
    C = term.shape[0]
    x = term
    d = 1
    while d < C:
        shifted = jnp.concatenate([jnp.zeros((d,), x.dtype), x[:-d]])
        x = x + jnp.where(pos >= d, shifted, jnp.zeros((), x.dtype))
        d *= 2
    return x


def _scan_kernel(levels, term_ref, pos_ref, out_ref, carry_ref):
    """In-chunk steps d = 1..L/2 with each level's inter-chunk operand
    carried in scratch.  ``carry_ref[j]`` holds the PREVIOUS chunk's state
    before step 2^j; it is read, then overwritten with this chunk's state,
    then the gated add runs — the save-before-update order is what makes
    the next grid step see exactly ``x_j`` of this chunk."""
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    x = term_ref[...]                       # (1, L)
    pos = pos_ref[...]                      # (1, L) int32
    L = x.shape[1]
    zero = jnp.zeros((), x.dtype)
    for j in range(levels):
        d = 1 << j
        prev = carry_ref[j:j + 1, :]        # previous chunk's x_j, (1, L)
        carry_ref[j:j + 1, :] = x
        shifted = jnp.concatenate([prev[:, L - d:], x[:, :L - d]], axis=1)
        x = x + jnp.where(pos >= d, shifted, zero)
    out_ref[...] = x


def _pallas_in_chunk(term, pos, L: int, interpret: bool):
    """Run the in-chunk levels (d < L) over the (nc, L) chunk grid."""
    C_pad = term.shape[0]
    nc = C_pad // L
    levels = max(L - 1, 0).bit_length()     # log2(L): steps 1, 2, .., L/2
    tr = term.reshape(nc, L)
    pr = pos.reshape(nc, L)
    out = pl.pallas_call(
        lambda *refs: _scan_kernel(levels, *refs),
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1, L), lambda c: (c, 0)),
            pl.BlockSpec((1, L), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, L), lambda c: (c, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, L), term.dtype),
        scratch_shapes=[pltpu.VMEM((max(levels, 1), L), term.dtype)],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(tr, pr)
    return out.reshape(-1)


def seg_cumsum_v2(term, start, *, chunk: int = 128,
                  interpret: Optional[bool] = None,
                  force_pallas: bool = False):
    """Segmented inclusive prefix sum of ``term`` (1D, any add-closed
    dtype), restarting where ``start`` is True — BIT-identical to
    ``des_scan._segmented_cumsum(term, start)`` on every path.

    ``chunk`` (power of two) sets the in-kernel level split L; it changes
    the execution schedule only, never the addition tree, so every chunk
    size produces the same bytes.  ``interpret=None`` resolves to the
    backend default (compiled on TPU, jnp emulation elsewhere);
    ``force_pallas`` runs the real kernel under the Pallas interpreter
    (parity testing)."""
    if chunk < 1 or (chunk & (chunk - 1)):
        raise ValueError(f"chunk must be a power of two, got {chunk}")
    C = term.shape[0]
    if C == 0:
        return term
    start = start.astype(bool) if start.dtype != jnp.bool_ else start
    pos = _in_segment_pos(start)
    interpret = resolve_kernel_interpret(interpret, warn=False)
    if interpret and not force_pallas:
        return _emulate(term, pos)

    # L = min(chunk, pow2_ceil(C)) keeps the in-kernel step set inside the
    # lax step set {2^j < C} even when one chunk covers the whole array.
    L = min(chunk, _pow2_ceil(C))
    pad = (-C) % L
    if pad:        # tail pad: fresh zero segments; sliced off below
        term = jnp.concatenate([term, jnp.zeros((pad,), term.dtype)])
        pos = jnp.concatenate([pos, jnp.zeros((pad,), pos.dtype)])
    x = _pallas_in_chunk(term, pos, L, interpret=interpret and force_pallas)

    # tail steps d = L, 2L, ... while d < C — plain global shifts; padding
    # sits at the END of the array so element p < C reads exactly the same
    # operands as the unpadded lax scan.
    d = L
    while d < C:
        shifted = jnp.concatenate([jnp.zeros((d,), x.dtype), x[:-d]])
        x = x + jnp.where(pos >= d, shifted, jnp.zeros((), x.dtype))
        d *= 2
    return x[:C]


def _scatter_kernel(f_ref, order_ref, sent_ref, out_ref):
    """Fused epilogue: ``out[order[i]] = sentinel ? 0 : f[i]``, one dynamic
    store per element.  ``order`` (identity-padded) is a permutation of the
    padded index range, so every output slot is written exactly once and no
    init pass over ``out`` is needed beyond the first grid step."""
    L = f_ref.shape[1]
    zero = jnp.zeros((1,), out_ref.dtype)

    def body(i, _):
        o = order_ref[0, i]
        val = jnp.where(sent_ref[0, i] != 0, zero, f_ref[0, i][None])
        out_ref[pl.ds(o, 1)] = val
        return 0

    jax.lax.fori_loop(0, L, body, 0)


def scatter_finish_v2(f, order, is_sentinel, *, chunk: int = 128,
                      interpret: Optional[bool] = None,
                      force_pallas: bool = False):
    """Scatter sorted results back to original rows with the sentinel mask
    folded in: returns ``out`` with ``out[order[i]] = 0 if is_sentinel[i]
    else f[i]`` — bitwise the lax ``where`` + ``.at[order].set`` epilogue,
    in one pass.  ``order`` must be a permutation of ``range(len(f))``."""
    C = f.shape[0]
    if C == 0:
        return f
    interpret = resolve_kernel_interpret(interpret, warn=False)
    if interpret and not force_pallas:
        masked = jnp.where(is_sentinel, jnp.zeros((), f.dtype), f)
        return jnp.zeros((C,), f.dtype).at[order].set(masked)

    L = min(chunk, _pow2_ceil(C))
    pad = (-C) % L
    if pad:        # identity-pad the permutation; padded rows write 0
        tail = jnp.arange(C, C + pad, dtype=order.dtype)
        order = jnp.concatenate([order, tail])
        f = jnp.concatenate([f, jnp.zeros((pad,), f.dtype)])
        is_sentinel = jnp.concatenate(
            [is_sentinel, jnp.ones((pad,), is_sentinel.dtype)])
    C_pad = C + pad
    nc = C_pad // L
    out = pl.pallas_call(
        _scatter_kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1, L), lambda c: (c, 0)),
            pl.BlockSpec((1, L), lambda c: (c, 0)),
            pl.BlockSpec((1, L), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((C_pad,), lambda c: (0,)),
        out_shape=jax.ShapeDtypeStruct((C_pad,), f.dtype),
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret and force_pallas,
    )(f.reshape(nc, L), order.astype(jnp.int32).reshape(nc, L),
      is_sentinel.astype(jnp.int32).reshape(nc, L))
    return out[:C]
