"""Jitted wrapper for the histogram kernel."""
import functools

import jax

from repro.kernels.histogram.kernel import histogram_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("vocab", "block_t", "block_v"))
def histogram(tokens, vocab: int, *, block_t=256, block_v=512):
    return histogram_kernel(tokens, vocab, block_t=block_t, block_v=block_v,
                            interpret=not _on_tpu())
