"""Pure-jnp oracle for the histogram kernel."""
import jax.numpy as jnp


def histogram_ref(tokens, vocab: int):
    return jnp.zeros((vocab,), jnp.int32).at[tokens].add(
        jnp.ones_like(tokens, jnp.int32), mode="drop")
