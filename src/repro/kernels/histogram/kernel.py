"""Histogram (word count) — Pallas TPU kernel.

The MapReduce layer's map() hot spot: counting token occurrences.  A GPU
would use shared-memory atomics; the TPU adaptation replaces atomics with a
(block_t × block_v) broadcast-compare + row-sum (VPU-friendly), accumulating
per-vocab-block partial counts in VMEM across the token grid axis.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import CompilerParams


def _hist_kernel(t_ref, o_ref, acc_ref, *, block_v: int, n_t_blocks: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vi = pl.program_id(0)
    toks = t_ref[...]                                   # (bt,)
    v_base = vi * block_v
    vocab_ids = v_base + jax.lax.broadcasted_iota(
        jnp.int32, (toks.shape[0], block_v), 1)
    hits = (toks[:, None] == vocab_ids).astype(jnp.int32)
    acc_ref[...] += jnp.sum(hits, axis=0)

    @pl.when(ti == n_t_blocks - 1)
    def _write():
        o_ref[...] = acc_ref[...]


def histogram_kernel(tokens, vocab: int, *, block_t: int = 256,
                     block_v: int = 512, interpret: bool = False):
    """tokens: (T,) int32 in [0, vocab) -> counts (vocab,) int32."""
    T = tokens.shape[0]
    block_t = min(block_t, T)
    block_v = min(block_v, vocab)
    assert T % block_t == 0 and vocab % block_v == 0
    nt, nv = T // block_t, vocab // block_v

    kernel = functools.partial(_hist_kernel, block_v=block_v, n_t_blocks=nt)
    return pl.pallas_call(
        kernel,
        grid=(nv, nt),
        in_specs=[pl.BlockSpec((block_t,), lambda v, t: (t,))],
        out_specs=pl.BlockSpec((block_v,), lambda v, t: (v,)),
        out_shape=jax.ShapeDtypeStruct((vocab,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_v,), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(tokens)
