"""Pure-jnp oracle for the grouped expert matmul."""
import jax.numpy as jnp


def grouped_matmul_ref(x, w):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
