"""Grouped (expert) matmul — Pallas TPU kernel.

The MoE hot spot: ``(E, C, D) @ (E, D, F) -> (E, C, F)`` — one matmul per
expert over its capacity slice.  TPU adaptation of CUDA "megablocks"-style
grouped GEMM: instead of a ragged block table (GPU SM scheduling), the expert
dim is the outer *parallel* grid axis and each (c, f) tile accumulates over
D-tiles in VMEM scratch — the MXU-aligned blocking is (block_c × block_d) ×
(block_d × block_f).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import CompilerParams


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d_blocks: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0, ...].astype(jnp.float32)      # (bc, bd)
    w = w_ref[0, ...].astype(jnp.float32)      # (bd, bf)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == n_d_blocks - 1)
    def _write():
        o_ref[0, ...] = acc_ref[...].astype(o_ref.dtype)


def grouped_matmul(x, w, *, block_c: int = 128, block_f: int = 128,
                   block_d: int = 128, interpret: bool = False):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    _, _, F = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    nc, nf, nd = C // block_c, F // block_f, D // block_d

    kernel = functools.partial(_gmm_kernel, n_d_blocks=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, block_d, block_f), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
