"""Jitted wrapper for the grouped matmul kernel (TPU/interpret dispatch)."""
import functools

import jax

from repro.kernels.moe_gmm.kernel import grouped_matmul
from repro.kernels.moe_gmm.ref import grouped_matmul_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _gmm(x, w, block_c, block_f, block_d):
    return grouped_matmul(x, w, block_c=block_c, block_f=block_f,
                          block_d=block_d, interpret=not _on_tpu())


def _gmm_fwd(x, w, block_c, block_f, block_d):
    return _gmm(x, w, block_c, block_f, block_d), (x, w)


def _gmm_bwd(block_c, block_f, block_d, res, g):
    # both cotangents are themselves grouped matmuls -> reuse the kernel:
    #   dx (E,C,D) = g (E,C,F) @ w^T (E,F,D);  dw (E,D,F) = x^T (E,D,C) @ g
    x, w = res
    interp = not _on_tpu()
    dx = grouped_matmul(g, w.transpose(0, 2, 1), block_c=block_c,
                        block_f=block_d, block_d=block_f, interpret=interp)
    dw = grouped_matmul(x.transpose(0, 2, 1), g, block_c=block_d,
                        block_f=block_f, block_d=block_c, interpret=interp)
    return dx, dw


_gmm.defvjp(_gmm_fwd, _gmm_bwd)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d"))
def gmm(x, w, *, block_c=128, block_f=128, block_d=128):
    E, C, D = x.shape
    F = w.shape[-1]
    return _gmm(x, w, min(block_c, C), min(block_f, F), min(block_d, D))
