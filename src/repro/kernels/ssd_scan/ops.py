"""Jitted wrapper for the SSD scan kernel."""
import functools

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd(x, dt, A, B, C, chunk):
    return ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=not _on_tpu())


def _ssd_fwd(x, dt, A, B, C, chunk):
    return _ssd(x, dt, A, B, C, chunk), (x, dt, A, B, C)


def _ssd_bwd(chunk, res, g):
    x, dt, A, B, C = res
    _, vjp = jax.vjp(ssd_ref, x, dt, A, B, C)
    return vjp(g)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A, B, C, *, chunk=128):
    return _ssd(x, dt, A, B, C, chunk)
