"""Mamba2 SSD chunked scan — Pallas TPU kernel.

The CUDA mamba2 kernel is a warp-level segmented scan; the TPU adaptation
(DESIGN.md §2) uses the state-space *duality*: within a chunk the output is an
attention-like (L×L) masked matmul (MXU), across chunks a first-order state
recurrence carried in VMEM scratch.  Grid = (batch·heads, chunks) with the
chunk axis sequential, so the (P,N) state lives in VMEM for a whole sequence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L,)
    A = a_ref[0]                                  # scalar (negative)
    B = b_ref[0, 0].astype(jnp.float32)          # (L, N)
    C = c_ref[0, 0].astype(jnp.float32)          # (L, N)

    dA = dt * A                                   # (L,)
    seg = jnp.cumsum(dA)                          # (L,)
    dtx = x * dt[:, None]                         # (L, P)

    # inter-chunk: carry-in state contribution
    state = state_ref[...]                        # (P, N)
    y_inter = jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(seg)[:, None]  # (L,P)

    # intra-chunk: masked attention-like term
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)     # (L,L)
    L = cb.shape[0]
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.exp(seg[:, None] - seg[None, :])
    m = jnp.where(li >= si, cb * decay, 0.0)
    y_intra = jax.lax.dot_general(m, dtx, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_inter + y_intra).astype(y_ref.dtype)

    # state update: decay full chunk + inject chunk state
    decay_to_end = jnp.exp(seg[-1] - seg)         # (L,)
    new_state = state * jnp.exp(seg[-1]) + jax.lax.dot_general(
        dtx, B * decay_to_end[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (P, N)
    state_ref[...] = new_state


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = False):
    """x: (BH, S, P); dt: (BH, S); A: (BH,); B, C: (BH, S, N) -> y (BH,S,P)."""
    BH, S, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    xr = x.reshape(BH, nc, chunk, P)
    dtr = dt.reshape(BH, nc, chunk)
    Br = B.reshape(BH, nc, chunk, N)
    Cr = C.reshape(BH, nc, chunk, N)

    out = pl.pallas_call(
        _ssd_kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nc, chunk, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xr, dtr, A, Br, Cr)
    return out.reshape(BH, S, P)
