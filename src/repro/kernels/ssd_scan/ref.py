"""Pure-jnp oracle: the exact sequential SSD recurrence (no chunking)."""
import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C):
    """x: (BH,S,P); dt: (BH,S); A: (BH,); B,C: (BH,S,N) -> (BH,S,P).

      h_t = h_{t-1} * exp(dt_t A) + dt_t * B_t ⊗ x_t
      y_t = C_t · h_t
    """
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)

    def per_seq(xs, dts, a, bs, cs):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = h * jnp.exp(dtt * a) + dtt * (xt[:, None] * bt[None, :])
            return h, h @ ct
        P, N = xs.shape[-1], bs.shape[-1]
        h0 = jnp.zeros((P, N), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xs, dts, bs, cs))
        return ys

    return jax.vmap(per_seq)(x, dt, A, B, C).astype(x.dtype)
