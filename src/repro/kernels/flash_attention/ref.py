"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  sm_scale=None):
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd)."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    if sm_scale is None:
        sm_scale = hd ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
