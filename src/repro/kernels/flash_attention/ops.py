"""Jitted wrapper: (B,S,H,hd) layout handling + TPU/interpret dispatch.

Forward AND backward are Pallas kernels (flash fwd emits log-sum-exp rows as
the backward residual; backward recomputes P blockwise — dq kernel + fused
dk/dv kernel).  The pure-jnp oracle lives in ref.py."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (flash_attention_bwd,
                                                  flash_attention_fwd,
                                                  flash_attention_fwd_lse)
from repro.kernels.flash_attention.ref import attention_ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fa(q, k, v, causal, window, block_q, block_k):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())


def _fa_fwd(q, k, v, causal, window, block_q, block_k):
    out, lse = flash_attention_fwd_lse(q, k, v, causal=causal, window=window,
                                       block_q=block_q, block_k=block_k,
                                       interpret=_interpret())
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, block_q, block_k, res, g):
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd(q, k, v, out, lse, g, causal=causal,
                                     window=window, block_q=block_q,
                                     block_k=block_k, interpret=_interpret())
    return dq, dk, dv


_fa.defvjp(_fa_fwd, _fa_bwd)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "q_offset", "kv_len",
                                    "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0, kv_len=None,
                    block_q=128, block_k=128):
    """q,k,v: (B,S,H,hd) — the model-side layout. GQA repeat happens upstream."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    if kv_len is not None or q_offset not in (0, None):
        # decode-style stepping is served by the XLA path (gather-bound)
        raise NotImplementedError("kernel serves full self-attention")
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, hd)
    out = _fa(qt, kt, vt, causal, window, min(block_q, Sq), min(block_k, Skv))
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
