"""Flash attention forward — Pallas TPU kernel.

TPU adaptation of the (GPU) flash-attention insight: online-softmax tiling so
the (Sq, Skv) score matrix never leaves VMEM.  Tiling is chosen for the MXU
(128-aligned q/kv blocks, head_dim lanes) and the HBM→VMEM pipeline: grid =
(batch·heads, q_blocks, kv_blocks) with the kv axis innermost and sequential,
carrying the running (m, l, acc) statistics in VMEM scratch.

Causal and sliding-window masks are applied in-kernel; fully-masked kv blocks
are skipped via ``pl.when`` (so local attention does O(S·w) work, not O(S²)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import CompilerParams

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               block_q: int, block_k: int, causal: bool, window: int,
               sm_scale: float, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # block-level reachability (skips O(S^2-S*w) work for local attention):
    q_end = q_start + block_q - 1
    k_end = k_start + block_k - 1
    reachable = jnp.bool_(True)
    if causal:
        reachable = jnp.logical_and(reachable, k_start <= q_end)
    if window > 0:
        reachable = jnp.logical_and(reachable, k_end > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, ...].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, ...].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, ...].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        sm_scale=None, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd) — batch·heads pre-flattened."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    nq, nk = Sq // block_q, Skv // block_k
    if sm_scale is None:
        sm_scale = hd ** -0.5

    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, sm_scale=sm_scale, n_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # m: running max
            pltpu.VMEM((block_q,), jnp.float32),        # l: running denom
            pltpu.VMEM((block_q, hd), jnp.float32),     # acc: running out
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------- backward

def _fa_fwd_lse_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                       acc_ref, *, block_q, block_k, causal, window, sm_scale,
                       n_kv_blocks):
    """Forward that also emits log-sum-exp rows (backward residual)."""
    _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
               block_q=block_q, block_k=block_k, causal=causal, window=window,
               sm_scale=sm_scale, n_kv_blocks=n_kv_blocks)

    @pl.when(pl.program_id(2) == n_kv_blocks - 1)
    def _write_lse():
        lse_ref[0, ...] = (m_ref[...] +
                           jnp.log(jnp.maximum(l_ref[...], 1e-30)))


def flash_attention_fwd_lse(q, k, v, *, causal=True, window=0, sm_scale=None,
                            block_q=128, block_k=128, interpret=False):
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    nq, nk = Sq // block_q, Skv // block_k
    if sm_scale is None:
        sm_scale = hd ** -0.5
    kernel = functools.partial(
        _fa_fwd_lse_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, sm_scale=sm_scale, n_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def _recompute_p_ds(q, k, lse, do, v, delta, *, q_start, k_start, block_q,
                    block_k, causal, window, sm_scale):
    """Shared backward block math: returns (p, ds) both (bq, bk) f32."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = jnp.logical_and(mask, q_pos >= k_pos)
    if window > 0:
        mask = jnp.logical_and(mask, q_pos - k_pos < window)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * sm_scale
    return p, ds


def _fa_bwd_dq_kernel(q_ref, k_ref, v_ref, lse_ref, do_ref, delta_ref, dq_ref,
                      acc_ref, *, block_q, block_k, causal, window, sm_scale,
                      n_kv_blocks):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start, k_start = qi * block_q, ki * block_k
    reachable = jnp.bool_(True)
    if causal:
        reachable = jnp.logical_and(reachable, k_start <= q_start + block_q - 1)
    if window > 0:
        reachable = jnp.logical_and(reachable,
                                    k_start + block_k - 1 > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        _, ds = _recompute_p_ds(q, k, lse_ref[0], do_ref[0].astype(jnp.float32),
                                v, delta_ref[0], q_start=q_start,
                                k_start=k_start, block_q=block_q,
                                block_k=block_k, causal=causal, window=window,
                                sm_scale=sm_scale)
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv_blocks - 1)
    def _write():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _fa_bwd_dkv_kernel(q_ref, k_ref, v_ref, lse_ref, do_ref, delta_ref,
                       dk_ref, dv_ref, dk_acc, dv_acc, *, block_q, block_k,
                       causal, window, sm_scale, n_q_blocks):
    ki, qi = pl.program_id(1), pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start, k_start = qi * block_q, ki * block_k
    reachable = jnp.bool_(True)
    if causal:
        reachable = jnp.logical_and(reachable, k_start <= q_start + block_q - 1)
    if window > 0:
        reachable = jnp.logical_and(reachable,
                                    k_start + block_k - 1 > q_start - window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        p, ds = _recompute_p_ds(q, k, lse_ref[0], do, v, delta_ref[0],
                                q_start=q_start, k_start=k_start,
                                block_q=block_q, block_k=block_k,
                                causal=causal, window=window,
                                sm_scale=sm_scale)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(qi == n_q_blocks - 1)
    def _write():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, do, *, causal=True, window=0,
                        sm_scale=None, block_q=128, block_k=128,
                        interpret=False):
    """Pallas backward: (dq, dk, dv). delta = rowsum(do * out) precomputed."""
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    nq, nk = Sq // block_q, Skv // block_k
    if sm_scale is None:
        sm_scale = hd ** -0.5
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_fa_bwd_dq_kernel, block_q=block_q, block_k=block_k,
                          causal=causal, window=window, sm_scale=sm_scale,
                          n_kv_blocks=nk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, lse, do, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_fa_bwd_dkv_kernel, block_q=block_q,
                          block_k=block_k, causal=causal, window=window,
                          sm_scale=sm_scale, n_q_blocks=nq),
        grid=(BH, nk, nq),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_q), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, block_q, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Skv, hd), k.dtype),
            jax.ShapeDtypeStruct((BH, Skv, hd), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, lse, do, delta)
    return dq, dk, dv
