# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
#   seg_scan  — chunked segmented prefix-sum: the hot loop of the
#               closed-form DES completion core (core/des_scan.py)
