"""Distributed phase-4 scaling — replicated vs owner-keyed bucket-sort core.

Sweeps C ∈ {50k, 200k, 1M} × members ∈ {1, 2, 4, 8} and writes
``BENCH_dist.json``: per-member wall time, scaling efficiency, and the
exchange/replicated ratio per (C, M) point.  The replicated PR-2 core runs
the full O(C log C) lexsort+scan on EVERY member; the exchange core
all-to-alls each cloudlet to its VM-owner and sorts only ~C/M per member —
so its per-member wall time must shrink as members are added while the
replicated core's total work grows with M.

Caveat recorded in the payload: benchmark members are host-emulated devices
sharing one CPU, so ``scaling_efficiency`` (t1 / (M · tM)) reflects the
algorithmic work partitioning, not parallel silicon — on real multi-chip
meshes the exchange core's wall time additionally divides by the member
count.  Override sizes with ``BENCH_DIST_SIZES``/``BENCH_DIST_MEMBERS``
(comma-separated) to shrink the sweep.
"""
import json
import os
import sys
import time

if __package__ in (None, ""):      # standalone: python benchmarks/dist_scaling.py
    _root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from benchmarks.common import emit, smoke
from repro.core.des_scan import (_pow2_ceil, default_vm_owner,
                                 simulate_completion_distributed)
from repro.core.executor import DistributedExecutor
from repro.core.partition import exchange_load

BENCH_JSON = "BENCH_dist.json"
SIZES = tuple(int(s) for s in os.environ.get(
    "BENCH_DIST_SIZES", "50000,200000,1000000").split(","))
MEMBERS = tuple(int(s) for s in os.environ.get(
    "BENCH_DIST_MEMBERS", "1,2,4,8").split(","))
N_VMS = 1024


def _timed(fn, repeats):
    jax.block_until_ready(fn())            # compile + warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / repeats


def main():
    devs = jax.devices()
    sizes, n_vms = SIZES, N_VMS
    if smoke():
        sizes, n_vms = (4_000,), 64
    members = [m for m in MEMBERS if m <= len(devs)]
    rng = np.random.default_rng(0)
    entries = []
    for C in sizes:
        repeats = 2 if C >= 500_000 else 3
        assign = jnp.asarray(rng.integers(0, n_vms, C).astype(np.int32))
        mi = jnp.asarray(rng.uniform(1e3, 5e4, C).astype(np.float32))
        mips = jnp.asarray(rng.uniform(500, 2000, n_vms).astype(np.float32))
        valid = jnp.ones(C, bool)
        base = {}                          # core -> wall at the smallest M
        by_m = {}
        for M in members:
            ex = DistributedExecutor(Mesh(np.array(devs[:M]), ("data",)))
            owner = default_vm_owner(n_vms, M)
            block = _pow2_ceil(int(exchange_load(owner, assign, valid,
                                                 M).max()))
            for core, kw in (("exchange", {"block": block}),
                             ("replicated", {"method": "replicated"})):
                wall = _timed(lambda: simulate_completion_distributed(
                    assign, mi, mips, valid, ex, vm_owner=owner, **kw),
                    repeats)
                base.setdefault(core, wall)
                # baselined against the SMALLEST member count in the sweep
                # (M=1 in the committed artifact; a shrunk BENCH_DIST_MEMBERS
                # override is labelled so --check readers aren't misled)
                entry = {"core": core, "n_cloudlets": C, "n_members": M,
                         "scan_s": wall, "baseline_members": members[0],
                         "speedup_vs_1": base[core] / wall,
                         "scaling_efficiency": base[core] / (M * wall)}
                if core == "exchange":
                    entry["block"] = block
                    entry["recv_capacity"] = M * block  # per-member sort size
                entries.append(entry)
                by_m[(core, M)] = entry
            ratio = (by_m[("exchange", M)]["scan_s"] /
                     by_m[("replicated", M)]["scan_s"])
            by_m[("exchange", M)]["vs_replicated"] = ratio
            emit(f"dist/cl{C}/n{M}/exchange",
                 by_m[("exchange", M)]["scan_s"] * 1e6,
                 f"{ratio:.2f}x-of-replicated")
            emit(f"dist/cl{C}/n{M}/replicated",
                 by_m[("replicated", M)]["scan_s"] * 1e6,
                 f"eff={by_m[('replicated', M)]['scaling_efficiency']:.2f}")
    return {"n_vms": n_vms, "members": members,
            "note": ("host-emulated members share one CPU: "
                     "scaling_efficiency measures algorithmic work "
                     "partitioning, not parallel silicon"),
            "entries": entries}


if __name__ == "__main__":
    _path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                         BENCH_JSON)
    with open(_path, "w") as f:
        json.dump(main(), f, indent=2)
    print(f"wrote {_path}")
