"""Table 5.1 — CloudSim vs Cloud²Sim execution time, simple vs loaded, for
1/2/4/8 members.  ("CloudSim" = the single-member sequential run.)"""
import jax

from benchmarks.common import emit, mesh_of, smoke
from repro.core.cloudsim import SimulationConfig, run_simulation


def main():
    n_devs = len(jax.devices())
    n_vms, n_cl, iters = (40, 80, 0.05) if smoke() else (200, 400, 1.0)
    rows = {}
    for loaded in (False, True):
        cfg = SimulationConfig(n_vms=n_vms, n_cloudlets=n_cl,
                               broker="round_robin", is_loaded=loaded,
                               workload_iters_per_gmi=iters)
        for n in [1, 2, 4, 8]:
            if n > n_devs:
                continue
            r = run_simulation(cfg, mesh_of(n))
            total = sum(r.timings.values())
            rows[(loaded, n)] = total
            tag = "loaded" if loaded else "simple"
            emit(f"t5.1/{tag}/n{n}", total * 1e6,
                 f"makespan={r.makespan:.1f}")
    if (True, 1) in rows and (True, max(k[1] for k in rows)) in rows:
        nmax = max(k[1] for k in rows)
        emit("t5.1/loaded/speedup", 0.0,
             f"S_{nmax}={rows[(True, 1)] / rows[(True, nmax)]:.2f}")
    return rows


if __name__ == "__main__":
    main()
