"""Figs 5.1–5.3 — scalability patterns vs #cloudlets × #members; classifies
each curve into the thesis's §5.1.1 regimes via the speedup model."""
import dataclasses

import jax

from benchmarks.common import emit, mesh_of, smoke
from repro.core.cloudsim import SimulationConfig, run_simulation
from repro.core.speedup import SpeedupModel


def main():
    n_devs = len(jax.devices())
    ns = [n for n in (1, 2, 4, 8) if n <= n_devs]
    cases = ([(60, 0.05)] if smoke()
             else [(150, 0.3), (200, 1.0), (400, 2.0)])
    for n_cl, iters in cases:
        # phase 4 now runs the closed-form scan core; on >1 member it is
        # partitioned over members too ("scan_dist"), so EVERY phase scales
        cfg = SimulationConfig(n_vms=200, n_cloudlets=n_cl,
                               broker="round_robin", is_loaded=True,
                               workload_iters_per_gmi=iters)
        times = []
        for n in ns:
            core = "scan" if n == 1 else "scan_dist"
            r = run_simulation(dataclasses.replace(cfg, core=core), mesh_of(n))
            times.append(sum(r.timings.values()))
            emit(f"f5.1/cl{n_cl}/n{n}", times[-1] * 1e6,
                 f"core_sim={r.timings['core_sim'] * 1e6:.0f}us")
        diffs = [b - a for a, b in zip(times, times[1:])]
        signs = [d < 0 for d in diffs]
        regime = ("positive" if all(signs) else
                  "negative" if not any(signs) else
                  "positive-then-negative" if signs[0] else "complex")
        emit(f"f5.3/cl{n_cl}/regime", 0.0, regime)


if __name__ == "__main__":
    main()
