"""Core DES engine scaling — wave-loop oracle vs closed-form segmented scan.

Sweeps n_cloudlets up to 100k and writes ``BENCH_core.json`` (machine-
readable old-vs-new core timings).  The wave loop is O(waves × C × V) —
superquadratic in C — so it is only *measured* while it fits a time budget
(``BENCH_CORE_WAVE_BUDGET_S``, default 30 s); past the budget the entry is a
quadratic extrapolation from the last measurement, flagged
``wave_extrapolated`` and strictly a LOWER bound (waves also grow with C),
so the reported speedups are conservative.
"""
import json
import os
import sys
import time

if __package__ in (None, ""):      # standalone: python benchmarks/core_scaling.py
    _root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, smoke
from repro.core.cloudsim import SimulationConfig, simulate_completion
from repro.core.des_scan import run_simulation_batch, simulate_completion_scan

BENCH_JSON = "BENCH_core.json"
SIZES = (1_000, 5_000, 20_000, 50_000, 100_000)
N_VMS = 512


def _timed(fn, *args, repeats=3):
    jax.block_until_ready(fn(*args))             # compile + warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats, out


def bench_core(sizes=SIZES, n_vms=N_VMS, wave_budget_s=None):
    if wave_budget_s is None:
        wave_budget_s = float(os.environ.get("BENCH_CORE_WAVE_BUDGET_S", 30))
    rng = np.random.default_rng(0)
    wave = jax.jit(simulate_completion)
    scan = jax.jit(simulate_completion_scan)
    entries = []
    last_wave = None                              # (C, seconds) last measured
    for C in sizes:
        assign = jnp.asarray(rng.integers(0, n_vms, C).astype(np.int32))
        mi = jnp.asarray(rng.uniform(1e3, 5e4, C).astype(np.float32))
        mips = jnp.asarray(rng.uniform(500, 2000, n_vms).astype(np.float32))
        valid = jnp.ones(C, bool)

        scan_s, (f_scan, _) = _timed(scan, assign, mi, mips, valid)
        entry = {"n_cloudlets": C, "scan_s": scan_s}

        predicted = (last_wave[1] * (C / last_wave[0]) ** 2
                     if last_wave else 0.0)
        if predicted <= wave_budget_s:
            wave_s, (f_wave, _) = _timed(wave, assign, mi, mips, valid,
                                         repeats=1)
            last_wave = (C, wave_s)
            rel = float(jnp.abs(f_wave - f_scan).max() /
                        jnp.maximum(jnp.abs(f_wave).max(), 1e-30))
            entry.update(wave_s=wave_s, wave_extrapolated=False,
                         max_rel_diff=rel)
        else:
            entry.update(wave_s=predicted, wave_extrapolated=True)
        entry["speedup"] = entry["wave_s"] / scan_s
        entries.append(entry)
        tag = "extrapolated-lower-bound" if entry["wave_extrapolated"] else \
            f"relerr={entry['max_rel_diff']:.1e}"
        emit(f"core/cl{C}/scan", scan_s * 1e6, f"speedup={entry['speedup']:.0f}x")
        emit(f"core/cl{C}/wave", entry["wave_s"] * 1e6, tag)
    return entries


def bench_batch(n_scenarios=32, n_cloudlets=2_000, n_vms=128):
    cfg = SimulationConfig(n_vms=n_vms, n_cloudlets=n_cloudlets,
                           broker="matchmaking")
    scales = np.linspace(0.5, 2.0, n_scenarios)
    run_simulation_batch(cfg, np.arange(n_scenarios),
                         mi_scale=scales)          # compile the (B,C) shape
    r = run_simulation_batch(cfg, np.arange(n_scenarios), mi_scale=scales)
    wall = r.timings["batch_total"]
    emit(f"core/batch{n_scenarios}", wall * 1e6,
         f"{n_scenarios / wall:.0f} scenarios/s")
    return {"n_scenarios": n_scenarios, "n_cloudlets": n_cloudlets,
            "wall_s": wall, "scenarios_per_s": n_scenarios / wall,
            "mean_makespan": float(r.makespans.mean())}


def main():
    if smoke():
        return {"n_vms": 32,
                "entries": bench_core(sizes=(500, 2_000), n_vms=32),
                "batch": bench_batch(n_scenarios=8, n_cloudlets=200,
                                     n_vms=32)}
    payload = {"n_vms": N_VMS, "entries": bench_core(),
               "batch": bench_batch()}
    return payload


if __name__ == "__main__":
    _path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                         BENCH_JSON)
    with open(_path, "w") as f:
        json.dump(main(), f, indent=2)
    print(f"wrote {_path}")
