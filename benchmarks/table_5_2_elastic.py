"""Table 5.2 — load averages with adaptive scaling: the elastic runner's
health/scale-event log during a real training run."""
import jax

from benchmarks.common import emit, smoke
from repro.configs import get_config, reduced
from repro.core.health import HealthConfig
from repro.data.pipeline import DataConfig
from repro.models.model import build_model
from repro.train.elastic_runner import run_elastic_training


def main():
    cfg = reduced(get_config("smollm-360m"), n_layers=2, d_model=64,
                  n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128,
                  vocab_size=256)
    model = build_model(cfg, remat=False, xent_chunk=16)
    rep = run_elastic_training(
        model, steps=8 if smoke() else 24,
        data_cfg=DataConfig(256, 32, 8), start_instances=1,
        health_cfg=HealthConfig(target_step_time=1e-4, min_threshold=-1.0,
                                time_between_scaling=6, window=3))
    emit("t5.2/scale_events", 0.0,
         ";".join(f"step{s}:{d}->{n}" for s, d, n in rep.scale_events)
         or "none")
    emit("t5.2/final_members", float(rep.final_n_instances), "")


if __name__ == "__main__":
    main()
