"""§3.3 — the analytical speedup model's four regimes, with parameters wired
to the measured Table-5.1 data (model vs measurement)."""
from benchmarks.common import emit
from repro.core.speedup import SpeedupModel


def main():
    cases = {
        "success":   SpeedupModel(t1=1247.0, k=0.995, c_per_n=2.0, fixed=12.0),
        "coordination_heavy": SpeedupModel(t1=3.7, k=0.30, c_per_n=2.2,
                                           fixed=9.0),
        "common":    SpeedupModel(t1=120.0, k=0.97, c_per_n=4.0, fixed=6.0),
        "borderline": SpeedupModel(t1=40.0, k=0.93, s_cost=6.0, c_per_n=1.4,
                                   fixed=2.0),
    }
    ns = [1, 2, 3, 4, 5, 6]
    for name, m in cases.items():
        curve = ";".join(f"{t:.1f}" for t in m.curve(ns))
        emit(f"model/{name}", 0.0, f"regime={m.regime(ns)};T_n={curve}")


if __name__ == "__main__":
    main()
