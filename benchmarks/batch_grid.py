"""Multi-axis scenario-grid throughput — scenarios/s at B ∈ {32, 128, 512}.

The grid stacks seeds × mi_scale × broker × VM-count × MIPS-distribution
variants (heterogeneous shapes padded: 0-MIPS VMs, valid=False cloudlets)
into ONE jitted vmap, and optionally shards the batch across mesh members —
or STREAMS it through the ``ElasticDispatcher`` middleware in fixed-shape
chunks (grids larger than device memory; one compile per geometry, verified
by the cache counters in the payload).  Writes ``BENCH_batch.json``: per-B
wall time, scenarios/s, and the single-member vs mesh-sharded vs streamed
split — the CloudSim-scale scenario throughput a sequential simulator can't
reach (arXiv:0903.2525).
"""
import json
import os
import sys

if __package__ in (None, ""):      # standalone: python benchmarks/batch_grid.py
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    _root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import jax
import numpy as np
from jax.sharding import Mesh

from benchmarks.common import emit, smoke
from repro.core.cloudsim import SimulationConfig
from repro.core.des_scan import make_scenario_grid, run_scenario_grid
from repro.core.executor import DistributedExecutor

BENCH_JSON = "BENCH_batch.json"
BATCH_SIZES = (32, 128, 512)
N_CLOUDLETS = 2_000
N_VMS = 128


def _make(B: int, n_vms: int, n_cloudlets: int):
    cfg = SimulationConfig(n_vms=n_vms, n_cloudlets=n_cloudlets)
    grid = make_scenario_grid(
        seeds=range(max(1, -(-B // 24))), mi_scales=[0.75, 1.5],
        brokers=["round_robin", "matchmaking"],
        vm_counts=[n_vms // 2, n_vms],
        mips_dists=["uniform", "fixed", "bimodal"])
    grid = {k: np.asarray(v)[:B] for k, v in grid.items()}
    assert len(grid["seeds"]) == B
    return cfg, grid


def bench_grid(B: int, executor=None, n_vms=N_VMS, n_cloudlets=N_CLOUDLETS):
    """B mixed-axis variants (2 brokers × 2 VM-counts × 3 MIPS-dists ×
    2 scales × seeds-to-fill, truncated to exactly B) through one jit."""
    cfg, grid = _make(B, n_vms, n_cloudlets)
    run_scenario_grid(cfg, grid, executor=executor)     # compile the shape
    r = run_scenario_grid(cfg, grid, executor=executor)
    wall = r.timings["batch_total"]
    mode = f"mesh{executor.n_members}" if executor is not None else "1member"
    emit(f"grid/B{B}/{mode}", wall * 1e6, f"{B / wall:.0f} scenarios/s")
    return {"n_scenarios": B, "n_cloudlets": n_cloudlets, "n_vms": n_vms,
            "mode": mode, "wall_s": wall, "scenarios_per_s": B / wall,
            "mean_makespan": float(r.makespans.mean()),
            "axes": {"brokers": 2, "vm_counts": 2, "mips_dists": 3,
                     "mi_scales": 2}}


def _stream_entry(B, chunk, n_vms, n_cloudlets, members, mode, wall, rep):
    """One streamed-dispatch BENCH entry (shared by the single-member
    stream bench and the paired sync/async measurement)."""
    emit(f"grid/B{B}/{mode}", wall * 1e6,
         f"{B / wall:.0f} scenarios/s;chunks={rep['n_chunks']};"
         f"compiles={rep['compiles']};ahead={rep['dispatch_ahead']}")
    return {"n_scenarios": B, "n_cloudlets": n_cloudlets, "n_vms": n_vms,
            "mode": mode, "wall_s": wall, "members": members,
            "chunk": chunk, "dispatch_ahead": rep["dispatch_ahead"],
            "scenarios_per_s": B / wall, "n_chunks": rep["n_chunks"],
            "compiles": rep["compiles"], "cache_hits": rep["cache_hits"],
            "staged_device": rep["staged_device"],
            "staged_host": rep["staged_host"]}


def bench_grid_streamed(B: int, chunk: int, n_vms=N_VMS,
                        n_cloudlets=N_CLOUDLETS, *, dispatch_ahead=2,
                        members=1):
    """The same grid streamed chunk-by-chunk through the dispatcher: only
    ``chunk`` variants are resident per dispatch (larger-than-memory grids)
    and the compile cache holds ONE executable for the whole stream.

    ``dispatch_ahead`` selects the pipeline: 0 = ``streamed_sync`` (the
    pre-async baseline: host-staged items, one blocking step + D2H per
    chunk), >=1 = ``streamed_async`` (chunk k+1 staged while chunk k
    computes; the host blocks only at the final reduce).  The async/sync
    pair at the SAME chunking and member count is the latency-hiding
    measurement the async dispatch PR is pinned on; both are best-of-3
    (chunked streams are short, so single-shot walls are noisy on a shared
    box)."""
    from repro.core.dispatch import ElasticDispatcher

    cfg, grid = _make(B, n_vms, n_cloudlets)
    d = ElasticDispatcher(devices=jax.devices()[:members],
                          start_members=members,
                          dispatch_ahead=dispatch_ahead)
    run_scenario_grid(cfg, grid, dispatcher=d, chunk=chunk)   # compile
    wall, r = None, None
    for _ in range(3):
        ri = run_scenario_grid(cfg, grid, dispatcher=d, chunk=chunk)
        wi = ri.timings["batch_total"]
        if wall is None or wi < wall:
            wall, r = wi, ri
    return _stream_entry(B, chunk, n_vms, n_cloudlets, members,
                         f"stream{chunk}", wall, r.dispatch)


def bench_streamed_pair(B: int, chunk: int, n_vms, n_cloudlets, members,
                        reps: int = 4):
    """``streamed_sync`` vs ``streamed_async`` measured PAIRED: the two
    modes alternate rep by rep so both sample the same box states, and each
    keeps its best — on a shared machine whose throughput wobbles between
    windows, back-to-back blocks would measure the neighbor's load, not the
    pipeline.  Sync (dispatch_ahead=0) is the legacy path end to end:
    host-staged items, one blocking D2H per chunk; async overlaps chunk
    k+1's staging/dispatch with chunk k's compute and synchronizes only at
    the reduce boundary."""
    from repro.core.dispatch import ElasticDispatcher

    cfg, grid = _make(B, n_vms, n_cloudlets)
    modes = {"streamed_sync": 0, "streamed_async": 4}
    disp = {m: ElasticDispatcher(devices=jax.devices()[:members],
                                 start_members=members, dispatch_ahead=a)
            for m, a in modes.items()}
    best = {}
    for m in modes:                        # compile both before measuring
        run_scenario_grid(cfg, grid, dispatcher=disp[m], chunk=chunk)
    for _ in range(reps):
        for m in modes:
            r = run_scenario_grid(cfg, grid, dispatcher=disp[m], chunk=chunk)
            w = r.timings["batch_total"]
            if m not in best or w < best[m][0]:
                best[m] = (w, r)
    return [_stream_entry(B, chunk, n_vms, n_cloudlets, members, m,
                          best[m][0], best[m][1].dispatch)
            for m in modes]


def main():
    if smoke():
        sizes, n_vms, n_cl = (8,), 16, 200
    else:
        sizes, n_vms, n_cl = BATCH_SIZES, N_VMS, N_CLOUDLETS
    entries = [bench_grid(B, n_vms=n_vms, n_cloudlets=n_cl) for B in sizes]
    n_dev = len(jax.devices())
    if n_dev > 1:
        ex = DistributedExecutor(Mesh(np.array(jax.devices()), ("data",)))
        entries += [bench_grid(B, executor=ex, n_vms=n_vms, n_cloudlets=n_cl)
                    for B in sizes]
    # single-member larger-than-memory streaming (the PR-4 entry)
    entries += [bench_grid_streamed(max(sizes), max(max(sizes) // 4, 1),
                                    n_vms=n_vms, n_cloudlets=n_cl)]
    # async vs sync pipeline at the SAME chunking on the full device set:
    # small chunks make the per-chunk dispatch/sync overhead a significant
    # cost, which is exactly what the dispatch-ahead pipeline hides
    B = max(sizes)
    entries += bench_streamed_pair(B, max(B // 32, 1), n_vms, n_cl, n_dev)
    return {"batch_sizes": list(sizes), "n_devices": n_dev,
            "entries": entries}


if __name__ == "__main__":
    _path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                         BENCH_JSON)
    with open(_path, "w") as f:
        json.dump(main(), f, indent=2)
    print(f"wrote {_path}")
