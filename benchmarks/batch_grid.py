"""Multi-axis scenario-grid throughput — scenarios/s at B ∈ {32, 128, 512}.

The grid stacks seeds × mi_scale × broker × VM-count × MIPS-distribution
variants (heterogeneous shapes padded: 0-MIPS VMs, valid=False cloudlets)
into ONE jitted vmap, and optionally shards the batch across mesh members —
or STREAMS it through the ``ElasticDispatcher`` middleware in fixed-shape
chunks (grids larger than device memory; one compile per geometry, verified
by the cache counters in the payload).  Writes ``BENCH_batch.json``: per-B
wall time, scenarios/s, and the single-member vs mesh-sharded vs streamed
split — the CloudSim-scale scenario throughput a sequential simulator can't
reach (arXiv:0903.2525).
"""
import json
import os
import sys

if __package__ in (None, ""):      # standalone: python benchmarks/batch_grid.py
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    _root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import jax
import numpy as np
from jax.sharding import Mesh

from benchmarks.common import emit, smoke
from repro.core.cloudsim import SimulationConfig
from repro.core.des_scan import make_scenario_grid, run_scenario_grid
from repro.core.executor import DistributedExecutor

BENCH_JSON = "BENCH_batch.json"
BATCH_SIZES = (32, 128, 512)
N_CLOUDLETS = 2_000
N_VMS = 128


def _make(B: int, n_vms: int, n_cloudlets: int):
    cfg = SimulationConfig(n_vms=n_vms, n_cloudlets=n_cloudlets)
    grid = make_scenario_grid(
        seeds=range(max(1, -(-B // 24))), mi_scales=[0.75, 1.5],
        brokers=["round_robin", "matchmaking"],
        vm_counts=[n_vms // 2, n_vms],
        mips_dists=["uniform", "fixed", "bimodal"])
    grid = {k: np.asarray(v)[:B] for k, v in grid.items()}
    assert len(grid["seeds"]) == B
    return cfg, grid


def bench_grid(B: int, executor=None, n_vms=N_VMS, n_cloudlets=N_CLOUDLETS):
    """B mixed-axis variants (2 brokers × 2 VM-counts × 3 MIPS-dists ×
    2 scales × seeds-to-fill, truncated to exactly B) through one jit."""
    cfg, grid = _make(B, n_vms, n_cloudlets)
    run_scenario_grid(cfg, grid, executor=executor)     # compile the shape
    r = run_scenario_grid(cfg, grid, executor=executor)
    wall = r.timings["batch_total"]
    mode = f"mesh{executor.n_members}" if executor is not None else "1member"
    emit(f"grid/B{B}/{mode}", wall * 1e6, f"{B / wall:.0f} scenarios/s")
    return {"n_scenarios": B, "n_cloudlets": n_cloudlets, "n_vms": n_vms,
            "mode": mode, "wall_s": wall, "scenarios_per_s": B / wall,
            "mean_makespan": float(r.makespans.mean()),
            "axes": {"brokers": 2, "vm_counts": 2, "mips_dists": 3,
                     "mi_scales": 2}}


def bench_grid_streamed(B: int, chunk: int, n_vms=N_VMS,
                        n_cloudlets=N_CLOUDLETS):
    """The same grid streamed chunk-by-chunk through the dispatcher: only
    ``chunk`` variants are resident per dispatch (larger-than-memory grids)
    and the compile cache holds ONE executable for the whole stream."""
    from repro.core.dispatch import ElasticDispatcher

    cfg, grid = _make(B, n_vms, n_cloudlets)
    d = ElasticDispatcher(devices=jax.devices()[:1])
    run_scenario_grid(cfg, grid, dispatcher=d, chunk=chunk)   # compile
    r = run_scenario_grid(cfg, grid, dispatcher=d, chunk=chunk)
    wall = r.timings["batch_total"]
    rep = r.dispatch
    emit(f"grid/B{B}/stream{chunk}", wall * 1e6,
         f"{B / wall:.0f} scenarios/s;chunks={rep['n_chunks']};"
         f"compiles={rep['compiles']}")
    return {"n_scenarios": B, "n_cloudlets": n_cloudlets, "n_vms": n_vms,
            "mode": f"stream{chunk}", "wall_s": wall,
            "scenarios_per_s": B / wall, "n_chunks": rep["n_chunks"],
            "compiles": rep["compiles"], "cache_hits": rep["cache_hits"]}


def main():
    if smoke():
        sizes, n_vms, n_cl = (8,), 16, 200
    else:
        sizes, n_vms, n_cl = BATCH_SIZES, N_VMS, N_CLOUDLETS
    entries = [bench_grid(B, n_vms=n_vms, n_cloudlets=n_cl) for B in sizes]
    n_dev = len(jax.devices())
    if n_dev > 1:
        ex = DistributedExecutor(Mesh(np.array(jax.devices()), ("data",)))
        entries += [bench_grid(B, executor=ex, n_vms=n_vms, n_cloudlets=n_cl)
                    for B in sizes]
    entries += [bench_grid_streamed(max(sizes), max(max(sizes) // 4, 1),
                                    n_vms=n_vms, n_cloudlets=n_cl)]
    return {"batch_sizes": list(sizes), "n_devices": n_dev,
            "entries": entries}


if __name__ == "__main__":
    _path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                         BENCH_JSON)
    with open(_path, "w") as f:
        json.dump(main(), f, indent=2)
    print(f"wrote {_path}")
