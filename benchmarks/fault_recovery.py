"""Fault tolerance — recovery latency per fault kind + fault-check overhead.

Two measurements on the scenario-grid workload, written to
``BENCH_fault.json``:

* ``overhead``: the fault-free ``streamed_async`` path with and without the
  guarded retirement (finiteness probe + chunk deadline), measured PAIRED —
  the two dispatchers alternate rep by rep so both sample the same box
  states, each keeps its best.  The guarded/unguarded ratio is the price of
  always-on failure detection; the PR acceptance pins it at <= 2%.  Both
  walls are ``scan_s`` entries (labelled by ``core``), so ``run.py --check``
  gates them against the committed file like every other benchmark.
* ``recovery``: for each fault kind, one injected failure mid-stream and the
  measured detect-to-replayed latency — ``recovery_s`` (forced failure
  remesh, member_crash/quarantine) or ``recovered_after_s`` (chunk replay:
  nan_poison / stall / compile_fail).  Latency entries are informational
  (they include injected sleeps), not regression-gated.
"""
import json
import os
import sys

if __package__ in (None, ""):   # standalone: python benchmarks/fault_recovery.py
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    _root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import jax
import numpy as np

from benchmarks.common import emit, smoke
from repro.core.cloudsim import SimulationConfig
from repro.core.des_scan import make_scenario_grid, run_scenario_grid
from repro.core.faults import FaultInjector, FaultSpec, RetryPolicy

BENCH_JSON = "BENCH_fault.json"


def _make(B: int, n_vms: int, n_cloudlets: int):
    cfg = SimulationConfig(n_vms=n_vms, n_cloudlets=n_cloudlets)
    grid = make_scenario_grid(
        seeds=range(max(1, -(-B // 8))), mi_scales=[0.75, 1.5],
        vm_counts=[n_vms // 2, n_vms], mips_dists=["uniform", "fixed"])
    grid = {k: np.asarray(v)[:B] for k, v in grid.items()}
    assert len(grid["seeds"]) == B
    return cfg, grid


def _dispatcher(members, *, policy=None, injector=None, ahead=4, pool=None):
    from repro.core.dispatch import ElasticDispatcher
    return ElasticDispatcher(devices=jax.devices()[:(pool or members)],
                             start_members=members, dispatch_ahead=ahead,
                             retry_policy=policy, fault_injector=injector)


def bench_overhead(B, chunk, n_vms, n_cloudlets, members, reps=8):
    """Fault-free streamed_async, guarded vs unguarded, paired best-of.
    The rep order REVERSES every rep (ABBA): on this shared 2-core box the
    mode measured second inherits the first one's cache/thermal state, and
    a fixed order turns that drift into a systematic bias of several
    percent — far larger than the real guard cost."""
    cfg, grid = _make(B, n_vms, n_cloudlets)
    guarded_policy = RetryPolicy(check_finite=True, chunk_timeout_s=300.0)
    disp = {"fault_unguarded": _dispatcher(members),
            "fault_guarded": _dispatcher(members, policy=guarded_policy)}
    best = {}
    for m in disp:                         # compile both before measuring
        run_scenario_grid(cfg, grid, dispatcher=disp[m], chunk=chunk)
    for rep in range(reps):
        order = list(disp) if rep % 2 == 0 else list(disp)[::-1]
        for m in order:
            r = run_scenario_grid(cfg, grid, dispatcher=disp[m], chunk=chunk)
            w = r.timings["batch_total"]
            if m not in best or w < best[m]:
                best[m] = w
    overhead = best["fault_guarded"] / best["fault_unguarded"] - 1.0
    entries = [{"core": m, "n_scenarios": B, "n_vms": n_vms,
                "n_cloudlets": n_cloudlets, "n_members": members,
                "chunk": chunk, "scan_s": best[m]} for m in disp]
    for e in entries:
        emit(f"fault/{e['core']}/B{B}", e["scan_s"] * 1e6,
             f"{B / e['scan_s']:.0f} scenarios/s")
    emit("fault/overhead", overhead * 1e6, f"{overhead * 100:+.2f}%")
    return {"entries": entries, "overhead_pct": overhead * 100.0}


def bench_recovery(B, chunk, n_vms, n_cloudlets):
    """One injected failure per kind mid-stream of the scenario grid; the
    report's structured failure/recovery records carry the latency."""
    cfg, grid = _make(B, n_vms, n_cloudlets)
    mid = max((B // chunk) // 2, 0)
    out = []

    # calibrate a stall deadline off the fault-free per-chunk wall so a
    # loaded box never trips it on legitimate chunks
    d0 = _dispatcher(1)
    r0 = run_scenario_grid(cfg, grid, dispatcher=d0, chunk=chunk)
    per_chunk = r0.timings["batch_total"] / max(r0.dispatch["n_chunks"], 1)
    deadline = max(8.0 * per_chunk, 0.5)

    members = 2 if len(jax.devices()) >= 2 else 1
    kinds = {
        "member_crash": (FaultSpec("member_crash", chunk=mid, member=1),
                         RetryPolicy(), members),
        "nan_poison": (FaultSpec("nan_poison", chunk=mid, member=0),
                       RetryPolicy(check_finite=True), 1),
        "stall": (FaultSpec("stall", chunk=mid, member=0,
                            delay_s=2.0 * deadline),
                  RetryPolicy(chunk_timeout_s=deadline), 1),
        "compile_fail": (FaultSpec("compile_fail", chunk=mid),
                         RetryPolicy(), 1),
    }
    if kinds["member_crash"][2] < 2:
        del kinds["member_crash"]          # nothing to kill on one device
    for kind, (spec, policy, m) in kinds.items():
        inj = FaultInjector([spec])
        # a spare device so member-crash recovery keeps the member count
        d = _dispatcher(m, policy=policy, injector=inj, ahead=2,
                        pool=min(m + 1, len(jax.devices())))
        r = run_scenario_grid(cfg, grid, dispatcher=d, chunk=chunk)
        rep = r.dispatch
        entry = {"kind": kind, "n_scenarios": B, "n_members": m,
                 "chunk": chunk, "failures": len(rep["failures"]),
                 "retries": rep["retries"]}
        if rep["recovery_events"]:
            entry["recovery_s"] = rep["recovery_events"][0].get("recovery_s")
            entry["replayed_chunks"] = len(
                rep["recovery_events"][0]["replayed_chunks"])
        if rep["failures"]:
            entry["recovered_after_s"] = rep["failures"][-1].get(
                "recovered_after_s")
        latency = entry.get("recovery_s") or entry.get("recovered_after_s")
        emit(f"fault/recover/{kind}", (latency or 0.0) * 1e6,
             f"retries={rep['retries']}")
        out.append(entry)
    return out


def main():
    if smoke():
        B, chunk, n_vms, n_cl = 8, 2, 16, 200
    else:
        B, chunk, n_vms, n_cl = 256, 32, 128, 2_000
    n_dev = len(jax.devices())
    overhead = bench_overhead(B, chunk, n_vms, n_cl, n_dev)
    rec_B, rec_chunk = (8, 2) if smoke() else (64, 8)
    recovery = bench_recovery(rec_B, rec_chunk, n_vms, n_cl)
    return {"n_devices": n_dev, "overhead": overhead, "recovery": recovery}


if __name__ == "__main__":
    _path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                         BENCH_JSON)
    with open(_path, "w") as f:
        json.dump(main(), f, indent=2)
    print(f"wrote {_path}")
