"""Multi-tenant serve front end — requests/s and sojourn SLOs under load.

Drives ``TenantFrontEnd`` with tenant counts {4, 16, 64} (constant total
request volume, so entries are comparable) over a shared scenario-grid job
— ONE CompileCache serves every tenant — and records throughput plus the
admitted-request sojourn p50/p99 for two scenarios per tenant count:

  serve_load/T<n>         clean traffic
  serve_load_faulty/T<n>  the same traffic with one tenant poisoned by an
                          unrecoverable injected fault (NaN poison past its
                          retry budget): its stream fails structured, every
                          other tenant keeps serving — the bench pins the
                          overhead of the containment path.

The cluster geometry is FIXED (no scale events) so ``scan_s`` is a stable
regression gate; scale-under-live-traffic is pinned functionally in
tests/test_frontend.py instead.
"""
import json
import os
import sys
import time

if __package__ in (None, ""):   # standalone: python benchmarks/serve_load.py
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    _root = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import jax
import numpy as np

from benchmarks.common import emit, smoke
from repro.core.cloudsim import SimulationConfig
from repro.core.des_scan import make_scenario_grid
from repro.core.dispatch import ElasticDispatcher
from repro.core.faults import FaultInjector, FaultSpec, RetryPolicy
from repro.serve.frontend import TenantFrontEnd, grid_request

BENCH_JSON = "BENCH_serve.json"


def _grid(B: int, n_vms: int, n_cloudlets: int):
    cfg = SimulationConfig(n_vms=n_vms, n_cloudlets=n_cloudlets)
    grid = make_scenario_grid(
        seeds=range(max(1, -(-B // 8))), mi_scales=[0.75, 1.5],
        vm_counts=[n_vms // 2, n_vms], mips_dists=["uniform", "fixed"])
    grid = {k: np.asarray(v)[:B] for k, v in grid.items()}
    return cfg, grid


def _serve_once(d, n_tenants, per_tenant, cfg, grid, chunk, faulty):
    """One full serve cycle: fresh front end on the (warm) shared
    dispatcher, admit everything, drain, return (wall, frontend)."""
    inj = None
    if faulty:
        inj = FaultInjector([FaultSpec(kind="nan_poison", chunk=0, times=999,
                                       tenant="t0")])
    fe = TenantFrontEnd(d, backlog_max=n_tenants * per_tenant + 1,
                        fault_injector=inj)
    policy = RetryPolicy(max_attempts=2, check_finite=faulty)
    for i in range(n_tenants):
        fe.register_tenant(f"t{i}", retry_policy=policy)
    for r in range(per_tenant):
        for i in range(n_tenants):
            dec = fe.submit(grid_request(f"t{i}", cfg, grid, chunk=chunk))
            assert dec.admitted, dec
    t0 = time.perf_counter()
    fe.run()
    return time.perf_counter() - t0, fe


def bench_cell(n_tenants, total_requests, B, n_vms, n_cloudlets, chunk,
               members, faulty, reps=2):
    cfg, grid = _grid(B, n_vms, n_cloudlets)
    d = ElasticDispatcher(devices=jax.devices()[:members],
                          start_members=members, dispatch_ahead=2)
    per_tenant = max(1, total_requests // n_tenants)
    _serve_once(d, n_tenants, 1, cfg, grid, chunk, faulty)   # compile warmup
    best = None
    for _ in range(reps):
        wall, fe = _serve_once(d, n_tenants, per_tenant, cfg, grid, chunk,
                               faulty)
        if best is None or wall < best[0]:
            best = (wall, fe)
    wall, fe = best
    s = fe.summary()
    soj = s["stats"]["sojourn"]
    n_done = sum(t["completed"] for t in s["tenants"].values())
    n_fail = sum(t["failed"] for t in s["tenants"].values())
    # nothing may go missing: every admitted request either completed or
    # failed structurally (no shedding on this fixed-geometry bench)
    assert n_done + n_fail == per_tenant * n_tenants, s["tenants"]
    core = f"serve_load{'_faulty' if faulty else ''}/T{n_tenants}"
    entry = {"core": core, "n_tenants": n_tenants,
             "n_requests": per_tenant * n_tenants, "n_scenarios": B,
             "n_vms": n_vms, "n_cloudlets": n_cloudlets,
             "n_members": members, "chunk": chunk, "scan_s": wall,
             "requests_per_s": (per_tenant * n_tenants) / wall,
             "sojourn_p50_s": soj.get("hist_p50"),
             "sojourn_p99_s": soj.get("hist_p99"),
             "completed": n_done, "failed": n_fail,
             "cache_builds": s["cache"]["builds"]}
    emit(core.replace("/", "_"), wall * 1e6,
         f"req_s={entry['requests_per_s']:.1f} "
         f"p99={soj.get('hist_p99', float('nan')) * 1e3:.1f}ms "
         f"failed={n_fail}")
    return entry


def main():
    if smoke():
        tenant_counts, total, B, n_vms, n_cl, chunk = (2, 4), 8, 8, 16, 200, 4
    else:
        tenant_counts, total, B, n_vms, n_cl, chunk = ((4, 16, 64), 64, 16,
                                                       64, 1_000, 8)
    members = min(4, len(jax.devices()))
    entries = [bench_cell(T, total, B, n_vms, n_cl, chunk, members, faulty)
               for T in tenant_counts for faulty in (False, True)]
    return {"n_devices": len(jax.devices()), "entries": entries}


if __name__ == "__main__":
    _path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                         BENCH_JSON)
    with open(_path, "w") as f:
        json.dump(main(), f, indent=2)
    print(f"wrote {_path}")
