"""Figs 5.4–5.7 — matchmaking-based scheduling: time, speedup %, efficiency
vs members (Eqs 3.7/3.8/3.10)."""
import jax

from benchmarks.common import emit, mesh_of, smoke
from repro.core.cloudsim import SimulationConfig, run_simulation


def main():
    n_devs = len(jax.devices())
    ns = [n for n in (1, 2, 4, 8) if n <= n_devs]
    sizes, iters = ((60,), 0.05) if smoke() else ((200, 400, 800), 1.0)
    for n_cl in sizes:
        cfg = SimulationConfig(n_vms=200, n_cloudlets=n_cl,
                               broker="matchmaking", is_loaded=True,
                               workload_iters_per_gmi=iters)
        t1 = None
        for n in ns:
            r = run_simulation(cfg, mesh_of(n))
            t = sum(r.timings.values())
            t1 = t if n == 1 else t1
            s = t1 / t
            emit(f"f5.4/cl{n_cl}/n{n}", t * 1e6,
                 f"speedup={s:.2f};eff={s / n:.2f};improve%={100 * (1 - 1 / s):.0f}")


if __name__ == "__main__":
    main()
